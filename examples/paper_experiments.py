"""Reproduce the paper's experiment suite on a chosen graph: Table-I stats,
Fig 5/6/7 message curves, Fig 8/9 active-node curves, termination-detection
overhead, and the simulated-runtime comparison.

    PYTHONPATH=src python examples/paper_experiments.py --graph EEN
    PYTHONPATH=src python examples/paper_experiments.py --graph chain --n 500
"""

from __future__ import annotations

import argparse

from repro.core import bz_core_numbers, kcore_decompose, work_bound
from repro.core.cost_model import DATACENTER, INTERNET, simulate_runtime
from repro.core.termination import HeartbeatModel, bsp_termination_cost
from repro.graph import generators as gen


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="EEN")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--n", type=int, default=500)
    args = ap.parse_args()

    if args.graph == "chain":
        g = gen.chain(args.n)
    else:
        g = gen.snap_analogue(args.graph, scale=args.scale, seed=0)

    res = kcore_decompose(g)
    assert (res.core == bz_core_numbers(g)).all()
    st = res.stats

    print(f"=== Table I row ({args.graph}) ===")
    print(f"n={g.n} m={g.m} AvgDeg={g.avg_deg:.1f} MaxDeg={g.max_deg} "
          f"MaxCore={res.core.max()}")

    print("\n=== Fig 5: total messages ===")
    wb = work_bound(g, res.core)
    print(f"total={st.total_messages}  work_bound={wb}  "
          f"ratio={st.total_messages / wb:.3f}")

    print("\n=== Fig 6/7: messages per round ===")
    print(st.messages_per_round.tolist())

    print("\n=== Fig 8/9: active nodes per round ===")
    print(st.active_per_round.tolist())

    print("\n=== termination detection (paper SIII.C vs BSP) ===")
    hb = HeartbeatModel().overhead(st, round_time_s=1.0)
    print(f"heartbeats={hb['total_heartbeats']} "
          f"(delay {hb['termination_delay_s']}s) vs BSP all-reduces="
          f"{bsp_termination_cost(st, 256)['allreduces']} (delay 1 round)")

    print("\n=== Fig 10 analogue: simulated runtime ===")
    for m in (INTERNET, DATACENTER):
        r = simulate_runtime(st, m)
        print(f"{m.name}: {r['total_s']:.4f}s "
              f"(latency-bound {r['latency_bound_fraction']:.0%})")


if __name__ == "__main__":
    main()
