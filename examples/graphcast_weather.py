"""GraphCast weather mode — the paper-faithful encoder-processor-decoder on
a (reduced) lat-lon grid + icosahedral multimesh: one autoregressive
rollout step and a short next-state training loop.

    PYTHONPATH=src python examples/graphcast_weather.py
"""


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models.gnn import graphcast as GC
from repro.optim import AdamWConfig, adamw_init, adamw_update

cfg = get_smoke("graphcast")
graph = {k: jnp.asarray(v) for k, v in GC.make_weather_graph(cfg).items()}
params = GC.init_weather_params(cfg, jax.random.key(0))
n_grid = cfg.params["grid_lat"] * cfg.params["grid_lon"]
n_vars = cfg.params["n_vars"]

rng = np.random.default_rng(0)
state0 = jnp.asarray(rng.normal(size=(n_grid, n_vars)).astype(np.float32))
# synthetic "dynamics": smooth decay toward a fixed pattern
target_pattern = jnp.asarray(rng.normal(size=(n_grid, n_vars))
                             .astype(np.float32))
next_state = lambda s: 0.9 * s + 0.1 * target_pattern


def loss_fn(p, s):
    pred = GC.weather_forward(p, cfg, s, graph)
    return jnp.mean((pred - next_state(s)) ** 2)


opt = adamw_init(params)
step = jax.jit(lambda p, o, s: (lambda l, g: adamw_update(
    p, g, o, AdamWConfig(lr=1e-3, weight_decay=0.0)) + (l,))(
    *jax.value_and_grad(loss_fn)(p, s)))

s = state0
losses = []
for i in range(25):
    params, opt, _, loss = step(params, opt, s)
    losses.append(float(loss))
    s = next_state(s)
print(f"weather next-state MSE: {losses[0]:.4f} -> {losses[-1]:.4f}")
assert losses[-1] < losses[0]

# autoregressive rollout
pred = state0
for _ in range(3):
    pred = GC.weather_forward(params, cfg, pred, graph)
print("3-step rollout finite:", bool(jnp.isfinite(pred).all()),
      "shape:", pred.shape)
