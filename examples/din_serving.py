"""RecSys scenario: train DIN briefly, then serve batched requests and run
candidate retrieval — the three serving shapes of the assigned config.

    PYTHONPATH=src python examples/din_serving.py
"""

import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ShapeSpec
from repro.models.recsys import din, steps as rsteps
from repro.optim import adamw_init

cfg = get_smoke("din")
params = din.init_params(cfg, jax.random.key(0))
opt = adamw_init(params)

train = jax.jit(rsteps.make_train_step(cfg), donate_argnums=(0, 1))
shape_tr = ShapeSpec("t", "train", {"batch": 256})
losses = []
for i in range(20):
    batch = rsteps.synth_batch(cfg, shape_tr, seed=i)
    params, opt, m = train(params, opt, batch)
    losses.append(float(m["loss"]))
print(f"train: loss {losses[0]:.4f} -> {losses[-1]:.4f}")

serve = jax.jit(rsteps.make_serve_step(cfg))
batch = rsteps.synth_batch(cfg, ShapeSpec("s", "serve", {"batch": 512}),
                           seed=99)
t0 = time.perf_counter()
probs = jax.block_until_ready(serve(params, batch))
print(f"serve_p99 batch=512: {1e3 * (time.perf_counter() - t0):.1f} ms, "
      f"mean ctr {float(probs.mean()):.3f}")

retr = jax.jit(rsteps.make_retrieval_step(cfg, top_k=10))
rb = rsteps.synth_batch(cfg, ShapeSpec("r", "retrieval",
                                       {"batch": 1, "n_candidates": 5000}),
                        seed=7)
vals, idx = retr(params, rb)
print("retrieval top-10 candidate ids:", np.asarray(idx).tolist())
