"""Quickstart: distributed k-core decomposition in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import KCoreConfig, bz_core_numbers, kcore_decompose
from repro.graph import generators as gen

# The paper's Fig. 1 example graph (A..H)
g, expected = gen.fig1_example()
res = kcore_decompose(g)
print("Fig-1 cores :", dict(zip("ABCDEFGH", res.core.tolist())))
assert (res.core == expected).all()

# A social-network analogue (facebook-combined, Table I)
g = gen.snap_analogue("FC", scale=0.2, seed=0)
res = kcore_decompose(g)
print(f"\nFC-analogue: n={g.n} m={g.m} max_core={res.core.max()} "
      f"rounds={res.rounds} total_messages={res.stats.total_messages}")
assert (res.core == bz_core_numbers(g)).all()

# messages per round — the paper's Fig 6/7 quantity
bars = res.stats.messages_per_round
peak = bars.max()
print("\nmessages per round:")
for r, m in enumerate(bars):
    print(f"  round {r:2d} {'#' * int(40 * m / peak):<40} {m}")

# beyond-paper: block-Gauss-Seidel scheduling
gs = kcore_decompose(g, KCoreConfig(mode="block_gs", n_blocks=16))
print(f"\nblock-GS: rounds {res.rounds} -> {gs.rounds}, messages "
      f"{res.stats.total_messages} -> {gs.stats.total_messages}")
