"""End-to-end driver: train a small LM through the full stack — data
pipeline -> train step (AdamW, grad clip, schedule) -> fault-tolerant
driver with checkpoint/restart -> loss curve.

Default is a CPU-friendly ~5M-param run (~2 min). The ~100M/300-step
configuration the deliverable describes is:

    PYTHONPATH=src python examples/train_lm_e2e.py \
        --layers 10 --d-model 768 --steps 300 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.data import synth_lm_batch
from repro.models.transformer import model as M
from repro.models.transformer.steps import make_train_step
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import TrainDriver, TrainDriverConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_lm")
    args = ap.parse_args()

    cfg = LMConfig(
        name="example-lm", n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 128, 1),
        d_head=64, d_ff=args.d_model * 3, vocab=8192, tie_embeddings=True)
    print(f"params: {cfg.n_params/1e6:.1f}M")

    params = M.init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, None, AdamWConfig(lr=1e-3),
                                   total_steps=args.steps),
                   donate_argnums=(0, 1))

    def step_fn(state, batch):
        p, o = state
        tokens, labels = batch
        p, o, metrics = step(p, o, tokens, labels)
        return (p, o), metrics

    def batch_fn(i):
        t, l = synth_lm_batch(cfg.vocab, args.batch, args.seq, seed=0,
                              step=i)
        return jnp.asarray(t), jnp.asarray(l)

    driver = TrainDriver(step_fn, (params, opt), batch_fn,
                         TrainDriverConfig(total_steps=args.steps,
                                           checkpoint_every=args.steps // 2,
                                           checkpoint_dir=args.ckpt_dir,
                                           log_every=max(args.steps // 10,
                                                         1)))
    report = driver.run()
    print("loss curve:")
    for m in report["metrics"]:
        print(f"  step {m['step']:4d} loss {m['loss']:.3f} "
              f"({m['step_time_s']:.2f}s/step)")
    first, last = report["metrics"][0]["loss"], report["metrics"][-1]["loss"]
    assert last < first, "loss did not decrease"
    print(f"OK: {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
