"""Streaming k-core maintenance: batched edge churn, warm-started incremental
re-convergence, and a batched core-number query server.

The static engine (repro.core.kcore) pays a full decomposition per graph.
This package layers dynamic-graph maintenance on top of it:

  * ``delta``  — apply insert/delete edge batches to the COO/CSR Graph under
    the paper's dataCleanse rules, reporting exactly what changed;
  * ``engine`` — warm-start the locality iteration from the previous fixpoint
    and re-converge only the affected frontier (provably exact, typically a
    small fraction of the from-scratch message bill);
  * ``server`` — interleave update batches with batched core-number /
    membership / max-k queries (the paper's million-client scenario);
  * ``concurrent`` — snapshot-isolated threaded front end: a read worker
    pool answers from the last converged fixpoint (seqlock-published
    immutable snapshots) while the single writer re-converges, with
    graceful drain + warm-restart checkpointing.
"""

from repro.streaming.concurrent import (ConcurrentKCoreServer, CoreSnapshot,
                                        SnapshotBox)
from repro.streaming.delta import (ChurnDelta, DeltaResult, EdgeBatch,
                                   PatchableCSR, apply_batch,
                                   canonical_edges, random_churn_batch)
from repro.streaming.engine import (BatchResult, StreamingConfig,
                                    StreamingKCoreEngine, warm_start_seed)
from repro.streaming.server import (AsofView, CoreCheckpointRing,
                                    KCoreServer, Request, Response)

__all__ = [
    "EdgeBatch",
    "ChurnDelta",
    "DeltaResult",
    "PatchableCSR",
    "apply_batch",
    "canonical_edges",
    "random_churn_batch",
    "StreamingConfig",
    "StreamingKCoreEngine",
    "BatchResult",
    "warm_start_seed",
    "KCoreServer",
    "ConcurrentKCoreServer",
    "CoreSnapshot",
    "SnapshotBox",
    "CoreCheckpointRing",
    "AsofView",
    "Request",
    "Response",
]
