"""Snapshot-isolated concurrent serving: reads proceed during re-convergence.

The sequential ``KCoreServer.serve`` loop interleaves update batches and
queries strictly, so every batch re-convergence stalls all reads — the
inverse of a production deployment, where millions of readers query core
numbers while ONE writer absorbs the update stream. This module is the
threaded front end that decouples them:

* **Double-buffered core state.** The maintenance engine itself is the
  *back* buffer: ``apply_batch`` / ``advance_window`` converge in place as
  always. The *front* buffer is an immutable ``CoreSnapshot`` — the last
  converged fixpoint's core vector (a read-only copy), its as-of ring view,
  and a monotone version — published through a seqlock-style
  ``SnapshotBox``. Readers never see intermediate estimates: every read is
  answered bit-exactly from SOME converged fixpoint (the consistency
  contract benchmarks/serving_mixed.py asserts response by response).

* **Worker pool for reads, single writer.** ``submit_read`` dispatches
  read ops onto a thread pool; ``update``/``advance_window`` run under the
  single-writer lock and flip the snapshot after converging. A read
  validates its request BEFORE acquiring a snapshot
  (``KCoreServer.validate``) and returns a structured error ``Response``
  instead of raising through the pool.

* **Staleness is bounded and observable.** During a re-convergence readers
  serve the previous fixpoint; the stale-read window is exactly one batch
  re-convergence wall. Exposed as ``kcore_snapshot_age_seconds`` (gauge,
  refreshed on every read) and ``kcore_reads_inflight``; every flip emits
  a ``snapshot.flip`` span, bumps ``kcore_snapshot_flips_total``, and
  lands as a ``snapshot_flip`` event in the flight recorder ring.

* **Warm restart.** ``drain()`` — the SIGTERM path in
  ``launch/kcore_serve.py`` — stops accepting reads, drains in-flight
  ones, waits out the writer, and saves the full server state
  (``KCoreServer.state_dict``: engine CSR + cores + window cursor + as-of
  ring) through ``repro/checkpoint``. A restarted server loads it and
  resumes the replay in lockstep: identical cores AND message bills to an
  uninterrupted run.

Thread-safety notes: snapshots are immutable (read-only numpy + frozen
dataclass), publication is a single reference swap guarded by the seqlock
counter, and all counters readers touch are the thread-safe
``repro.obs.metrics`` primitives. The underlying ``KCoreServer``'s plain
attributes are written only by the single writer.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Iterable

import numpy as np

from repro.obs import flight as _flight
from repro.obs import trace as _trace
from repro.streaming.server import (AsofView, KCoreServer, Request,
                                    Response)

READ_OPS = ("core", "in_kcore", "members", "max_k", "core_asof")


def _json_payload(payload):
    """Flatten a Response payload to plain JSON types."""
    if isinstance(payload, np.ndarray):
        return payload.tolist()
    if isinstance(payload, tuple):              # core_asof: (boundary_t, cores)
        bt, core = payload
        return {"t": float(bt), "core": np.asarray(core).tolist()}
    if isinstance(payload, (np.integer, np.floating, np.bool_)):
        return payload.item()
    return payload


@dataclasses.dataclass(frozen=True)
class CoreSnapshot:
    """One published converged fixpoint — everything a read can touch."""

    version: int              # monotone publication counter (1-based)
    core: np.ndarray          # read-only copy of the converged core vector
    n: int
    m: int                    # edge count at the fixpoint
    max_k: int
    asof: AsofView            # frozen as-of ring view at flip time
    batches_applied: int      # engine batch counter at flip time
    t_hi: float | None        # window head time (windowed mode only)
    published_at: float       # perf_counter at the flip

    def age_s(self) -> float:
        """Seconds since this fixpoint was published — the staleness any
        read answered from it carries."""
        return time.perf_counter() - self.published_at


class SnapshotBox:
    """Seqlock-style publication point for the front buffer.

    ``publish`` bumps the version to odd, swaps the snapshot reference,
    and bumps back to even; ``read`` retries while the counter is odd or
    moved mid-read. Under CPython the reference swap is itself atomic, so
    the retry loop effectively never spins — the protocol is kept explicit
    so the old-or-new-never-torn contract is enforced by construction,
    not by interpreter implementation detail.
    """

    def __init__(self):
        self._version = 0             # even = stable, odd = flip in progress
        self._snap: CoreSnapshot | None = None
        self._write_lock = threading.Lock()
        self.flips = 0

    def publish(self, snap: CoreSnapshot) -> None:
        with self._write_lock:
            self._version += 1        # odd: flip in progress
            self._snap = snap
            self._version += 1        # even: stable again
            self.flips += 1

    def read(self) -> CoreSnapshot:
        while True:
            v1 = self._version
            snap = self._snap
            if (v1 & 1) == 0 and self._version == v1 and snap is not None:
                return snap
            if snap is None and self._version == v1 and (v1 & 1) == 0:
                raise RuntimeError("no snapshot published yet")
            time.sleep(0)             # flip mid-publication; yield + retry


class ConcurrentKCoreServer:
    """Threaded snapshot-isolated front end over a ``KCoreServer``.

    Reads (``submit_read`` / ``read`` / ``serve_concurrent``) execute on a
    worker pool against the latest published ``CoreSnapshot``; writes
    (``update`` / ``advance_window``) run under the single-writer lock and
    flip a fresh snapshot when the engine has converged. ``drain`` is the
    graceful-shutdown path (optionally checkpointing for a warm restart).
    """

    def __init__(self, server: KCoreServer, read_workers: int = 4,
                 checkpoint_dir: str | None = None):
        if read_workers < 1:
            raise ValueError("read_workers must be >= 1")
        self.server = server
        self.checkpoint_dir = checkpoint_dir
        self.box = SnapshotBox()
        self._pool = ThreadPoolExecutor(max_workers=int(read_workers),
                                        thread_name_prefix="kcore-read")
        self._write_lock = threading.RLock()
        self._draining = threading.Event()
        m = server.metrics
        self._reads_total = m.counter("kcore_reads_total")
        self._reads_inflight = m.gauge("kcore_reads_inflight")
        self._snapshot_age = m.gauge("kcore_snapshot_age_seconds")
        self._flips_total = m.counter("kcore_snapshot_flips_total")
        self._version_gauge = m.gauge("kcore_snapshot_version")
        self._flip()                  # publish the initial fixpoint

    # ---------------- front buffer ------------------------------------- #
    @property
    def snapshot(self) -> CoreSnapshot:
        """The currently published fixpoint (what reads are seeing)."""
        return self.box.read()

    def snapshot_age_s(self) -> float:
        return self.box.read().age_s()

    def _flip(self) -> CoreSnapshot:
        """Publish the engine's converged state as the new front buffer.

        Called by the writer after every converged batch/advance (and once
        at construction). The core vector is copied and frozen — the back
        buffer keeps churning, the snapshot never moves.
        """
        srv = self.server
        version = self.box.flips + 1
        with _trace.span("snapshot.flip", version=version):
            core = np.array(srv.engine.core, np.int32)
            core.setflags(write=False)
            t_hi = (float(srv.windowed.t_bounds[1])
                    if srv.windowed is not None else None)
            snap = CoreSnapshot(
                version=version, core=core, n=srv.engine.n, m=srv.engine.m,
                max_k=int(core.max()) if core.size else 0,
                asof=srv.asof_ring.snapshot(),
                batches_applied=srv.engine.batches_applied, t_hi=t_hi,
                published_at=time.perf_counter())
            self.box.publish(snap)
        self._flips_total.inc()
        self._version_gauge.set(version)
        self._snapshot_age.set(0.0)
        rec = _flight.recorder()
        if rec.active:
            rec.note_event("snapshot_flip", version=version,
                           batch=snap.batches_applied, n=snap.n, m=snap.m,
                           max_k=snap.max_k)
        return snap

    # ---------------- writes (single writer) --------------------------- #
    def update(self, batch):
        """Apply a churn batch in the back buffer, then flip."""
        with self._write_lock:
            res = self.server.update(batch)
            self._flip()
            return res

    def advance_window(self, k: int = 1):
        """Advance the sliding window in the back buffer, then flip."""
        with self._write_lock:
            ws = self.server.advance_window(k)
            self._flip()
            return ws

    # ---------------- reads (worker pool) ------------------------------ #
    def submit_read(self, req: Request) -> Future:
        """Dispatch one read op to the pool; resolves to a Response."""
        if self._draining.is_set():
            raise RuntimeError("server is draining")
        return self._pool.submit(self._read, req)

    def read(self, req: Request) -> Response:
        """Execute one read op on the calling thread (same snapshot path
        as the pool — the HTTP front end already runs per-connection
        threads, so it reads inline instead of double-dispatching)."""
        return self._read(req)

    def serve_concurrent(self, requests: Iterable[Request]
                         ) -> list[Response]:
        """Submit a batch of reads and gather their responses in order."""
        futures = [self.submit_read(r) for r in requests]
        return [f.result() for f in futures]

    def _read(self, req: Request) -> Response:
        t0 = time.perf_counter()
        srv = self.server
        payload, error, version = None, None, None
        self._reads_inflight.inc()
        try:
            with _trace.span("serve.read", op=req.op):
                try:
                    if req.op not in READ_OPS:
                        raise ValueError(
                            f"op {req.op!r} is not a read — writes go "
                            "through the single writer (update / "
                            "advance_window)")
                    # validate BEFORE acquiring the snapshot: a malformed
                    # request must not touch serving state at all
                    v = srv.validate(req)
                    snap = self.box.read()
                    version = snap.version
                    self._snapshot_age.set(snap.age_s())
                    if req.op == "core":
                        payload = snap.core[v]
                    elif req.op == "in_kcore":
                        payload = snap.core[v] >= int(req.k)
                    elif req.op == "members":
                        payload = np.flatnonzero(snap.core >= int(req.k))
                    elif req.op == "max_k":
                        payload = snap.max_k
                    else:                         # core_asof
                        bt, core = snap.asof.asof(req.t)
                        payload = (bt, core if v is None else core[v])
                except (ValueError, IndexError, KeyError, TypeError) as exc:
                    # structured error instead of raising through the pool
                    error = str(exc)
                    op = req.op if req.op in srv.OPS else "unknown"
                    srv.metrics.counter("server_errors_total", op=op).inc()
        finally:
            self._reads_inflight.inc(-1.0)
        dt = time.perf_counter() - t0
        self._reads_total.inc()
        if error is None:
            srv.metrics.counter("server_requests_total", op=req.op).inc()
            srv.metrics.histogram("server_request_seconds",
                                  op=req.op).observe(dt)
        return Response(op=req.op, payload=payload, wall_s=dt, error=error,
                        version=version)

    def handle_query(self, op: str, vertices=None, k=None, t=None) -> dict:
        """JSON-safe adapter for HTTP front ends (obs/http.py).

        Builds the Request, reads inline on the calling thread (the HTTP
        server is already one-thread-per-connection), and serializes the
        payload to plain JSON types. Kept here so the obs layer never has
        to import streaming — it just calls whatever backend is attached.
        """
        if self._draining.is_set():
            return {"op": op, "ok": False, "error": "server is draining"}
        resp = self._read(Request(op=op, vertices=vertices, k=k, t=t))
        out = {"op": resp.op, "ok": resp.ok, "wall_s": resp.wall_s,
               "version": resp.version}
        if resp.error is not None:
            out["error"] = resp.error
        else:
            out["payload"] = _json_payload(resp.payload)
        return out

    # ---------------- shutdown / warm restart -------------------------- #
    @property
    def draining(self) -> bool:
        return self._draining.is_set()

    def drain(self, save: bool = True, step: int | None = None
              ) -> str | None:
        """Graceful shutdown: refuse new reads, drain in-flight ones, wait
        for the writer to finish its batch, then (optionally) checkpoint.

        Returns the committed checkpoint path (None when not saving).
        Idempotent — the SIGTERM handler and a normal exit can both call
        it. The checkpoint is written through ``repro.checkpoint``'s
        atomic-rename commit, so a kill mid-save leaves the previous
        complete step loadable.
        """
        self._draining.set()
        self._pool.shutdown(wait=True)
        with self._write_lock:        # writer finished its current batch
            if not (save and self.checkpoint_dir):
                return None
            from repro.checkpoint import save_checkpoint
            if step is None:
                step = self.server.updates_applied
            path = save_checkpoint(self.checkpoint_dir, int(step),
                                   self.server.state_dict())
            rec = _flight.recorder()
            if rec.active:
                rec.note_event("checkpoint_save", step=int(step), path=path)
            return path

    def stats(self) -> dict:
        """Server stats plus the concurrency counters."""
        snap = self.box.read()
        out = self.server.stats()
        out.update({
            "snapshot_version": snap.version,
            "snapshot_flips": self.box.flips,
            "snapshot_age_s": snap.age_s(),
            "reads_total": int(self._reads_total.value),
            "reads_inflight": int(self._reads_inflight.value),
        })
        return out
