"""Mutable graph delta layer: batched edge insert/delete on the COO/CSR Graph.

Two ways to apply a churn batch, with identical dataCleanse semantics:

  * ``apply_batch`` — rebuild: produces a *new* immutable Graph by one
    O(m log m) lexsort over the surviving edge set. Simple, and the
    reference the patch path is property-tested against.
  * ``PatchableCSR`` — in-place: slack-padded CSR storage where each row
    carries spare slots, so a batch patches arc slots in O(batch * deg)
    instead of touching all m edges. Rows that overflow their slack, vertex
    growth, or a dead-slot fraction past ``compact_dead_frac`` trigger an
    O(m) compaction (amortized away over a stream). The padded slot arrays
    double as the engine's masked-superstep inputs — dead slots are just
    masked arcs, so no densification happens between batches.

The dataCleanse rules applied to the batch itself (same as Graph.from_edges):

  * self-loops in the batch are dropped;
  * edges are undirected — (u, v) and (v, u) are the same edge, canonical
    form is (min, max);
  * inserting an edge that already exists is a no-op, as is deleting one
    that doesn't; duplicates within the batch collapse.

Deletes are applied before inserts, so a batch that deletes and inserts the
same edge nets out to "edge present".
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structs import Graph


@dataclasses.dataclass(frozen=True)
class EdgeBatch:
    """One churn batch: arrays of (u, v) pairs to delete and insert."""

    insert: np.ndarray        # (Bi, 2) int64 — may be empty
    delete: np.ndarray        # (Bd, 2) int64 — may be empty

    @classmethod
    def make(cls, insert=None, delete=None) -> "EdgeBatch":
        def arr(x):
            if x is None:
                return np.zeros((0, 2), np.int64)
            return np.asarray(x, np.int64).reshape(-1, 2)
        return cls(insert=arr(insert), delete=arr(delete))

    @property
    def size(self) -> int:
        return int(self.insert.shape[0] + self.delete.shape[0])


@dataclasses.dataclass(frozen=True)
class DeltaResult:
    """Outcome of applying an EdgeBatch."""

    graph: Graph              # the post-batch graph
    inserted: np.ndarray      # (bi, 2) canonical edges actually added
    deleted: np.ndarray       # (bd, 2) canonical edges actually removed
    touched: np.ndarray       # sorted unique vertex ids incident to a change


def canonical_edges(g: Graph) -> np.ndarray:
    """The (m, 2) canonical (min < max) edge list of a Graph."""
    half = g.src < g.dst
    return np.stack([g.src[half].astype(np.int64),
                     g.dst[half].astype(np.int64)], axis=1)


def _canonicalize(pairs: np.ndarray) -> np.ndarray:
    """dataCleanse a raw (B, 2) pair list: drop self-loops, canonical order,
    dedupe."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    pairs = pairs[pairs[:, 0] != pairs[:, 1]]
    if pairs.size == 0:
        return pairs.reshape(0, 2)
    canon = np.stack([pairs.min(axis=1), pairs.max(axis=1)], axis=1)
    return np.unique(canon, axis=0)


def edge_keys(edges: np.ndarray, n: int) -> np.ndarray:
    """Encode canonical edges as scalar keys u * n + v for set algebra.

    The one canonical key scheme for edge-set membership/diff across the
    streaming and temporal layers (temporal/window.py uses it for window
    deltas; temporal/events.py applies the same encoding columnwise)."""
    return edges[:, 0] * np.int64(n) + edges[:, 1]


_keys = edge_keys          # internal alias, predates the public name


def apply_batch(g: Graph, batch: EdgeBatch) -> DeltaResult:
    """Apply a churn batch; returns the new Graph and the effective delta.

    Vertex ids beyond g.n in the batch grow the vertex set (the new graph
    has n = max(g.n, 1 + max id referenced)); deletes referencing unknown
    vertices are no-ops.
    """
    ins = _canonicalize(batch.insert)
    dele = _canonicalize(batch.delete)
    if (ins.size and ins.min() < 0) or (dele.size and dele.min() < 0):
        raise ValueError("negative vertex id in churn batch")
    n = max(g.n, int(ins.max()) + 1 if ins.size else 0)
    # key base must cover delete ids too (deleting an unknown vertex is a
    # no-op, but its key must not alias a real edge's key)
    base = max(n, int(dele.max()) + 1 if dele.size else 0)

    edges = canonical_edges(g)
    keys = _keys(edges, base)

    # deletes first
    if dele.size:
        dk = _keys(dele, base)
        hit = np.isin(keys, dk)
        deleted = edges[hit]
        edges, keys = edges[~hit], keys[~hit]
    else:
        deleted = np.zeros((0, 2), np.int64)

    # then inserts (drop ones already present)
    if ins.size:
        fresh = ~np.isin(_keys(ins, base), keys)
        inserted = ins[fresh]
        edges = np.concatenate([edges, inserted])
    else:
        inserted = np.zeros((0, 2), np.int64)

    new_g = Graph.from_edges(edges, n=n)
    touched = np.unique(np.concatenate([inserted.reshape(-1),
                                        deleted.reshape(-1)]))
    return DeltaResult(graph=new_g, inserted=inserted, deleted=deleted,
                       touched=touched.astype(np.int64))


# ---------------------------------------------------------------------- #
# In-place CSR patching
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ChurnDelta:
    """What a patched batch actually changed (no materialized Graph)."""

    inserted: np.ndarray      # (bi, 2) canonical edges actually added
    deleted: np.ndarray       # (bd, 2) canonical edges actually removed
    touched: np.ndarray       # sorted unique vertex ids incident to a change
    compacted: bool           # did this batch trigger an O(m) compaction?


class PatchableCSR:
    """Slack-padded CSR adjacency supporting in-place edge churn.

    Storage: every vertex u owns a contiguous slot range
    ``[row_off[u], row_off[u+1])`` in flat ``src``/``dst`` arrays;
    ``live`` marks which slots currently hold an arc. ``src`` is constant
    per row (the owner), so the slot arrays are src-sorted by construction
    — exactly the sorted-COO-with-mask layout the masked superstep and the
    sharded partitioner consume, without any per-batch sort.

    Capacity per row is ``deg + max(ceil(slack * deg), min_slack)`` at
    (re)build time. An insert lands in a free slot of each endpoint's row;
    a delete just clears ``live``. Compaction (rebuild with fresh slack)
    triggers on row overflow, vertex growth, or when the dead-slot fraction
    of the total capacity exceeds ``compact_dead_frac``.
    """

    def __init__(self, g: Graph, slack: float = 0.3, min_slack: int = 4,
                 compact_dead_frac: float = 0.25):
        self.slack = float(slack)
        # >= 1 so a compaction always frees at least one slot per row (the
        # overflow-retry in apply_batch relies on it)
        self.min_slack = max(int(min_slack), 1)
        self.compact_dead_frac = float(compact_dead_frac)
        self.compactions = 0
        self._alloc(g.n, g.src, g.dst, g.deg)

    # ------------------------------------------------------------------ #
    def _alloc(self, n: int, src: np.ndarray, dst: np.ndarray,
               deg: np.ndarray, reserve: np.ndarray | None = None) -> None:
        """(Re)build storage from src-sorted live arcs with fresh slack.

        ``reserve`` (n,) adds per-row slots on top of the slack — the
        batch-aware compaction passes the incoming insert counts so one
        rebuild is guaranteed to fit the whole batch."""
        deg = np.asarray(deg, np.int64)
        pad = np.maximum(np.ceil(self.slack * deg).astype(np.int64),
                         self.min_slack)
        cap = deg + pad
        if reserve is not None:
            cap = cap + np.asarray(reserve, np.int64)
        self.n = int(n)
        self.row_off = np.zeros(n + 1, np.int64)
        np.cumsum(cap, out=self.row_off[1:])
        C = int(self.row_off[-1])
        self.src = np.repeat(np.arange(n, dtype=np.int32),
                             cap).astype(np.int32, copy=False)
        self.dst = self.src.copy()      # dead slots point at their owner
        self.live = np.zeros(C, bool)
        # scatter the existing arcs to the head of each row
        if src.size:
            arc_slot = (self.row_off[src]
                        + (np.arange(src.size) - np.cumsum(deg)[src]
                           + deg[src])).astype(np.int64)
            self.dst[arc_slot] = dst
            self.live[arc_slot] = True
        self.deg = deg.astype(np.int32).copy()
        self.m = int(deg.sum()) // 2
        # holes = slots that were live and got deleted (NOT virgin slack):
        # the fragmentation measure driving compact_dead_frac
        self.hole = np.zeros(C, bool)
        self.dead = 0

    @property
    def capacity(self) -> int:
        return int(self.row_off[-1])

    # ------------------------------------------------------------------ #
    def _row(self, u: int) -> slice:
        return slice(int(self.row_off[u]), int(self.row_off[u + 1]))

    def _find_slot(self, u: int, v: int) -> int:
        """Slot index of live arc u->v, or -1."""
        r = self._row(u)
        hit = np.flatnonzero(self.live[r] & (self.dst[r] == v))
        return int(r.start + hit[0]) if hit.size else -1

    def _free_slot(self, u: int) -> int:
        """A dead slot in u's row, or -1 if the row is full."""
        r = self._row(u)
        free = np.flatnonzero(~self.live[r])
        return int(r.start + free[0]) if free.size else -1

    def has_edge(self, u: int, v: int) -> bool:
        return self._find_slot(u, v) >= 0

    # ------------------------------------------------------------------ #
    def _compact(self, n: int | None = None,
                 reserve: np.ndarray | None = None) -> None:
        """Rebuild with fresh slack (and optionally a grown vertex set
        and/or per-row reserved slots for an incoming batch)."""
        n = self.n if n is None else int(n)
        keep = self.live
        src = self.src[keep].astype(np.int64)
        dst = self.dst[keep].astype(np.int64)
        # rows stay contiguous under filtering, so src stays sorted
        deg = np.bincount(src, minlength=n)
        self._alloc(n, src.astype(np.int32), dst.astype(np.int32), deg,
                    reserve=reserve)
        self.compactions += 1

    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: EdgeBatch) -> ChurnDelta:
        """Patch a churn batch in place; returns the effective delta.

        Semantics are identical to the rebuild path ``apply_batch(g, b)``:
        deletes first, then inserts; no-ops dropped; vertex ids beyond n in
        the inserts grow the vertex set.
        """
        ins = _canonicalize(batch.insert)
        dele = _canonicalize(batch.delete)
        if (ins.size and ins.min() < 0) or (dele.size and dele.min() < 0):
            raise ValueError("negative vertex id in churn batch")
        compacted = False
        new_n = max(self.n, int(ins.max()) + 1 if ins.size else 0)
        if new_n > self.n:
            self._compact(new_n)
            compacted = True

        deleted = []
        for u, v in dele.tolist():
            if v >= self.n:             # unknown vertex: no-op
                continue
            s_uv = self._find_slot(u, v)
            if s_uv < 0:
                continue
            s_vu = self._find_slot(v, u)
            self.live[s_uv] = False
            self.live[s_vu] = False
            self.hole[s_uv] = True
            self.hole[s_vu] = True
            self.deg[u] -= 1
            self.deg[v] -= 1
            self.m -= 1
            self.dead += 2
            deleted.append((u, v))

        # batch-aware growth policy: if ANY row lacks free slots for its
        # incoming inserts, compact ONCE with the batch's per-row need
        # reserved, instead of compacting per overflowing insert (a windowed
        # replay at full scale was thrashing ~90 O(m) compactions per batch
        # through the hub rows). need over-counts already-present edges —
        # over-reserving is just slack, never a correctness issue.
        if ins.size:
            need = np.bincount(ins.reshape(-1), minlength=self.n)
            row_cap = np.diff(self.row_off)
            free = row_cap - np.bincount(self.src[self.live],
                                         minlength=self.n)
            if (need > free).any():
                self._compact(reserve=need)
                compacted = True

        inserted = []
        for u, v in ins.tolist():
            if self.has_edge(u, v):     # already present: no-op
                continue
            s_uv = self._free_slot(u)
            s_vu = self._free_slot(v)
            if s_uv < 0 or s_vu < 0:    # row overflow: compact, then retry
                self._compact()
                compacted = True
                s_uv = self._free_slot(u)
                s_vu = self._free_slot(v)
            self.dst[s_uv] = v
            self.dst[s_vu] = u
            self.live[s_uv] = True
            self.live[s_vu] = True
            for s in (s_uv, s_vu):
                if self.hole[s]:        # refilled a real hole, not slack
                    self.hole[s] = False
                    self.dead -= 1
            self.deg[u] += 1
            self.deg[v] += 1
            self.m += 1
            inserted.append((u, v))

        if self.dead > self.compact_dead_frac * max(self.capacity, 1):
            self._compact()
            compacted = True

        def arr(pairs):
            return (np.asarray(pairs, np.int64).reshape(-1, 2) if pairs
                    else np.zeros((0, 2), np.int64))

        ins_a, del_a = arr(inserted), arr(deleted)
        touched = np.unique(np.concatenate([ins_a.reshape(-1),
                                            del_a.reshape(-1)]))
        return ChurnDelta(inserted=ins_a, deleted=del_a,
                          touched=touched.astype(np.int64),
                          compacted=compacted)

    # ------------------------------------------------------------------ #
    def state_dict(self) -> dict:
        """Checkpointable array pytree of the full slot state.

        Everything mutable is captured (slot arrays, degrees, hole/dead
        fragmentation bookkeeping, compaction count) so a restored CSR is
        bit-identical — same capacities, same slot order, same compaction
        trigger point — not merely the same graph.
        """
        return {
            "row_off": self.row_off,
            "src": self.src,
            "dst": self.dst,
            "live": self.live,
            "hole": self.hole,
            "deg": self.deg,
            "dead": np.asarray(self.dead, np.int64),
            "compactions": np.asarray(self.compactions, np.int64),
        }

    @classmethod
    def from_state(cls, state: dict, *, slack: float = 0.3,
                   min_slack: int = 4,
                   compact_dead_frac: float = 0.25) -> "PatchableCSR":
        """Rebuild from ``state_dict`` output without touching a Graph.

        The churn knobs are config, not state — pass the engine's (they
        only affect FUTURE compactions).
        """
        csr = cls.__new__(cls)
        csr.slack = float(slack)
        csr.min_slack = max(int(min_slack), 1)
        csr.compact_dead_frac = float(compact_dead_frac)
        # own, writable copies: the CSR mutates these in place, and restored
        # checkpoint leaves can arrive as read-only (mmap/device) buffers
        csr.row_off = np.array(state["row_off"], np.int64)
        csr.n = int(csr.row_off.shape[0]) - 1
        csr.src = np.array(state["src"], np.int32)
        csr.dst = np.array(state["dst"], np.int32)
        csr.live = np.array(state["live"], bool)
        csr.hole = np.array(state["hole"], bool)
        csr.deg = np.array(state["deg"], np.int32)
        csr.m = int(csr.deg.sum()) // 2
        csr.dead = int(state["dead"])
        csr.compactions = int(state["compactions"])
        return csr

    def to_graph(self) -> Graph:
        """Materialize the exact immutable Graph (sorted COO) — O(m log m).

        Verification/interop only; the engine's hot path consumes the slot
        arrays directly.
        """
        src = self.src[self.live]
        dst = self.dst[self.live]
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        offsets = np.zeros(self.n + 1, np.int64)
        np.cumsum(self.deg, out=offsets[1:])
        return Graph(n=self.n, m=self.m, src=src, dst=dst,
                     offsets=offsets, deg=self.deg.copy())


def random_churn_batch(g: Graph, n_insert: int, n_delete: int,
                       rng: np.random.Generator) -> EdgeBatch:
    """Sample a churn batch: ``n_delete`` existing edges chosen uniformly
    without replacement, and ``n_insert`` uniform non-loop pairs (mostly new
    edges; collisions with existing ones are legal no-op inserts)."""
    edges = canonical_edges(g)
    n_delete = min(n_delete, edges.shape[0])
    if n_delete:
        sel = rng.choice(edges.shape[0], size=n_delete, replace=False)
        delete = edges[sel]
    else:
        delete = np.zeros((0, 2), np.int64)
    if n_insert and g.n >= 2:
        insert = rng.integers(0, g.n, size=(n_insert, 2), dtype=np.int64)
        insert = insert[insert[:, 0] != insert[:, 1]]
    else:
        insert = np.zeros((0, 2), np.int64)
    return EdgeBatch.make(insert=insert, delete=delete)
