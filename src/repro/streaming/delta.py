"""Mutable graph delta layer: batched edge insert/delete on the COO/CSR Graph.

The static Graph is immutable (frozen dataclass); a churn batch produces a
*new* Graph plus a precise report of what actually changed. The same
dataCleanse rules as Graph.from_edges apply to the batch itself:

  * self-loops in the batch are dropped;
  * edges are undirected — (u, v) and (v, u) are the same edge, canonical
    form is (min, max);
  * inserting an edge that already exists is a no-op, as is deleting one
    that doesn't; duplicates within the batch collapse.

Deletes are applied before inserts, so a batch that deletes and inserts the
same edge nets out to "edge present".

Rebuild cost is O(m log m) per batch (one lexsort over the surviving edge
set) — at the scales this repo benchmarks the host-side rebuild is noise
next to the message bill the engine is measuring; a fully in-place CSR
patch is an open item in ROADMAP.md.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structs import Graph


@dataclasses.dataclass(frozen=True)
class EdgeBatch:
    """One churn batch: arrays of (u, v) pairs to delete and insert."""

    insert: np.ndarray        # (Bi, 2) int64 — may be empty
    delete: np.ndarray        # (Bd, 2) int64 — may be empty

    @classmethod
    def make(cls, insert=None, delete=None) -> "EdgeBatch":
        def arr(x):
            if x is None:
                return np.zeros((0, 2), np.int64)
            return np.asarray(x, np.int64).reshape(-1, 2)
        return cls(insert=arr(insert), delete=arr(delete))

    @property
    def size(self) -> int:
        return int(self.insert.shape[0] + self.delete.shape[0])


@dataclasses.dataclass(frozen=True)
class DeltaResult:
    """Outcome of applying an EdgeBatch."""

    graph: Graph              # the post-batch graph
    inserted: np.ndarray      # (bi, 2) canonical edges actually added
    deleted: np.ndarray       # (bd, 2) canonical edges actually removed
    touched: np.ndarray       # sorted unique vertex ids incident to a change


def canonical_edges(g: Graph) -> np.ndarray:
    """The (m, 2) canonical (min < max) edge list of a Graph."""
    half = g.src < g.dst
    return np.stack([g.src[half].astype(np.int64),
                     g.dst[half].astype(np.int64)], axis=1)


def _canonicalize(pairs: np.ndarray) -> np.ndarray:
    """dataCleanse a raw (B, 2) pair list: drop self-loops, canonical order,
    dedupe."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    pairs = pairs[pairs[:, 0] != pairs[:, 1]]
    if pairs.size == 0:
        return pairs.reshape(0, 2)
    canon = np.stack([pairs.min(axis=1), pairs.max(axis=1)], axis=1)
    return np.unique(canon, axis=0)


def _keys(edges: np.ndarray, n: int) -> np.ndarray:
    """Encode canonical edges as scalar keys u * n + v for set algebra."""
    return edges[:, 0] * np.int64(n) + edges[:, 1]


def apply_batch(g: Graph, batch: EdgeBatch) -> DeltaResult:
    """Apply a churn batch; returns the new Graph and the effective delta.

    Vertex ids beyond g.n in the batch grow the vertex set (the new graph
    has n = max(g.n, 1 + max id referenced)); deletes referencing unknown
    vertices are no-ops.
    """
    ins = _canonicalize(batch.insert)
    dele = _canonicalize(batch.delete)
    if (ins.size and ins.min() < 0) or (dele.size and dele.min() < 0):
        raise ValueError("negative vertex id in churn batch")
    n = max(g.n, int(ins.max()) + 1 if ins.size else 0)
    # key base must cover delete ids too (deleting an unknown vertex is a
    # no-op, but its key must not alias a real edge's key)
    base = max(n, int(dele.max()) + 1 if dele.size else 0)

    edges = canonical_edges(g)
    keys = _keys(edges, base)

    # deletes first
    if dele.size:
        dk = _keys(dele, base)
        hit = np.isin(keys, dk)
        deleted = edges[hit]
        edges, keys = edges[~hit], keys[~hit]
    else:
        deleted = np.zeros((0, 2), np.int64)

    # then inserts (drop ones already present)
    if ins.size:
        fresh = ~np.isin(_keys(ins, base), keys)
        inserted = ins[fresh]
        edges = np.concatenate([edges, inserted])
    else:
        inserted = np.zeros((0, 2), np.int64)

    new_g = Graph.from_edges(edges, n=n)
    touched = np.unique(np.concatenate([inserted.reshape(-1),
                                        deleted.reshape(-1)]))
    return DeltaResult(graph=new_g, inserted=inserted, deleted=deleted,
                       touched=touched.astype(np.int64))


def random_churn_batch(g: Graph, n_insert: int, n_delete: int,
                       rng: np.random.Generator) -> EdgeBatch:
    """Sample a churn batch: ``n_delete`` existing edges chosen uniformly
    without replacement, and ``n_insert`` uniform non-loop pairs (mostly new
    edges; collisions with existing ones are legal no-op inserts)."""
    edges = canonical_edges(g)
    n_delete = min(n_delete, edges.shape[0])
    if n_delete:
        sel = rng.choice(edges.shape[0], size=n_delete, replace=False)
        delete = edges[sel]
    else:
        delete = np.zeros((0, 2), np.int64)
    if n_insert and g.n >= 2:
        insert = rng.integers(0, g.n, size=(n_insert, 2), dtype=np.int64)
        insert = insert[insert[:, 0] != insert[:, 1]]
    else:
        insert = np.zeros((0, 2), np.int64)
    return EdgeBatch.make(insert=insert, delete=delete)
