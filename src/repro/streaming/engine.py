"""Warm-started incremental k-core engine.

Correctness rests on the locality theorem the static engine is built on
(core/kcore.py, paper §II.B): iterating est'(u) = H({min(est(v), est(u))})
converges to the exact core numbers from ANY per-vertex seed that upper
bounds them. So after a churn batch the engine only has to produce a sound
upper-bound seed — then frontier-localized supersteps re-converge exactly.

Seeding rules (all sound, proofs in the docstrings below):

  * a vertex whose core number cannot have increased keeps
    ``min(old_core, new_deg)`` — deletions only lower cores, and the old
    fixpoint is an upper bound of the new one outside the insertion region;
  * vertices that MAY have increased — the insertion region R — are re-seeded
    from a tight upper-bound vector computed by a batch generalization of
    the single-edge subcore theorem: +1 passes over level-set components
    anchored at inserted edges, pruned by a support peel
    (see ``_insertion_upper_bound``). The passes run as ONE jitted device
    program (``_ub_converge``), so seed cost is a single dispatch;
  * a per-batch COST MODEL (``repro.core.cost_model.choose_seed``) picks
    between the tight bound and a plain degree seed (sound by definition:
    deg >= core): estimated +1 passes x per-pass cost vs the extra fused
    rounds a degree seed costs. Bulk loads whose cores rise by many levels
    (a window filling from empty) seed from degrees; mid-churn batches
    whose cores barely move keep the low-message tight bound even when
    their insert fraction is large — the wall cliff of the old 25%-churn
    step function without giving up the message story.

The graph itself lives in a slack-padded in-place CSR (streaming/delta.py
``PatchableCSR``): a batch patches arc slots instead of rebuilding the
sorted COO, and the slot arrays feed the supersteps directly (dead slots
are masked arcs).

Message accounting mirrors core/messages.py: round 0 of a batch charges
deg(u) for every vertex whose seed differs from its previously broadcast
value (it must re-announce), plus 2 messages per inserted/deleted edge (the
link handshake/teardown); every later round charges deg(u) per vertex whose
estimate decreased. This makes "messages per batch" directly comparable to
the from-scratch total the paper reports.

Four frontier execution modes (plus ``auto``, which picks per batch):

  * ``dense``   — full-width jitted masked superstep (core.masked_round_segment):
    one XLA program for the whole stream, frontier as a boolean mask;
  * ``compact`` — per-round extraction of the active subgraph, padded to
    powers of two so jit recompiles only O(log n) distinct shapes; work per
    round is proportional to the frontier, not the graph;
  * ``sharded`` — the masked superstep runs as a shard_map over a device
    mesh (core.make_sharded_superstep(..., masked=True)): vertex state
    sharded by contiguous range, one est all_gather plus one 1-bit changed
    all_gather per round. The in-place CSR's slot arrays are already
    src-sorted, so sharding a churned graph needs no sort.
  * ``fused``   — the ENTIRE batch re-convergence runs as one device-resident
    ``lax.while_loop`` (core.fused_convergence): no per-round host
    round-trips; the host gets back only the final estimate plus per-round
    stat buffers from which exact MessageStats are reconstructed. With a
    mesh attached the while_loop nests the masked shard_map superstep
    (``fused_sharded``). All fused-program shapes are high-water-marked
    (CSR capacity, shard arc blocks, h-index search depth) so a whole
    windowed replay compiles O(log) distinct jit signatures — measured,
    not asserted, via repro.core.jit_telemetry (``BatchResult.recompiles``).

All modes produce identical estimates and identical message counts.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dispatch as _dispatch
from repro.core.cost_model import SeedCostModel, choose_seed
from repro.core.jit_telemetry import compile_count, compile_seconds
from repro.core.kcore import (KCoreConfig, _bs_iters, _hindex_by_bsearch,
                              _receivers_arrays, kcore_decompose,
                              kcore_decompose_sharded,
                              make_sharded_superstep, masked_round_segment)
from repro.core.messages import MessageStats
from repro.core.runtime import fused_converge_dense, fused_converge_sharded
from repro.graph.padding import next_pow2 as _next_pow2
from repro.graph.padding import round_up as _round_up
from repro.graph.structs import Graph
from repro.obs import flight as _flight
from repro.obs import trace as _trace
from repro.streaming.delta import ChurnDelta, DeltaResult, EdgeBatch, \
    PatchableCSR

FRONTIER_MODES = ("dense", "compact", "sharded", "fused", "auto")


# ---------------------------------------------------------------------- #
# Config / result
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class StreamingConfig:
    frontier: str = "dense"          # one of FRONTIER_MODES
    max_rounds: int | None = None    # None -> n + 1 per batch (worst case)
    # "auto" picks compact below this initial-frontier fraction, else
    # fused (the sharded-fused variant when a mesh is attached)
    compact_threshold: float = 0.02
    # in-place CSR knobs (see delta.PatchableCSR)
    slack: float = 0.3
    min_slack: int = 4
    compact_dead_frac: float = 0.25
    # pre-seeds the padded live-arc shape (engine._padded_slots) so a
    # stream that grows into a known load doesn't walk its jitted programs
    # through every pow2 size on the way up (the windowed engine sets it
    # from the expected window size); 0 = grow organically
    min_arc_capacity: int = 0
    # per-batch seeding policy (repro.core.cost_model.choose_seed): the
    # tight subcore upper bound costs one +1 device pass per unit of core
    # raise — unbounded for bulk loads (a filling window raises cores by
    # tens) — while a plain degree seed (always sound: deg >= core) costs
    # extra fused re-convergence rounds instead. The model compares the
    # two in units of fused rounds and picks per batch; for small churn
    # (the streaming benchmark's 0.2-2%) the tight bound always wins, so
    # the incremental message story is unchanged. All frontier modes share
    # the seed, so cross-mode bill equality is unaffected either way.
    seed_model: SeedCostModel = SeedCostModel()


@dataclasses.dataclass
class BatchResult:
    """Outcome of one incremental batch."""

    core: np.ndarray          # exact core numbers after the batch
    rounds: int               # supersteps to re-converge (excl. seed round)
    converged: bool
    stats: MessageStats       # per-round accounting; [0] = seed broadcast
    delta: ChurnDelta         # what the batch actually changed
    region_size: int          # |R| — insertion region that was re-seeded up
    seed_changed: int         # vertices that had to rebroadcast at seed time
    mode: str = "dense"       # execution mode this batch actually ran in
    # per-phase walls, always measured (two perf_counter reads per phase —
    # nanoseconds against phases that run for milliseconds); the same
    # boundaries the trace spans mark, so a benchmark row gets the
    # patch/seed/converge/reconstruct breakdown without tracing enabled
    patch_s: float = 0.0      # host seconds spent patching the CSR in place
    seed_s: float = 0.0       # warm-start seed + initial frontier
    converge_s: float = 0.0   # re-convergence (device dispatch + rounds)
    reconstruct_s: float = 0.0  # host-side stats assembly
    # warm-start seeding decision (repro.core.cost_model.choose_seed):
    # "tight" = subcore upper bound, "degree" = plain degree seed, and the
    # pass-count estimate the cost model based the choice on
    seed_strategy: str = "tight"
    seed_est_passes: int = 0
    # fresh XLA compilations this batch caused (process-wide; 0 = every
    # jitted program was a cache hit — the shape-stability signal), and the
    # wall XLA spent on them (jit_telemetry.compile_seconds delta)
    recompiles: int = 0
    compile_s: float = 0.0
    # (whether the batch forced an O(m) CSR compaction: delta.compacted)
    # PatchableCSR health after the batch — long churn streams live or die
    # by compaction behavior, so it is first-class, not property-test-only:
    csr_compactions: int = 0  # cumulative O(m) compactions so far
    csr_dead_frac: float = 0.0   # hole slots / capacity (fragmentation)
    csr_occupancy: float = 0.0   # live arc slots / capacity (slack usage)

    @property
    def total_messages(self) -> int:
        return self.stats.total_messages


# ---------------------------------------------------------------------- #
# Warm-start seeding
# ---------------------------------------------------------------------- #

def _ub_pass_body(U, cap, src, dst, live, ins_u, ins_v, ins_live, n):
    """One vectorized +1 pass of the insertion upper bound (see below).

    All device-side segment ops; dead/padding arc slots carry live=False.
    Returns (U', raised_any).

      1. bottleneck propagation: T(x) = max over paths from x to an
         inserted-edge endpoint of min(k_e, min U over the path) — the
         fixpoint of T(x) = max(A(x), max_{y~x} min(U(y), T(y))) where A is
         the best incident inserted-edge level. T(x) >= U(x) iff x's
         component in the level set G_{>=U(x)} contains a qualifying
         insertion (the union-find condition, as a max-min path problem);
      2. candidates: T(x) >= U(x) and deg(x) > U(x);
      3. synchronous support peel to the greatest fixpoint: survivors keep
         > U(x) neighbors that are themselves survivors at the same level
         or sit strictly above it. (Peeling order never changes the
         greatest fixpoint, so the parallel peel equals the sequential
         stack peel of the reference implementation.)
    """
    k_ins = jnp.where(ins_live, jnp.minimum(U[ins_u], U[ins_v]),
                      jnp.int32(-1))
    A = jnp.full(n, -1, jnp.int32).at[ins_u].max(k_ins).at[ins_v].max(k_ins)

    def prop_body(state):
        T, _ = state
        val = jnp.where(live, jnp.minimum(U[dst], T[dst]), jnp.int32(-1))
        T2 = jnp.maximum(T, jax.ops.segment_max(val, src, num_segments=n))
        return T2, (T2 > T).any()

    T, _ = lax.while_loop(lambda s: s[1], prop_body, (A, jnp.bool_(True)))

    cand0 = (T >= U) & (cap > U)

    def peel_body(state):
        c, _ = state
        qual = live & ((U[dst] > U[src]) | (c[dst] & (U[dst] == U[src])))
        s = jax.ops.segment_sum(qual.astype(jnp.int32), src, num_segments=n)
        c2 = c & (s > U)
        return c2, (c2 != c).any()

    cand, _ = lax.while_loop(lambda s: s[1], peel_body,
                             (cand0, jnp.bool_(True)))
    return jnp.where(cand, U + 1, U), cand.any()


@functools.partial(jax.jit, static_argnames=("n",))
def _ub_pass(U, cap, src, dst, live, ins_u, ins_v, ins_live, n):
    """One jitted +1 pass (kept as the single-pass entry point; the engine
    hot path runs ``_ub_converge`` instead)."""
    return _ub_pass_body(U, cap, src, dst, live, ins_u, ins_v, ins_live, n)


@functools.partial(jax.jit, static_argnames=("n",))
def _ub_converge(U, cap, src, dst, live, ins_u, ins_v, ins_live, n):
    """ALL +1 passes of the insertion upper bound in one device program.

    The pass loop used to live on host — one jitted ``_ub_pass`` dispatch
    plus a blocking ``raised`` sync per pass, ~20 passes per heavy batch.
    Fusing it into an outer ``lax.while_loop`` makes the whole seed
    computation a single dispatch with no host round-trips; each pass is
    the identical ``_ub_pass_body``, so the resulting U is unchanged
    (property-tested against the union-find reference)."""
    def pass_body(state):
        U, _ = state
        return _ub_pass_body(U, cap, src, dst, live, ins_u, ins_v,
                             ins_live, n)

    U, _ = lax.while_loop(lambda s: s[1], pass_body, (U, jnp.bool_(True)))
    return U


def _insertion_upper_bound_arrays(n: int, src, dst, live, deg,
                                  old_core_ext: np.ndarray,
                                  inserted: np.ndarray) -> np.ndarray:
    """Vectorized insertion upper bound over raw (masked) arc arrays.

    ``src``/``dst``/``live`` may be numpy or already-device arrays (the
    engine passes its padded CSR slot arrays); shapes should be stable
    across batches (pow2-padded) so the jitted pass compiles O(log) times.
    """
    U = old_core_ext.astype(np.int64).copy()
    if inserted.size == 0 or n == 0:
        return U
    ins_pad = _next_pow2(max(inserted.shape[0], 1))
    ins_u = np.zeros(ins_pad, np.int32)
    ins_v = np.zeros(ins_pad, np.int32)
    ins_live = np.zeros(ins_pad, bool)
    ins_u[: inserted.shape[0]] = inserted[:, 0]
    ins_v[: inserted.shape[0]] = inserted[:, 1]
    ins_live[: inserted.shape[0]] = True

    U_j = jnp.asarray(U, jnp.int32)
    cap_j = jnp.asarray(deg, jnp.int32)
    src_j, dst_j = jnp.asarray(src), jnp.asarray(dst)
    live_j = jnp.asarray(live)
    iu, iv, il = jnp.asarray(ins_u), jnp.asarray(ins_v), jnp.asarray(ins_live)
    U_j = _ub_converge(U_j, cap_j, src_j, dst_j, live_j, iu, iv, il, n)
    return np.asarray(U_j).astype(np.int64)


def _insertion_upper_bound(new_g: Graph, old_core_ext: np.ndarray,
                           inserted: np.ndarray) -> np.ndarray:
    """Pointwise upper bound U >= new core numbers, tight around insertions.

    Batch generalization of the classic single-edge subcore theorem
    (Sariyuce et al., "Streaming algorithms for k-core decomposition"):
    inserting ONE edge (u, v) into a graph with exact cores c raises core
    numbers by at most 1, and only for vertices x with c(x) = k =
    min(c(u), c(v)) reachable from an endpoint through vertices of core k.

    We iterate +1 "passes" over an evolving bound vector U (initialized to
    the pre-batch exact cores, so U >= cores holds at the start):

      pass: a vertex x is RAISED by 1 iff
        (a) its component in the level set G_{>=U(x)} = {y : U(y) >= U(x)}
            (computed in the post-batch graph) contains an endpoint of an
            inserted edge e with min(U(u_e), U(v_e)) >= U(x); and
        (b) new_deg(x) > U(x) (a core number never exceeds the degree); and
        (c) x survives a support peel: iteratively discard candidates with
            fewer than U(x)+1 neighbors that are either candidates at the
            same level or have U > U(x) (a vertex cannot sit in a
            (U(x)+1)-core without U(x)+1 qualified neighbors).

    Passes repeat until no vertex is raised. Soundness (U_final >= new
    cores): induct over a sequential replay — deletions first (cores only
    drop, so U_0 = old cores stays an upper bound), then insertions one at
    a time. If the i-th insertion truly raises x from c_i(x) and
    U(x) = c_i(x) still, then the true subcore path (core values exactly
    c_i(x)) is a path in the level set G_{>=U(x)} because U >= c_i
    pointwise, the raising edge has min-endpoint-bound >= c_i(x), x's true
    (c_i(x)+1)-core membership forces >= U(x)+1 qualified neighbors (each
    with final core > U(x), hence eventually U > U(x) or a same-level
    candidate), and its degree exceeds U(x) — so a later pass raises x.
    The level-set connectivity is evaluated in the final graph, a supergraph
    of every intermediate one, which only enlarges components (safe: over-
    approximating raises costs extra seed broadcasts, never correctness).

    The passes run as ONE jitted device program (``_ub_converge``: an
    outer while_loop over ``_ub_pass_body`` — a max-min bottleneck
    propagation replaces the host-side union-find sweep; a synchronous
    segment-sum peel replaces the stack peel — both reach the same
    fixpoints, checked against ``_insertion_upper_bound_unionfind`` in the
    tests). The number of passes is bounded by the largest true core
    increase (1-2 for realistic churn; up to tens when a sliding window
    first fills).
    """
    return _insertion_upper_bound_arrays(
        new_g.n, new_g.src, new_g.dst, np.ones(new_g.num_arcs, bool),
        new_g.deg, old_core_ext, inserted)


def _insertion_upper_bound_unionfind(new_g: Graph, old_core_ext: np.ndarray,
                                     inserted: np.ndarray) -> np.ndarray:
    """Host-side union-find reference for ``_insertion_upper_bound``.

    One arc sort + union-find sweep over levels per pass, O(m alpha) plus a
    stack peel, all numpy/Python. Kept as the oracle the vectorized path is
    property-tested against (tests/test_streaming.py).
    """
    n = new_g.n
    U = old_core_ext.astype(np.int64).copy()
    if inserted.size == 0 or n == 0:
        return U
    cap = new_g.deg.astype(np.int64)
    src, dst, offsets = new_g.src, new_g.dst, new_g.offsets
    half = src < dst
    e_u = src[half].astype(np.int64)
    e_v = dst[half].astype(np.int64)
    ins_u, ins_v = inserted[:, 0], inserted[:, 1]

    parent = np.zeros(n, np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:        # path compression
            parent[x], x = root, parent[x]
        return int(root)

    while True:
        # --- per-pass structures on the current bound vector U ---------- #
        k_ins = np.minimum(U[ins_u], U[ins_v])
        A = np.full(n, -1, np.int64)    # best inserted-edge level per vertex
        np.maximum.at(A, ins_u, k_ins)
        np.maximum.at(A, ins_v, k_ins)
        lev_arc = np.minimum(U[e_u], U[e_v])
        arc_order = np.argsort(-lev_arc, kind="stable")
        vert_order = np.argsort(-U, kind="stable")

        parent[:] = np.arange(n)
        M = A.copy()                    # per-root max inserted-edge level
        marked = np.zeros(n, bool)

        ai, vi = 0, 0
        n_arcs = arc_order.shape[0]
        while vi < n:
            L = int(U[vert_order[vi]])
            # activate all arcs of the level set G_{>=L}
            while ai < n_arcs and lev_arc[arc_order[ai]] >= L:
                a = arc_order[ai]
                ra, rb = find(int(e_u[a])), find(int(e_v[a]))
                if ra != rb:
                    parent[ra] = rb
                    M[rb] = max(M[rb], M[ra])
                ai += 1
            # candidates at level L: connected to a qualifying insertion
            cand = []
            while vi < n and U[vert_order[vi]] == L:
                x = int(vert_order[vi])
                vi += 1
                if cap[x] > L and M[find(x)] >= L:
                    cand.append(x)
            if not cand:
                continue
            # support peel: survivors need >= L+1 neighbors with U > L or
            # surviving candidates at this level
            in_c = np.zeros(n, bool)
            in_c[cand] = True
            s = {x: int(np.count_nonzero(
                    (U[dst[offsets[x]:offsets[x + 1]]] > L)
                    | in_c[dst[offsets[x]:offsets[x + 1]]]))
                 for x in cand}
            stack = [x for x in cand if s[x] <= L]
            while stack:
                x = stack.pop()
                if not in_c[x]:
                    continue
                in_c[x] = False
                for y in dst[offsets[x]:offsets[x + 1]]:
                    y = int(y)
                    if in_c[y]:
                        s[y] -= 1
                        if s[y] == L:
                            stack.append(y)
            marked |= in_c
        if not marked.any():
            return U
        U[marked] += 1


def warm_start_seed(new_g: Graph, old_core: np.ndarray,
                    delta: ChurnDelta | DeltaResult
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Sound upper-bound seed for the new graph's core numbers.

    Returns (seed, region): seed (n,) int32 with seed >= new core pointwise;
    region (n,) bool marks the insertion region that was re-seeded upward.
    Outside the region the seed is min(old_core, new_deg) — deletions only
    lower cores, so the previous fixpoint stays an upper bound there.
    """
    n = new_g.n
    old_core_ext = np.zeros(n, np.int64)
    old_core_ext[: old_core.shape[0]] = old_core  # new vertices: old core 0
    new_deg = new_g.deg.astype(np.int64)

    U = _insertion_upper_bound(new_g, old_core_ext, delta.inserted)
    seed = np.minimum(U, new_deg)
    region = U > old_core_ext
    return seed.astype(np.int32), region


# ---------------------------------------------------------------------- #
# Frontier-localized re-convergence
# ---------------------------------------------------------------------- #

@functools.partial(jax.jit, static_argnames=("n", "n_iters"))
def _compact_kernel(est_u, est_dst_masked, src, n, n_iters):
    """h-index over a pre-gathered compact frontier subproblem."""
    new = _hindex_by_bsearch(est_u, est_dst_masked, src, n, n_iters)
    return new, new < est_u


# ---------------------------------------------------------------------- #
# The engine
# ---------------------------------------------------------------------- #

class StreamingKCoreEngine:
    """Maintains exact core numbers of a mutating graph.

    ``__init__`` pays one static decomposition; every ``apply_batch`` then
    re-converges incrementally from the previous fixpoint. ``self.core`` is
    exact after every batch (tested against the BZ oracle).

    Pass ``mesh`` (+ ``axis_names``) to run mesh-native: the initial
    decomposition uses the sharded static engine and churn batches with a
    ``sharded``/``auto`` frontier iterate the masked shard_map superstep.
    All execution modes are exact-equal in cores AND message counts, so a
    mesh never changes an answer — only where the work runs.
    """

    def __init__(self, g: Graph, config: StreamingConfig = StreamingConfig(),
                 kcore_config: KCoreConfig = KCoreConfig(),
                 mesh=None, axis_names=("data",)):
        if config.frontier not in FRONTIER_MODES:
            raise ValueError(f"unknown frontier mode {config.frontier!r}")
        if config.frontier == "sharded" and mesh is None:
            from repro.distribution.compat import make_mesh
            mesh = make_mesh((jax.device_count(),), ("data",))
            axis_names = ("data",)
        self.config = config
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self._csr = PatchableCSR(g, slack=config.slack,
                                 min_slack=config.min_slack,
                                 compact_dead_frac=config.compact_dead_frac)
        self._graph_cache: Graph | None = g
        self._slots_cache: tuple | None = None
        self._live_cache: tuple | None = None
        # shape high-water marks (see _padded_slots / _run_fused): per-batch
        # fluctuations must never SHRINK a jitted program's shape
        self._arc_pad_hwm = _next_pow2(max(int(config.min_arc_capacity), 1))
        self._shard_A_floor = 0
        self._n_iters_hwm = 0
        if mesh is not None and config.frontier in ("sharded", "fused",
                                                    "auto"):
            # sharded init: same cores/messages as the single-device static
            # engine (tests/test_distributed.py), no host-side detour
            init = kcore_decompose_sharded(g, mesh, self.axis_names,
                                           max_rounds=kcore_config.max_rounds)
        else:
            init = kcore_decompose(g, kcore_config)
        self.core = init.core.astype(np.int32)
        self.init_result = init
        self.batches_applied = 0

    # ------------------------------------------------------------------ #
    @property
    def graph(self) -> Graph:
        """The current graph, materialized lazily (O(m log m)) and cached.

        The engine itself never consumes this — supersteps and seeding run
        on the patched CSR slot arrays; this is for callers (oracles,
        benchmarks, churn samplers)."""
        if self._graph_cache is None:
            self._graph_cache = self._csr.to_graph()
        return self._graph_cache

    @property
    def csr(self) -> PatchableCSR:
        return self._csr

    @property
    def n(self) -> int:
        """Vertex count — O(1), no Graph materialization."""
        return self._csr.n

    @property
    def m(self) -> int:
        """Edge count — O(1), no Graph materialization."""
        return self._csr.m

    def _live_arrays(self) -> tuple:
        """(src, dst) of the LIVE arcs only, still src-sorted (row-major
        slot order survives boolean filtering), cached until the next batch
        mutates the CSR. One O(capacity) extraction buys every downstream
        device program a 2-4x smaller arc dimension than the slack+hole
        padded slot arrays."""
        if self._live_cache is None:
            csr = self._csr
            self._live_cache = (csr.src[csr.live], csr.dst[csr.live])
        return self._live_cache

    def _padded_slots(self) -> tuple:
        """(src, dst, mask) live arc arrays padded to a pow2 HIGH-WATER
        arc count, cached until the next batch mutates the CSR. Shared by
        the seed pass and the dense/fused supersteps so their jitted
        programs see O(log) distinct arc shapes over a whole churn stream:
        the live count moves both ways batch to batch, and re-crossing a
        pow2 boundary would mint a fresh signature each time; the high-
        water mark (pre-seeded by ``min_arc_capacity``) only grows."""
        if self._slots_cache is None:
            src_live, dst_live = self._live_arrays()
            k = src_live.size
            self._arc_pad_hwm = max(self._arc_pad_hwm,
                                    _next_pow2(max(k, 1)))
            arc_pad = self._arc_pad_hwm
            src_np = np.zeros(arc_pad, np.int32)
            src_np[:k] = src_live
            dst_np = np.zeros(arc_pad, np.int32)
            dst_np[:k] = dst_live
            mask = np.zeros(arc_pad, bool)
            mask[:k] = True
            self._slots_cache = (src_np, dst_np, mask)
        return self._slots_cache

    # ------------------------------------------------------------------ #
    def state_dict(self) -> dict:
        """Checkpointable pytree of the engine's exact state.

        Cores plus the full PatchableCSR slot state (``delta.PatchableCSR
        .state_dict``) — everything a warm restart needs to continue the
        stream without re-running the initial decomposition. Feed straight
        to ``repro.checkpoint.save_checkpoint``; rebuild with
        ``StreamingKCoreEngine.from_state_dict``.
        """
        return {
            "core": np.asarray(self.core, np.int32),
            "batches_applied": np.asarray(self.batches_applied, np.int64),
            "csr": self._csr.state_dict(),
            # jit-shape high-water marks: not needed for correctness, but
            # restoring them means a warm restart re-enters the stream at
            # the steady-state program shapes instead of recompiling its
            # way back up through every pow2 size
            "arc_pad_hwm": np.asarray(self._arc_pad_hwm, np.int64),
            "n_iters_hwm": np.asarray(self._n_iters_hwm, np.int64),
            "shard_A_floor": np.asarray(self._shard_A_floor, np.int64),
        }

    @classmethod
    def from_state_dict(cls, state: dict,
                        config: StreamingConfig = StreamingConfig(),
                        mesh=None, axis_names=("data",)
                        ) -> "StreamingKCoreEngine":
        """Warm-restart an engine from ``state_dict`` output.

        No decomposition runs: the restored cores ARE the fixpoint of the
        restored CSR (the pair was captured atomically), so the engine
        resumes exactly where the checkpointed one stopped. Restored
        leaves may be jnp arrays (``repro.checkpoint`` restores onto
        device) — everything is normalized back to host numpy here.
        """
        if config.frontier not in FRONTIER_MODES:
            raise ValueError(f"unknown frontier mode {config.frontier!r}")
        if config.frontier == "sharded" and mesh is None:
            from repro.distribution.compat import make_mesh
            mesh = make_mesh((jax.device_count(),), ("data",))
            axis_names = ("data",)
        eng = cls.__new__(cls)
        eng.config = config
        eng.mesh = mesh
        eng.axis_names = tuple(axis_names)
        eng._csr = PatchableCSR.from_state(
            {k: np.asarray(v) for k, v in state["csr"].items()},
            slack=config.slack, min_slack=config.min_slack,
            compact_dead_frac=config.compact_dead_frac)
        eng._graph_cache = None
        eng._slots_cache = None
        eng._live_cache = None
        eng._arc_pad_hwm = max(
            _next_pow2(max(int(config.min_arc_capacity), 1)),
            int(np.asarray(state.get("arc_pad_hwm", 1))))
        eng._shard_A_floor = int(np.asarray(state.get("shard_A_floor", 0)))
        eng._n_iters_hwm = int(np.asarray(state.get("n_iters_hwm", 0)))
        eng.core = np.asarray(state["core"], np.int32)
        eng.init_result = None
        eng.batches_applied = int(np.asarray(state["batches_applied"]))
        return eng

    # ------------------------------------------------------------------ #
    def _resolve_mode(self, n: int, active: np.ndarray) -> str:
        """Config frontier -> the execution mode this batch runs in.

        ``fused`` resolves to its mesh variant (``fused_sharded``) when a
        mesh is attached; ``auto`` picks compact below the frontier-size
        threshold and the fused path above it (device-resident while_loop
        beats per-round host dispatch whenever the frontier stays large
        for many rounds)."""
        mode = self.config.frontier
        if mode == "auto":
            frac = float(active.sum()) / max(n, 1)
            if frac <= self.config.compact_threshold:
                return "compact"
            mode = "fused"
        if mode == "fused" and self.mesh is not None:
            return "fused_sharded"
        return mode

    def _make_step(self, mode: str, n: int, n_iters: int):
        """Build the per-round step(est, active) -> (new_est, changed, recv)
        for one batch. All three implementations are exact-equal."""
        csr = self._csr
        src, dst, live, deg = csr.src, csr.dst, csr.live, csr.deg

        if mode == "dense":
            src_p, dst_p, amask_p = self._padded_slots()
            plan = _dispatch.resolve_plan()
            if plan.kind == "pallas":
                # segment-sum route only (ell=None): the slot arrays are
                # masked/mutable, not a static fully-live adjacency. Arc
                # contents are baked into the program — a churning stream
                # re-stages per batch (the documented REPRO_PALLAS=on cost).
                prog = _dispatch.masked_round_program(
                    n, n_iters, plan,
                    np.asarray(src_p, np.int32), np.asarray(dst_p, np.int32))
                amask_j = jnp.asarray(amask_p)

                def step(est, active):
                    new_j, ch_j, recv_j = prog(
                        jnp.asarray(est), amask_j, jnp.asarray(active))
                    return new_j, np.asarray(ch_j), np.asarray(recv_j)

                return step
            src_j, dst_j, amask_j = (jnp.asarray(a) for a in
                                     (src_p, dst_p, amask_p))

            def step(est, active):
                # est stays device-resident across rounds (the loop treats
                # it opaquely); only the small bool masks come back to host
                new_j, ch_j, recv_j = masked_round_segment(
                    jnp.asarray(est), src_j, dst_j, amask_j,
                    jnp.asarray(active), n, n_iters)
                return new_j, np.asarray(ch_j), np.asarray(recv_j)

            return step

        if mode == "compact":
            def step(est, active):
                act_ids = np.flatnonzero(active)
                if act_ids.size == 0:
                    z = np.zeros(n, bool)
                    return est, z, z
                arc_sel = live & active[src]
                sub_src = np.searchsorted(
                    act_ids, src[arc_sel]).astype(np.int32)
                sub_dst_est = est[dst[arc_sel]].astype(np.int32)

                n_act_pad = _next_pow2(act_ids.size)
                arc_pad = _next_pow2(max(sub_src.size, 1))
                est_u = np.zeros(n_act_pad, np.int32)
                est_u[: act_ids.size] = est[act_ids]
                src_pad = np.full(arc_pad, n_act_pad - 1, np.int32)
                src_pad[: sub_src.size] = sub_src
                dst_est_pad = np.zeros(arc_pad, np.int32)  # 0 never counts
                dst_est_pad[: sub_src.size] = sub_dst_est

                new_sub, changed_sub = _compact_kernel(
                    jnp.asarray(est_u), jnp.asarray(dst_est_pad),
                    jnp.asarray(src_pad), n_act_pad, n_iters)

                new_est = est.copy()
                new_est[act_ids] = np.asarray(new_sub)[: act_ids.size]
                changed = np.zeros(n, bool)
                changed[act_ids] = np.asarray(changed_sub)[: act_ids.size]
                recv = _receivers_arrays(n, src, dst, live, changed)
                return new_est, changed, recv

            return step

        # sharded: shard the slot arrays (already src-sorted — no sort) and
        # iterate the masked shard_map superstep
        sg = self._shard_slots(n)
        superstep, _ = make_sharded_superstep(sg, self.mesh, self.axis_names,
                                              n_iters, masked=True)
        n_dev = sg.n_shards
        V, n_pad = sg.verts_per_shard, sg.n_pad
        src_j = jnp.asarray(sg.src)
        dst_j = jnp.asarray(sg.dst)
        amask_j = jnp.asarray(sg.arc_mask)
        deg_j = jnp.asarray(sg.deg)

        def step(est, active):
            est_p = np.zeros(n_pad, np.int32)
            est_p[:n] = est
            act_p = np.zeros(n_pad, bool)
            act_p[:n] = active
            new_j, ch_j, recv_j, _msgs = superstep(
                jnp.asarray(est_p.reshape(n_dev, V)), src_j, dst_j, amask_j,
                deg_j, jnp.asarray(act_p.reshape(n_dev, V)))
            new = np.asarray(new_j).reshape(-1)[:n]
            ch = np.asarray(ch_j).reshape(-1)[:n]
            recv = np.asarray(recv_j).reshape(-1)[:n]
            return new, ch, recv

        return step

    def _shard_slots(self, n: int):
        """Shard the CSR slot arrays over the mesh with the arc-block
        high-water floor applied (src-sorted by construction — no sort)."""
        from repro.graph.partition import shard_arc_arrays

        src_live, dst_live = self._live_arrays()
        n_dev = int(np.prod([self.mesh.shape[a] for a in self.axis_names]))
        sg = shard_arc_arrays(n, src_live, dst_live,
                              np.ones(src_live.size, bool), self._csr.deg,
                              n_dev, pow2=True,
                              min_arcs_per_shard=self._shard_A_floor)
        self._shard_A_floor = max(self._shard_A_floor, sg.arcs_per_shard)
        return sg

    def _run_fused(self, seed: np.ndarray, active: np.ndarray, n: int,
                   n_iters: int, cap: int, sharded: bool):
        """One fused device-resident re-convergence through the shared
        runtime (core/runtime.py) — the same layer the static engine's
        ``kcore_decompose(..., fused=True)`` calls. Returns a FusedOutcome
        whose three int64 arrays cover exactly the productive rounds — the
        host-loop modes' accounting."""
        if sharded:
            sg = self._shard_slots(n)
            return fused_converge_sharded(seed, active, sg, self.mesh,
                                          self.axis_names, n=n,
                                          n_iters=n_iters, max_rounds=cap)
        src_p, dst_p, amask_p = self._padded_slots()
        return fused_converge_dense(seed, active, src_p, dst_p, amask_p,
                                    self._csr.deg, n=n, n_iters=n_iters,
                                    max_rounds=cap)

    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: EdgeBatch) -> BatchResult:
        """Apply one churn batch and re-converge to exact cores.

        When tracing is enabled (repro.obs.trace) each batch emits a
        ``batch`` span with ``csr-patch`` / ``seed`` / ``converge`` /
        ``host-reconstruct`` children (the fused modes nest the runtime's
        ``fused-converge`` -> ``device-converge`` / ``stats-reconstruct``
        tree under ``converge``, and fresh XLA compiles land as
        ``xla.compile`` events wherever they happened). The same phase
        boundaries are always measured into ``BatchResult.patch_s`` /
        ``seed_s`` / ``converge_s`` / ``reconstruct_s``.
        """
        with _trace.span("batch", batch_id=self.batches_applied) as bsp:
            res = self._apply_batch_body(batch)
            bsp.set(mode=res.mode, rounds=res.rounds,
                    messages=res.stats.total_messages,
                    converged=res.converged,
                    seed_strategy=res.seed_strategy,
                    region=res.region_size,
                    recompiles=res.recompiles,
                    compile_s=round(res.compile_s, 6))
        return res

    def _apply_batch_body(self, batch: EdgeBatch) -> BatchResult:
        compiles0, csecs0 = compile_count(), compile_seconds()
        t0 = time.perf_counter()
        with _trace.span("csr-patch"):
            delta = self._csr.apply_batch(batch)
        patch_s = time.perf_counter() - t0
        self._graph_cache = None
        self._slots_cache = None
        self._live_cache = None
        csr = self._csr
        n = csr.n
        deg64 = csr.deg.astype(np.int64)

        t_seed = time.perf_counter()
        with _trace.span("seed") as ssp:
            old_core_ext = np.zeros(n, np.int64)
            old_core_ext[: self.core.shape[0]] = self.core
            seed_choice = choose_seed(delta.inserted, csr.deg, old_core_ext,
                                      model=self.config.seed_model)
            if seed_choice.strategy == "degree":
                # bulk load: degree seed (see StreamingConfig.seed_model)
                U = deg64.copy()
            else:
                src_p, dst_p, live_p = self._padded_slots()
                U = _insertion_upper_bound_arrays(n, src_p, dst_p, live_p,
                                                  csr.deg, old_core_ext,
                                                  delta.inserted)
            seed = np.minimum(U, deg64).astype(np.int32)
            region = U > old_core_ext
            old_core32 = old_core_ext.astype(np.int32)

            # ---- round 0: seed broadcast + link handshakes ------------ #
            seed_changed = seed != old_core32
            msgs = [int(deg64[seed_changed].sum())
                    + 2 * int(delta.inserted.shape[0])
                    + 2 * int(delta.deleted.shape[0])]
            changed_counts = [int(seed_changed.sum())]

            # ---- initial frontier ------------------------------------- #
            # recompute u iff its h-index inputs changed: an incident edge
            # appeared/disappeared, or a neighbor's broadcast value changed.
            active = np.zeros(n, bool)
            touched = delta.touched[delta.touched < n]
            active[touched] = True
            active |= seed_changed
            src_live, dst_live = self._live_arrays()
            active |= _receivers_arrays(n, src_live, dst_live, None,
                                        seed_changed)
            ssp.set(strategy=seed_choice.strategy,
                    region=int(region.sum()),
                    frontier=int(active.sum()))
        seed_s = time.perf_counter() - t_seed
        # active_per_round follows the static engine's convention:
        # [r] = vertices recomputing/broadcasting in round r. Round 0 is the
        # seed rebroadcast; round 1's recomputers are the initial frontier.
        actives = [int(seed_changed.sum()), int(active.sum())]

        mode = self._resolve_mode(n, active)
        # flight: one run per churn batch; round 0 = seed rebroadcast +
        # link handshakes. No prev_est on round 0 — seed vs the old core
        # legitimately moves both ways, only rounds >= 1 must be monotone.
        rec = _flight.recorder()
        if rec.active:
            rec.start_run("streaming", mode, batch=self.batches_applied, n=n)
            rec.record_round(actives[0], msgs[0], changed_counts[0],
                             est=seed)
        est = seed
        rounds, converged = 0, False
        cap = (self.config.max_rounds if self.config.max_rounds is not None
               else n + 1)
        # the binary-search depth is bucketed (multiple of 4) and high-water-
        # marked: extra iterations are idempotent at the h-index fixpoint,
        # so neither a shrinking max degree nor one that creeps up by single
        # bits may mint a fresh jit signature
        n_iters = _round_up(_bs_iters(int(csr.deg.max()) if n else 0), 4)
        n_iters = self._n_iters_hwm = max(n_iters, self._n_iters_hwm)

        t_conv = time.perf_counter()
        with _trace.span("converge", mode=mode):
            if mode in ("fused", "fused_sharded"):
                if active.any():
                    outcome = self._run_fused(seed, active, n, n_iters, cap,
                                              sharded=mode == "fused_sharded")
                    core, rounds = outcome.est, outcome.rounds
                    converged = outcome.converged
                    msgs.extend(outcome.msgs.tolist())
                    changed_counts.extend(outcome.changed.tolist())
                    actives.extend(outcome.recv.tolist())
                else:
                    core, converged = np.asarray(seed, np.int32), True
            else:
                step = self._make_step(mode, n, n_iters)
                while rounds < cap and active.any():
                    t_r = time.perf_counter() if rec.active else 0.0
                    with _trace.span("kcore.round", round=rounds):
                        new_est, ch, recv = step(est, active)
                        rounds += 1
                        if not ch.any():
                            converged = True
                            break
                        msgs.append(int(deg64[ch].sum()))
                        changed_counts.append(int(ch.sum()))
                        if rec.active:
                            rec.record_round(
                                actives[rounds], msgs[-1],
                                changed_counts[-1],
                                est=np.asarray(new_est),
                                prev_est=np.asarray(est),
                                host_s=time.perf_counter() - t_r)
                        active = recv
                        actives.append(int(active.sum()))
                        est = new_est
                if not active.any():
                    converged = True
                core = np.asarray(est, np.int32)
        converge_s = time.perf_counter() - t_conv

        t_rec = time.perf_counter()
        with _trace.span("host-reconstruct"):
            stats = MessageStats(
                messages_per_round=np.asarray(msgs, np.int64),
                active_per_round=np.asarray(actives[: len(msgs)], np.int64),
                changed_per_round=np.asarray(changed_counts[: len(msgs)],
                                             np.int64),
            )
            self.core = core
            self.batches_applied += 1
            cap_slots = max(csr.capacity, 1)
            if rec.active:
                rec.end_run(converged=converged,
                            messages=int(stats.total_messages))
            reconstruct_s = time.perf_counter() - t_rec
            return BatchResult(core=core, rounds=rounds, converged=converged,
                               stats=stats, delta=delta,
                               region_size=int(region.sum()),
                               seed_changed=int(seed_changed.sum()),
                               mode=mode, patch_s=patch_s,
                               seed_s=seed_s, converge_s=converge_s,
                               reconstruct_s=reconstruct_s,
                               seed_strategy=seed_choice.strategy,
                               seed_est_passes=seed_choice.est_passes,
                               recompiles=compile_count() - compiles0,
                               compile_s=compile_seconds() - csecs0,
                               csr_compactions=int(csr.compactions),
                               csr_dead_frac=csr.dead / cap_slots,
                               csr_occupancy=2 * csr.m / cap_slots)
