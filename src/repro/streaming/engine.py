"""Warm-started incremental k-core engine.

Correctness rests on the locality theorem the static engine is built on
(core/kcore.py, paper §II.B): iterating est'(u) = H({min(est(v), est(u))})
converges to the exact core numbers from ANY per-vertex seed that upper
bounds them. So after a churn batch the engine only has to produce a sound
upper-bound seed — then frontier-localized supersteps re-converge exactly.

Seeding rules (all sound, proofs in the docstrings below):

  * a vertex whose core number cannot have increased keeps
    ``min(old_core, new_deg)`` — deletions only lower cores, and the old
    fixpoint is an upper bound of the new one outside the insertion region;
  * vertices that MAY have increased — the insertion region R — are re-seeded
    from a tight upper-bound vector computed by a batch generalization of
    the single-edge subcore theorem: +1 passes over level-set components
    anchored at inserted edges, pruned by a support peel
    (see ``_insertion_upper_bound``).

Message accounting mirrors core/messages.py: round 0 of a batch charges
deg(u) for every vertex whose seed differs from its previously broadcast
value (it must re-announce), plus 2 messages per inserted/deleted edge (the
link handshake/teardown); every later round charges deg(u) per vertex whose
estimate decreased. This makes "messages per batch" directly comparable to
the from-scratch total the paper reports.

Two frontier execution modes:

  * ``dense``   — full-width jitted masked superstep (core.masked_round_segment):
    one XLA program for the whole stream, frontier as a boolean mask;
  * ``compact`` — per-round extraction of the active subgraph, padded to
    powers of two so jit recompiles only O(log n) distinct shapes; work per
    round is proportional to the frontier, not the graph.

Both modes produce identical estimates and identical message counts.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.kcore import (KCoreConfig, _bs_iters, _hindex_by_bsearch,
                              _receivers_np, kcore_decompose,
                              masked_round_segment)
from repro.core.messages import MessageStats
from repro.graph.structs import Graph
from repro.streaming.delta import DeltaResult, EdgeBatch, apply_batch


# ---------------------------------------------------------------------- #
# Config / result
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class StreamingConfig:
    frontier: str = "dense"          # "dense" | "compact"
    max_rounds: int | None = None    # None -> n + 1 per batch (worst case)


@dataclasses.dataclass
class BatchResult:
    """Outcome of one incremental batch."""

    core: np.ndarray          # exact core numbers after the batch
    rounds: int               # supersteps to re-converge (excl. seed round)
    converged: bool
    stats: MessageStats       # per-round accounting; [0] = seed broadcast
    delta: DeltaResult        # what the batch actually changed
    region_size: int          # |R| — insertion region that was re-seeded up
    seed_changed: int         # vertices that had to rebroadcast at seed time

    @property
    def total_messages(self) -> int:
        return self.stats.total_messages


# ---------------------------------------------------------------------- #
# Warm-start seeding
# ---------------------------------------------------------------------- #

def _insertion_upper_bound(new_g: Graph, old_core_ext: np.ndarray,
                           inserted: np.ndarray) -> np.ndarray:
    """Pointwise upper bound U >= new core numbers, tight around insertions.

    Batch generalization of the classic single-edge subcore theorem
    (Sariyuce et al., "Streaming algorithms for k-core decomposition"):
    inserting ONE edge (u, v) into a graph with exact cores c raises core
    numbers by at most 1, and only for vertices x with c(x) = k =
    min(c(u), c(v)) reachable from an endpoint through vertices of core k.

    We iterate +1 "passes" over an evolving bound vector U (initialized to
    the pre-batch exact cores, so U >= cores holds at the start):

      pass: a vertex x is RAISED by 1 iff
        (a) its component in the level set G_{>=U(x)} = {y : U(y) >= U(x)}
            (computed in the post-batch graph) contains an endpoint of an
            inserted edge e with min(U(u_e), U(v_e)) >= U(x); and
        (b) new_deg(x) > U(x) (a core number never exceeds the degree); and
        (c) x survives a support peel: iteratively discard candidates with
            fewer than U(x)+1 neighbors that are either candidates at the
            same level or have U > U(x) (a vertex cannot sit in a
            (U(x)+1)-core without U(x)+1 qualified neighbors).

    Passes repeat until no vertex is raised. Soundness (U_final >= new
    cores): induct over a sequential replay — deletions first (cores only
    drop, so U_0 = old cores stays an upper bound), then insertions one at
    a time. If the i-th insertion truly raises x from c_i(x) and
    U(x) = c_i(x) still, then the true subcore path (core values exactly
    c_i(x)) is a path in the level set G_{>=U(x)} because U >= c_i
    pointwise, the raising edge has min-endpoint-bound >= c_i(x), x's true
    (c_i(x)+1)-core membership forces >= U(x)+1 qualified neighbors (each
    with final core > U(x), hence eventually U > U(x) or a same-level
    candidate), and its degree exceeds U(x) — so a later pass raises x.
    The level-set connectivity is evaluated in the final graph, a supergraph
    of every intermediate one, which only enlarges components (safe: over-
    approximating raises costs extra seed broadcasts, never correctness).

    Complexity per pass: one arc sort + union-find sweep over levels,
    O(m alpha) plus the peel, all host-side numpy; the number of passes is
    bounded by the largest true core increase (1-2 for realistic churn).
    """
    n = new_g.n
    U = old_core_ext.astype(np.int64).copy()
    if inserted.size == 0 or n == 0:
        return U
    cap = new_g.deg.astype(np.int64)
    src, dst, offsets = new_g.src, new_g.dst, new_g.offsets
    half = src < dst
    e_u = src[half].astype(np.int64)
    e_v = dst[half].astype(np.int64)
    ins_u, ins_v = inserted[:, 0], inserted[:, 1]

    parent = np.zeros(n, np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:        # path compression
            parent[x], x = root, parent[x]
        return int(root)

    while True:
        # --- per-pass structures on the current bound vector U ---------- #
        k_ins = np.minimum(U[ins_u], U[ins_v])
        A = np.full(n, -1, np.int64)    # best inserted-edge level per vertex
        np.maximum.at(A, ins_u, k_ins)
        np.maximum.at(A, ins_v, k_ins)
        lev_arc = np.minimum(U[e_u], U[e_v])
        arc_order = np.argsort(-lev_arc, kind="stable")
        vert_order = np.argsort(-U, kind="stable")

        parent[:] = np.arange(n)
        M = A.copy()                    # per-root max inserted-edge level
        marked = np.zeros(n, bool)

        ai, vi = 0, 0
        n_arcs = arc_order.shape[0]
        while vi < n:
            L = int(U[vert_order[vi]])
            # activate all arcs of the level set G_{>=L}
            while ai < n_arcs and lev_arc[arc_order[ai]] >= L:
                a = arc_order[ai]
                ra, rb = find(int(e_u[a])), find(int(e_v[a]))
                if ra != rb:
                    parent[ra] = rb
                    M[rb] = max(M[rb], M[ra])
                ai += 1
            # candidates at level L: connected to a qualifying insertion
            cand = []
            while vi < n and U[vert_order[vi]] == L:
                x = int(vert_order[vi])
                vi += 1
                if cap[x] > L and M[find(x)] >= L:
                    cand.append(x)
            if not cand:
                continue
            # support peel: survivors need >= L+1 neighbors with U > L or
            # surviving candidates at this level
            in_c = np.zeros(n, bool)
            in_c[cand] = True
            s = {x: int(np.count_nonzero(
                    (U[dst[offsets[x]:offsets[x + 1]]] > L)
                    | in_c[dst[offsets[x]:offsets[x + 1]]]))
                 for x in cand}
            stack = [x for x in cand if s[x] <= L]
            while stack:
                x = stack.pop()
                if not in_c[x]:
                    continue
                in_c[x] = False
                for y in dst[offsets[x]:offsets[x + 1]]:
                    y = int(y)
                    if in_c[y]:
                        s[y] -= 1
                        if s[y] == L:
                            stack.append(y)
            marked |= in_c
        if not marked.any():
            return U
        U[marked] += 1


def warm_start_seed(new_g: Graph, old_core: np.ndarray, delta: DeltaResult
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Sound upper-bound seed for the new graph's core numbers.

    Returns (seed, region): seed (n,) int32 with seed >= new core pointwise;
    region (n,) bool marks the insertion region that was re-seeded upward.
    Outside the region the seed is min(old_core, new_deg) — deletions only
    lower cores, so the previous fixpoint stays an upper bound there.
    """
    n = new_g.n
    old_core_ext = np.zeros(n, np.int64)
    old_core_ext[: old_core.shape[0]] = old_core  # new vertices: old core 0
    new_deg = new_g.deg.astype(np.int64)

    U = _insertion_upper_bound(new_g, old_core_ext, delta.inserted)
    seed = np.minimum(U, new_deg)
    region = U > old_core_ext
    return seed.astype(np.int32), region


# ---------------------------------------------------------------------- #
# Frontier-localized re-convergence
# ---------------------------------------------------------------------- #

def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


@functools.partial(jax.jit, static_argnames=("n", "n_iters"))
def _compact_kernel(est_u, est_dst_masked, src, n, n_iters):
    """h-index over a pre-gathered compact frontier subproblem."""
    new = _hindex_by_bsearch(est_u, est_dst_masked, src, n, n_iters)
    return new, new < est_u


def _compact_round(g: Graph, est: np.ndarray, active: np.ndarray,
                   n_iters: int) -> tuple[np.ndarray, np.ndarray]:
    """One superstep touching only the active subgraph.

    Extracts the arcs sourced at active vertices, remaps them to a dense
    [0, n_act) segment space padded to powers of two (so jit sees O(log n)
    shapes over the whole stream), gathers the neighbor estimates host-side
    (neighbors may be inactive — their values come from the full vector),
    and runs the same binary-search h-index as the full-width path.
    Returns (new_est, changed) full-size.
    """
    act_ids = np.flatnonzero(active)
    if act_ids.size == 0:
        return est, np.zeros(g.n, bool)
    arc_sel = active[g.src]
    sub_src = np.searchsorted(act_ids, g.src[arc_sel]).astype(np.int32)
    sub_dst_est = est[g.dst[arc_sel]].astype(np.int32)

    n_act_pad = _next_pow2(act_ids.size)
    arc_pad = _next_pow2(max(sub_src.size, 1))
    est_u = np.zeros(n_act_pad, np.int32)
    est_u[: act_ids.size] = est[act_ids]
    src_pad = np.full(arc_pad, n_act_pad - 1, np.int32)
    src_pad[: sub_src.size] = sub_src
    dst_est_pad = np.zeros(arc_pad, np.int32)   # 0 never counts for k >= 1
    dst_est_pad[: sub_src.size] = sub_dst_est

    new_sub, changed_sub = _compact_kernel(
        jnp.asarray(est_u), jnp.asarray(dst_est_pad), jnp.asarray(src_pad),
        n_act_pad, n_iters)

    new_est = est.copy()
    new_est[act_ids] = np.asarray(new_sub)[: act_ids.size]
    changed = np.zeros(g.n, bool)
    changed[act_ids] = np.asarray(changed_sub)[: act_ids.size]
    return new_est, changed


# ---------------------------------------------------------------------- #
# The engine
# ---------------------------------------------------------------------- #

class StreamingKCoreEngine:
    """Maintains exact core numbers of a mutating graph.

    ``__init__`` pays one static decomposition; every ``apply_batch`` then
    re-converges incrementally from the previous fixpoint. ``self.core`` is
    exact after every batch (tested against the BZ oracle).
    """

    def __init__(self, g: Graph, config: StreamingConfig = StreamingConfig(),
                 kcore_config: KCoreConfig = KCoreConfig()):
        if config.frontier not in ("dense", "compact"):
            raise ValueError(f"unknown frontier mode {config.frontier!r}")
        self.config = config
        self.graph = g
        init = kcore_decompose(g, kcore_config)
        self.core = init.core.astype(np.int32)
        self.init_result = init
        self.batches_applied = 0

    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: EdgeBatch) -> BatchResult:
        delta = apply_batch(self.graph, batch)
        g = delta.graph
        n = g.n
        seed, region = warm_start_seed(g, self.core, delta)

        old_core_ext = np.zeros(n, np.int32)
        old_core_ext[: self.core.shape[0]] = self.core
        deg64 = g.deg.astype(np.int64)

        # ---- round 0: seed broadcast + link handshakes ---------------- #
        seed_changed = seed != old_core_ext
        msgs = [int(deg64[seed_changed].sum())
                + 2 * int(delta.inserted.shape[0])
                + 2 * int(delta.deleted.shape[0])]
        changed_counts = [int(seed_changed.sum())]

        # ---- initial frontier ----------------------------------------- #
        # recompute u iff its h-index inputs changed: an incident edge
        # appeared/disappeared, or a neighbor's broadcast value changed.
        active = np.zeros(n, bool)
        touched = delta.touched[delta.touched < n]
        active[touched] = True
        active |= seed_changed
        active |= _receivers_np(g, seed_changed)
        # active_per_round follows the static engine's convention:
        # [r] = vertices recomputing/broadcasting in round r. Round 0 is the
        # seed rebroadcast; round 1's recomputers are the initial frontier.
        actives = [int(seed_changed.sum()), int(active.sum())]

        est = seed
        rounds, converged = 0, False
        cap = (self.config.max_rounds if self.config.max_rounds is not None
               else n + 1)
        n_iters = _bs_iters(g.max_deg)

        if self.config.frontier == "dense":
            # pad arcs to a power of two so the jitted superstep recompiles
            # only O(log m) times over the whole update stream
            arc_pad = _next_pow2(max(g.num_arcs, 1))
            src_np = np.zeros(arc_pad, np.int32)
            src_np[: g.num_arcs] = g.src
            dst_np = np.zeros(arc_pad, np.int32)
            dst_np[: g.num_arcs] = g.dst
            amask_np = np.zeros(arc_pad, bool)
            amask_np[: g.num_arcs] = True
            est_j = jnp.asarray(est)
            src_j = jnp.asarray(src_np)
            dst_j = jnp.asarray(dst_np)
            amask = jnp.asarray(amask_np)
            while rounds < cap and active.any():
                new_j, changed_j, recv_j = masked_round_segment(
                    est_j, src_j, dst_j, amask, jnp.asarray(active),
                    n, n_iters)
                rounds += 1
                ch = np.asarray(changed_j)
                if not ch.any():
                    converged = True
                    break
                msgs.append(int(deg64[ch].sum()))
                changed_counts.append(int(ch.sum()))
                active = np.asarray(recv_j)   # next frontier, from the device
                actives.append(int(active.sum()))
                est_j = new_j
            est = np.asarray(est_j)
        else:  # compact
            while rounds < cap and active.any():
                new_est, ch = _compact_round(g, est, active, n_iters)
                rounds += 1
                if not ch.any():
                    converged = True
                    break
                msgs.append(int(deg64[ch].sum()))
                changed_counts.append(int(ch.sum()))
                active = _receivers_np(g, ch)
                actives.append(int(active.sum()))
                est = new_est
        if not active.any():
            converged = True

        core = np.asarray(est, np.int32)
        stats = MessageStats(
            messages_per_round=np.asarray(msgs, np.int64),
            active_per_round=np.asarray(actives[: len(msgs)], np.int64),
            changed_per_round=np.asarray(changed_counts[: len(msgs)],
                                         np.int64),
        )
        self.graph = g
        self.core = core
        self.batches_applied += 1
        return BatchResult(core=core, rounds=rounds, converged=converged,
                           stats=stats, delta=delta,
                           region_size=int(region.sum()),
                           seed_changed=int(seed_changed.sum()))
