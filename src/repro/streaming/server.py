"""Core-number query server: update batches interleaved with batched queries.

Models the paper's million-client scenario from the serving side: clients do
not run the decomposition, they ask a maintained index. The server owns a
StreamingKCoreEngine; updates mutate the graph and incrementally re-converge,
queries are O(1)/O(n) numpy reads of the maintained fixpoint — so query
latency is decoupled from graph size and churn entirely.

Request/Response are plain dataclasses (not wire formats): launch/kcore_serve
drives the loop from a CLI, and a real transport would marshal the same ops.

Supported ops
  * ``core``      — core numbers for a batch of vertex ids;
  * ``in_kcore``  — k-core membership for a batch of vertex ids;
  * ``members``   — all vertices of the k-core;
  * ``max_k``     — the degeneracy (largest non-empty k);
  * ``update``    — apply an EdgeBatch through the incremental engine;
  * ``core_asof`` — core numbers AT TIME t, answered from the ring of
    core vectors checkpointed at window boundaries (temporal replay mode,
    repro.temporal): O(1) per lookup for any retained boundary.

Every request's wall-clock is observed into a PER-SERVER metrics registry
(repro.obs.metrics — per-server so tests/processes running several servers
never merge their latency distributions): ``stats()`` reports p50/p95/p99
seconds per op under ``"latency"``, raw-float cumulative walls (callers
format; rounding here would destroy microsecond query walls), and the
registry itself is exposed as ``server.metrics`` for JSON/Prometheus
export. When span tracing is live each serve/update/advance also emits a
``serve.request`` / ``server.update`` / ``window.advance`` span.

A server can be constructed over a static Graph (churn arrives as explicit
``update`` batches) or over a ``WindowedKCoreEngine`` (temporal mode:
``advance_window`` slides the window, and every boundary's core vector is
checkpointed into the as-of ring).
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Any, Iterable

import numpy as np

from repro.core.kcore import KCoreConfig
from repro.graph.structs import Graph
from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry
from repro.streaming.delta import EdgeBatch
from repro.streaming.engine import (BatchResult, StreamingConfig,
                                    StreamingKCoreEngine)

if TYPE_CHECKING:   # temporal depends on streaming, never the reverse
    from repro.temporal.window import WindowedKCoreEngine, WindowStep


@dataclasses.dataclass(frozen=True)
class Request:
    op: str          # core | in_kcore | members | max_k | update | core_asof
    vertices: np.ndarray | None = None   # core / in_kcore / core_asof
    k: int | None = None                 # in_kcore / members
    batch: EdgeBatch | None = None       # update
    t: float | None = None               # core_asof


@dataclasses.dataclass
class Response:
    op: str
    payload: Any
    wall_s: float


class CoreCheckpointRing:
    """Bounded ring of (t, core) snapshots for as-of queries.

    ``push`` records the core vector at a window boundary (a read-only
    copy — retained history cannot be corrupted through the returned
    references); ``asof(t)`` returns the snapshot at the latest retained
    boundary with boundary-time <= t — an O(log capacity) searchsorted
    plus an O(1) vector reference, independent of graph size or stream
    length. Callers that want to mutate the result must copy it."""

    def __init__(self, capacity: int = 16):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = int(capacity)
        self._times: list[float] = []
        self._cores: list[np.ndarray] = []

    def __len__(self) -> int:
        return len(self._times)

    @property
    def times(self) -> np.ndarray:
        """Retained boundary times, oldest first."""
        return np.asarray(self._times, np.float64)

    def push(self, t: float, core: np.ndarray) -> None:
        t = float(t)
        if self._times and t < self._times[-1]:
            raise ValueError("checkpoint times must be non-decreasing")
        snap = np.asarray(core, np.int32).copy()
        snap.setflags(write=False)
        self._times.append(t)
        self._cores.append(snap)
        if len(self._times) > self.capacity:
            del self._times[0], self._cores[0]

    def asof(self, t: float) -> tuple[float, np.ndarray]:
        """(boundary_time, core) at the latest boundary <= t."""
        if not self._times:
            raise KeyError("no checkpoints retained")
        i = int(np.searchsorted(self._times, float(t), side="right")) - 1
        if i < 0:
            raise KeyError(
                f"t={t} predates the oldest retained boundary "
                f"({self._times[0]}); increase the ring capacity")
        return self._times[i], self._cores[i]


class KCoreServer:
    """Serving facade over the incremental maintenance engine."""

    def __init__(self, g: Graph | None = None,
                 config: StreamingConfig = StreamingConfig(),
                 kcore_config: KCoreConfig = KCoreConfig(),
                 mesh=None, axis_names=("data",),
                 windowed: WindowedKCoreEngine | None = None,
                 asof_capacity: int = 16):
        if (g is None) == (windowed is None):
            raise ValueError("pass exactly one of g / windowed")
        if windowed is not None:
            if (mesh is not None or axis_names != ("data",)
                    or config != StreamingConfig()
                    or kcore_config != KCoreConfig()):
                raise ValueError(
                    "windowed mode: config/kcore_config/mesh/axis_names "
                    "belong to the WindowedKCoreEngine — pass them to its "
                    "constructor, the server would silently ignore them")
            self.windowed = windowed
            self.engine = windowed.engine
        else:
            self.windowed = None
            self.engine = StreamingKCoreEngine(g, config, kcore_config,
                                               mesh=mesh,
                                               axis_names=axis_names)
        self.asof_ring = CoreCheckpointRing(asof_capacity)
        self.queries_served = 0
        self.clients_answered = 0     # total vertex ids answered
        self.updates_applied = 0
        self.update_messages = 0
        self.update_rounds = 0
        self.query_wall_s = 0.0
        self.update_wall_s = 0.0
        # per-server registry (NOT the process default): several servers in
        # one process — a pytest run, an A/B bench — must not merge their
        # latency distributions
        self.metrics = MetricsRegistry()
        # pre-register every op so stats()/latency()/the scrape endpoint
        # expose a STABLE schema: zero-request ops show count 0 / null
        # quantiles instead of a missing key (dashboards key on op names)
        for op in self.OPS:
            self.metrics.counter("server_requests_total", op=op)
            self.metrics.histogram("server_request_seconds", op=op)

    OPS = ("core", "in_kcore", "members", "max_k", "core_asof", "update",
           "advance_window")

    def _observe(self, op: str, wall_s: float) -> None:
        self.metrics.counter("server_requests_total", op=op).inc()
        self.metrics.histogram("server_request_seconds", op=op).observe(wall_s)

    # ---------------- queries (reads of the maintained fixpoint) -------- #
    @property
    def core(self) -> np.ndarray:
        return self.engine.core

    def core_number(self, vertices) -> np.ndarray:
        v = np.asarray(vertices, np.int64).reshape(-1)
        self._check_ids(v)
        return self.core[v]

    def in_kcore(self, vertices, k: int) -> np.ndarray:
        return self.core_number(vertices) >= int(k)

    def kcore_members(self, k: int) -> np.ndarray:
        return np.flatnonzero(self.core >= int(k))

    def max_k(self) -> int:
        return int(self.core.max()) if self.core.size else 0

    def _check_ids(self, v: np.ndarray) -> None:
        # engine.n is O(1); engine.graph would materialize the full CSR
        if v.size and (v.min() < 0 or v.max() >= self.engine.n):
            raise IndexError("vertex id out of range")

    # ---------------- as-of queries (temporal mode) --------------------- #
    def core_asof(self, t: float, vertices=None) -> tuple[float, np.ndarray]:
        """Core numbers at time ``t``: the vector checkpointed at the
        latest retained window boundary <= t (KeyError if t predates the
        ring). Returns (boundary_time, cores)."""
        if t is None:
            raise ValueError("core_asof requires t")
        bt, core = self.asof_ring.asof(t)
        if vertices is None:
            return bt, core
        v = np.asarray(vertices, np.int64).reshape(-1)
        self._check_ids(v)
        return bt, core[v]

    def asof_boundaries(self) -> np.ndarray:
        """Boundary times currently answerable by ``core_asof``."""
        return self.asof_ring.times

    # ---------------- updates ------------------------------------------ #
    def update(self, batch: EdgeBatch) -> BatchResult:
        if self.windowed is not None:
            # mutating the engine behind the window's edge-set bookkeeping
            # would silently corrupt every later boundary delta
            raise ValueError("windowed mode: the event stream owns the "
                             "graph — advance_window() instead of update()")
        t0 = time.perf_counter()
        with _trace.span("server.update"):
            res = self.engine.apply_batch(batch)
        dt = time.perf_counter() - t0
        self.update_wall_s += dt
        self.updates_applied += 1
        self.update_messages += res.total_messages
        self.update_rounds += res.rounds
        self._observe("update", dt)
        return res

    def advance_window(self, k: int = 1) -> WindowStep:
        """Temporal mode: slide the window k strides, re-converge, and
        checkpoint the boundary's core vector into the as-of ring."""
        if self.windowed is None:
            raise ValueError("server was not constructed over a "
                             "WindowedKCoreEngine")
        t0 = time.perf_counter()
        ws = self.windowed.advance(k)
        dt = time.perf_counter() - t0
        self.update_wall_s += dt
        self.updates_applied += 1
        self.update_messages += ws.result.total_messages
        self.update_rounds += ws.result.rounds
        self.asof_ring.push(ws.t_hi, ws.result.core)
        self._observe("advance_window", dt)
        return ws

    # ---------------- request loop ------------------------------------- #
    def serve(self, requests: Iterable[Request]) -> list[Response]:
        out = []
        for req in requests:
            t0 = time.perf_counter()
            with _trace.span("serve.request", op=req.op):
                if req.op == "core":
                    payload = self.core_number(req.vertices)
                    self.clients_answered += payload.size
                elif req.op == "in_kcore":
                    payload = self.in_kcore(req.vertices, req.k)
                    self.clients_answered += payload.size
                elif req.op == "members":
                    payload = self.kcore_members(req.k)
                elif req.op == "max_k":
                    payload = self.max_k()
                elif req.op == "core_asof":
                    payload = self.core_asof(req.t, req.vertices)
                    self.clients_answered += payload[1].size
                elif req.op == "update":
                    payload = self.update(req.batch)
                else:
                    raise ValueError(f"unknown op {req.op!r}")
            dt = time.perf_counter() - t0
            if req.op != "update":      # update() already tracks its wall
                self.queries_served += 1
                self.query_wall_s += dt
                self._observe(req.op, dt)
            out.append(Response(op=req.op, payload=payload, wall_s=dt))
        return out

    def latency(self) -> dict:
        """Per-op latency summaries (seconds): ``{op: {count, sum, min,
        max, mean, p50, p95, p99}}`` from the per-server histograms."""
        out: dict = {}
        for entries in (
                self.metrics.to_json().get("server_request_seconds") or []):
            snap = {k: v for k, v in entries.items()
                    if k not in ("labels", "type")}
            out[entries["labels"]["op"]] = snap
        return out

    def stats(self) -> dict:
        # walls are RAW float seconds — a typical batched query runs tens of
        # microseconds, so any fixed rounding here would zero real signal;
        # presentation (launch/kcore_serve) formats, this layer measures
        return {
            "n": self.engine.n,
            "m": self.engine.m,
            "max_k": self.max_k(),
            "queries_served": self.queries_served,
            "clients_answered": self.clients_answered,
            "updates_applied": self.updates_applied,
            "update_messages": self.update_messages,
            "update_rounds": self.update_rounds,
            "query_wall_s": self.query_wall_s,
            "update_wall_s": self.update_wall_s,
            "asof_boundaries": len(self.asof_ring),
            "latency": self.latency(),
        }
