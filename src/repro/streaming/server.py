"""Core-number query server: update batches interleaved with batched queries.

Models the paper's million-client scenario from the serving side: clients do
not run the decomposition, they ask a maintained index. The server owns a
StreamingKCoreEngine; updates mutate the graph and incrementally re-converge,
queries are O(1)/O(n) numpy reads of the maintained fixpoint — so query
latency is decoupled from graph size and churn entirely.

Request/Response are plain dataclasses (not wire formats): launch/kcore_serve
drives the loop from a CLI, and a real transport would marshal the same ops.

Supported ops
  * ``core``      — core numbers for a batch of vertex ids;
  * ``in_kcore``  — k-core membership for a batch of vertex ids;
  * ``members``   — all vertices of the k-core;
  * ``max_k``     — the degeneracy (largest non-empty k);
  * ``update``    — apply an EdgeBatch through the incremental engine.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable

import numpy as np

from repro.core.kcore import KCoreConfig
from repro.graph.structs import Graph
from repro.streaming.delta import EdgeBatch
from repro.streaming.engine import (BatchResult, StreamingConfig,
                                    StreamingKCoreEngine)


@dataclasses.dataclass(frozen=True)
class Request:
    op: str                       # core | in_kcore | members | max_k | update
    vertices: np.ndarray | None = None   # core / in_kcore
    k: int | None = None                 # in_kcore / members
    batch: EdgeBatch | None = None       # update


@dataclasses.dataclass
class Response:
    op: str
    payload: Any
    wall_s: float


class KCoreServer:
    """Serving facade over the incremental maintenance engine."""

    def __init__(self, g: Graph, config: StreamingConfig = StreamingConfig(),
                 kcore_config: KCoreConfig = KCoreConfig(),
                 mesh=None, axis_names=("data",)):
        self.engine = StreamingKCoreEngine(g, config, kcore_config,
                                           mesh=mesh, axis_names=axis_names)
        self.queries_served = 0
        self.clients_answered = 0     # total vertex ids answered
        self.updates_applied = 0
        self.update_messages = 0
        self.update_rounds = 0
        self.query_wall_s = 0.0
        self.update_wall_s = 0.0

    # ---------------- queries (reads of the maintained fixpoint) -------- #
    @property
    def core(self) -> np.ndarray:
        return self.engine.core

    def core_number(self, vertices) -> np.ndarray:
        v = np.asarray(vertices, np.int64).reshape(-1)
        self._check_ids(v)
        return self.core[v]

    def in_kcore(self, vertices, k: int) -> np.ndarray:
        return self.core_number(vertices) >= int(k)

    def kcore_members(self, k: int) -> np.ndarray:
        return np.flatnonzero(self.core >= int(k))

    def max_k(self) -> int:
        return int(self.core.max()) if self.core.size else 0

    def _check_ids(self, v: np.ndarray) -> None:
        # engine.n is O(1); engine.graph would materialize the full CSR
        if v.size and (v.min() < 0 or v.max() >= self.engine.n):
            raise IndexError("vertex id out of range")

    # ---------------- updates ------------------------------------------ #
    def update(self, batch: EdgeBatch) -> BatchResult:
        t0 = time.perf_counter()
        res = self.engine.apply_batch(batch)
        self.update_wall_s += time.perf_counter() - t0
        self.updates_applied += 1
        self.update_messages += res.total_messages
        self.update_rounds += res.rounds
        return res

    # ---------------- request loop ------------------------------------- #
    def serve(self, requests: Iterable[Request]) -> list[Response]:
        out = []
        for req in requests:
            t0 = time.perf_counter()
            if req.op == "core":
                payload = self.core_number(req.vertices)
                self.clients_answered += payload.size
            elif req.op == "in_kcore":
                payload = self.in_kcore(req.vertices, req.k)
                self.clients_answered += payload.size
            elif req.op == "members":
                payload = self.kcore_members(req.k)
            elif req.op == "max_k":
                payload = self.max_k()
            elif req.op == "update":
                payload = self.update(req.batch)
            else:
                raise ValueError(f"unknown op {req.op!r}")
            dt = time.perf_counter() - t0
            if req.op != "update":      # update() already tracks its wall
                self.queries_served += 1
                self.query_wall_s += dt
            out.append(Response(op=req.op, payload=payload, wall_s=dt))
        return out

    def stats(self) -> dict:
        return {
            "n": self.engine.n,
            "m": self.engine.m,
            "max_k": self.max_k(),
            "queries_served": self.queries_served,
            "clients_answered": self.clients_answered,
            "updates_applied": self.updates_applied,
            "update_messages": self.update_messages,
            "update_rounds": self.update_rounds,
            "query_wall_s": round(self.query_wall_s, 4),
            "update_wall_s": round(self.update_wall_s, 4),
        }
