"""Core-number query server: update batches interleaved with batched queries.

Models the paper's million-client scenario from the serving side: clients do
not run the decomposition, they ask a maintained index. The server owns a
StreamingKCoreEngine; updates mutate the graph and incrementally re-converge,
queries are O(1)/O(n) numpy reads of the maintained fixpoint — so query
latency is decoupled from graph size and churn entirely.

Request/Response are plain dataclasses (not wire formats): launch/kcore_serve
drives the loop from a CLI, and a real transport would marshal the same ops.

Supported ops
  * ``core``      — core numbers for a batch of vertex ids;
  * ``in_kcore``  — k-core membership for a batch of vertex ids;
  * ``members``   — all vertices of the k-core;
  * ``max_k``     — the degeneracy (largest non-empty k);
  * ``update``    — apply an EdgeBatch through the incremental engine;
  * ``core_asof`` — core numbers AT TIME t, answered from the ring of
    core vectors checkpointed at window boundaries (temporal replay mode,
    repro.temporal): O(1) per lookup for any retained boundary.

Every request's wall-clock is observed into a PER-SERVER metrics registry
(repro.obs.metrics — per-server so tests/processes running several servers
never merge their latency distributions): ``stats()`` reports p50/p95/p99
seconds per op under ``"latency"``, raw-float cumulative walls (callers
format; rounding here would destroy microsecond query walls), and the
registry itself is exposed as ``server.metrics`` for JSON/Prometheus
export. When span tracing is live each serve/update/advance also emits a
``serve.request`` / ``server.update`` / ``window.advance`` span.

A server can be constructed over a static Graph (churn arrives as explicit
``update`` batches) or over a ``WindowedKCoreEngine`` (temporal mode:
``advance_window`` slides the window, and every boundary's core vector is
checkpointed into the as-of ring).
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Any, Iterable

import numpy as np

from repro.core.kcore import KCoreConfig
from repro.graph.structs import Graph
from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry
from repro.streaming.delta import EdgeBatch
from repro.streaming.engine import (BatchResult, StreamingConfig,
                                    StreamingKCoreEngine)

if TYPE_CHECKING:   # temporal depends on streaming, never the reverse
    from repro.temporal.window import WindowedKCoreEngine, WindowStep


@dataclasses.dataclass(frozen=True)
class Request:
    op: str          # core | in_kcore | members | max_k | update | core_asof
    vertices: np.ndarray | None = None   # core / in_kcore / core_asof
    k: int | None = None                 # in_kcore / members
    batch: EdgeBatch | None = None       # update
    t: float | None = None               # core_asof


@dataclasses.dataclass
class Response:
    op: str
    payload: Any
    wall_s: float
    # structured failure: a malformed request (bad vertex id, missing
    # argument, unknown op) yields payload=None + this message instead of
    # an exception — a worker pool must never die on a bad request, and a
    # transport would marshal this field, not a traceback
    error: str | None = None
    # snapshot version the read was answered from (concurrent front end
    # only; None for the sequential serve loop)
    version: int | None = None

    @property
    def ok(self) -> bool:
        return self.error is None


def _asof_lookup(times, cores, t: float) -> tuple[float, np.ndarray]:
    """Shared as-of search over parallel (times, cores) sequences."""
    if not times:
        raise KeyError("no checkpoints retained")
    i = int(np.searchsorted(np.asarray(times), float(t),
                            side="right")) - 1
    if i < 0:
        raise KeyError(
            f"t={t} predates the oldest retained boundary "
            f"({times[0]}); increase the ring capacity")
    return times[i], cores[i]


@dataclasses.dataclass(frozen=True)
class AsofView:
    """Immutable as-of store: a frozen (times, cores) snapshot of a
    CoreCheckpointRing. Core arrays are the ring's read-only copies, so
    the view can be shared across reader threads freely."""

    times: tuple[float, ...]
    cores: tuple[np.ndarray, ...]

    def __len__(self) -> int:
        return len(self.times)

    def asof(self, t: float) -> tuple[float, np.ndarray]:
        return _asof_lookup(self.times, self.cores, t)


class CoreCheckpointRing:
    """Bounded ring of (t, core) snapshots for as-of queries.

    ``push`` records the core vector at a window boundary (a read-only
    copy — retained history cannot be corrupted through the returned
    references); ``asof(t)`` returns the snapshot at the latest retained
    boundary with boundary-time <= t — an O(log capacity) searchsorted
    plus an O(1) vector reference, independent of graph size or stream
    length. Callers that want to mutate the result must copy it."""

    def __init__(self, capacity: int = 16):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = int(capacity)
        self._times: list[float] = []
        self._cores: list[np.ndarray] = []

    def __len__(self) -> int:
        return len(self._times)

    @property
    def times(self) -> np.ndarray:
        """Retained boundary times, oldest first."""
        return np.asarray(self._times, np.float64)

    def push(self, t: float, core: np.ndarray) -> None:
        t = float(t)
        if self._times and t < self._times[-1]:
            raise ValueError("checkpoint times must be non-decreasing")
        snap = np.asarray(core, np.int32).copy()
        snap.setflags(write=False)
        self._times.append(t)
        self._cores.append(snap)
        if len(self._times) > self.capacity:
            del self._times[0], self._cores[0]

    def asof(self, t: float) -> tuple[float, np.ndarray]:
        """(boundary_time, core) at the latest boundary <= t."""
        return _asof_lookup(self._times, self._cores, t)

    def snapshot(self) -> "AsofView":
        """Immutable view of the currently retained boundaries.

        O(len) tuple copy of the (already read-only) snapshot references —
        the concurrent server freezes one of these into each published
        ``CoreSnapshot`` so as-of reads stay consistent with the core
        vector they were flipped with, no matter how far the writer's ring
        has advanced since."""
        return AsofView(tuple(self._times), tuple(self._cores))

    # ------------------------------------------------------------------ #
    def state_dict(self) -> dict:
        """Checkpointable pytree: boundary times (k,) + cores stacked to
        (k, n). Fixed leaf COUNT regardless of occupancy, so a restore
        target's structure never depends on how full the ring was."""
        if self._cores:
            cores = np.stack([np.asarray(c, np.int32) for c in self._cores])
        else:
            cores = np.zeros((0, 0), np.int32)
        return {"times": np.asarray(self._times, np.float64), "cores": cores}

    def load_state(self, state: dict) -> None:
        """Restore retained boundaries in place (capacity is config)."""
        times = np.asarray(state["times"], np.float64).reshape(-1)
        cores = np.asarray(state["cores"], np.int32)
        keep = min(times.shape[0], self.capacity)
        times, cores = times[-keep:] if keep else times[:0], \
            cores[-keep:] if keep else cores[:0]
        self._times, self._cores = [], []
        for t, core in zip(times.tolist(), cores):
            snap = core.copy()
            snap.setflags(write=False)
            self._times.append(float(t))
            self._cores.append(snap)


class KCoreServer:
    """Serving facade over the incremental maintenance engine."""

    def __init__(self, g: Graph | None = None,
                 config: StreamingConfig = StreamingConfig(),
                 kcore_config: KCoreConfig = KCoreConfig(),
                 mesh=None, axis_names=("data",),
                 windowed: WindowedKCoreEngine | None = None,
                 asof_capacity: int = 16):
        if (g is None) == (windowed is None):
            raise ValueError("pass exactly one of g / windowed")
        if windowed is not None:
            if (mesh is not None or axis_names != ("data",)
                    or config != StreamingConfig()
                    or kcore_config != KCoreConfig()):
                raise ValueError(
                    "windowed mode: config/kcore_config/mesh/axis_names "
                    "belong to the WindowedKCoreEngine — pass them to its "
                    "constructor, the server would silently ignore them")
            self.windowed = windowed
            self.engine = windowed.engine
        else:
            self.windowed = None
            self.engine = StreamingKCoreEngine(g, config, kcore_config,
                                               mesh=mesh,
                                               axis_names=axis_names)
        self.asof_ring = CoreCheckpointRing(asof_capacity)
        self.queries_served = 0
        self.clients_answered = 0     # total vertex ids answered
        self.errors_returned = 0      # malformed requests answered with
        self.updates_applied = 0      # a structured error Response
        self.update_messages = 0
        self.update_rounds = 0
        self.query_wall_s = 0.0
        self.update_wall_s = 0.0
        # per-server registry (NOT the process default): several servers in
        # one process — a pytest run, an A/B bench — must not merge their
        # latency distributions
        self.metrics = MetricsRegistry()
        # pre-register every op so stats()/latency()/the scrape endpoint
        # expose a STABLE schema: zero-request ops show count 0 / null
        # quantiles instead of a missing key (dashboards key on op names)
        for op in self.OPS:
            self.metrics.counter("server_requests_total", op=op)
            self.metrics.histogram("server_request_seconds", op=op)
            self.metrics.counter("server_errors_total", op=op)
        self.metrics.counter("server_errors_total", op="unknown")

    OPS = ("core", "in_kcore", "members", "max_k", "core_asof", "update",
           "advance_window")

    def _observe(self, op: str, wall_s: float) -> None:
        self.metrics.counter("server_requests_total", op=op).inc()
        self.metrics.histogram("server_request_seconds", op=op).observe(wall_s)

    # ---------------- queries (reads of the maintained fixpoint) -------- #
    @property
    def core(self) -> np.ndarray:
        return self.engine.core

    def core_number(self, vertices) -> np.ndarray:
        v = np.asarray(vertices, np.int64).reshape(-1)
        self._check_ids(v)
        return self.core[v]

    def in_kcore(self, vertices, k: int) -> np.ndarray:
        return self.core_number(vertices) >= int(k)

    def kcore_members(self, k: int) -> np.ndarray:
        return np.flatnonzero(self.core >= int(k))

    def max_k(self) -> int:
        return int(self.core.max()) if self.core.size else 0

    def _check_ids(self, v: np.ndarray) -> None:
        # engine.n is O(1); engine.graph would materialize the full CSR
        if v.size and (v.min() < 0 or v.max() >= self.engine.n):
            raise IndexError("vertex id out of range")

    # ---------------- as-of queries (temporal mode) --------------------- #
    def core_asof(self, t: float, vertices=None) -> tuple[float, np.ndarray]:
        """Core numbers at time ``t``: the vector checkpointed at the
        latest retained window boundary <= t (KeyError if t predates the
        ring). Returns (boundary_time, cores)."""
        if t is None:
            raise ValueError("core_asof requires t")
        if vertices is None:
            bt, core = self.asof_ring.asof(t)
            return bt, core
        # ids are validated BEFORE the ring lookup: a bad request must not
        # touch retained state at all (and in the concurrent front end,
        # must fail before a snapshot is even acquired)
        v = np.asarray(vertices, np.int64).reshape(-1)
        self._check_ids(v)
        bt, core = self.asof_ring.asof(t)
        return bt, core[v]

    def asof_boundaries(self) -> np.ndarray:
        """Boundary times currently answerable by ``core_asof``."""
        return self.asof_ring.times

    # ---------------- updates ------------------------------------------ #
    def update(self, batch: EdgeBatch) -> BatchResult:
        if self.windowed is not None:
            # mutating the engine behind the window's edge-set bookkeeping
            # would silently corrupt every later boundary delta
            raise ValueError("windowed mode: the event stream owns the "
                             "graph — advance_window() instead of update()")
        t0 = time.perf_counter()
        with _trace.span("server.update"):
            res = self.engine.apply_batch(batch)
        dt = time.perf_counter() - t0
        self.update_wall_s += dt
        self.updates_applied += 1
        self.update_messages += res.total_messages
        self.update_rounds += res.rounds
        self._observe("update", dt)
        return res

    def advance_window(self, k: int = 1) -> WindowStep:
        """Temporal mode: slide the window k strides, re-converge, and
        checkpoint the boundary's core vector into the as-of ring."""
        if self.windowed is None:
            raise ValueError("server was not constructed over a "
                             "WindowedKCoreEngine")
        t0 = time.perf_counter()
        ws = self.windowed.advance(k)
        dt = time.perf_counter() - t0
        self.update_wall_s += dt
        self.updates_applied += 1
        self.update_messages += ws.result.total_messages
        self.update_rounds += ws.result.rounds
        self.asof_ring.push(ws.t_hi, ws.result.core)
        self._observe("advance_window", dt)
        return ws

    # ---------------- request loop ------------------------------------- #
    def validate(self, req: Request) -> np.ndarray | None:
        """Validate a request BEFORE any state is touched.

        Returns the normalized (int64, flat) vertex array for ops that
        carry one, raising ValueError/IndexError/TypeError on a malformed
        request. Centralised so every front end — the sequential ``serve``
        loop here and the snapshot readers in streaming/concurrent.py —
        rejects bad requests without acquiring a snapshot or mutating
        anything.
        """
        if req.op not in self.OPS:
            raise ValueError(f"unknown op {req.op!r}")
        v = None
        if req.op in ("core", "in_kcore", "core_asof"):
            if req.vertices is None and req.op != "core_asof":
                raise ValueError(f"{req.op} requires vertices")
            if req.vertices is not None:
                v = np.asarray(req.vertices, np.int64).reshape(-1)
                self._check_ids(v)
        if req.op in ("in_kcore", "members") and req.k is None:
            raise ValueError(f"{req.op} requires k")
        if req.op == "core_asof" and req.t is None:
            raise ValueError("core_asof requires t")
        if req.op == "update" and req.batch is None:
            raise ValueError("update requires batch")
        return v

    def serve(self, requests: Iterable[Request]) -> list[Response]:
        out = []
        for req in requests:
            t0 = time.perf_counter()
            error = None
            payload = None
            with _trace.span("serve.request", op=req.op):
                try:
                    self.validate(req)
                    if req.op == "core":
                        payload = self.core_number(req.vertices)
                        self.clients_answered += payload.size
                    elif req.op == "in_kcore":
                        payload = self.in_kcore(req.vertices, req.k)
                        self.clients_answered += payload.size
                    elif req.op == "members":
                        payload = self.kcore_members(req.k)
                    elif req.op == "max_k":
                        payload = self.max_k()
                    elif req.op == "core_asof":
                        payload = self.core_asof(req.t, req.vertices)
                        self.clients_answered += payload[1].size
                    else:   # update (validate() rejected every other op)
                        payload = self.update(req.batch)
                except (ValueError, IndexError, KeyError, TypeError) as exc:
                    # malformed request -> structured error Response; a
                    # request must never raise through the serving loop
                    # (or, concurrently, through the worker pool)
                    error = str(exc)
                    self.errors_returned += 1
                    op = req.op if req.op in self.OPS else "unknown"
                    self.metrics.counter("server_errors_total", op=op).inc()
            dt = time.perf_counter() - t0
            if error is None and req.op != "update":
                # update() already tracks its wall; errors are counted
                # separately so latency histograms stay reads-only
                self.queries_served += 1
                self.query_wall_s += dt
                self._observe(req.op, dt)
            out.append(Response(op=req.op, payload=payload, wall_s=dt,
                                error=error))
        return out

    def latency(self) -> dict:
        """Per-op latency summaries (seconds): ``{op: {count, sum, min,
        max, mean, p50, p95, p99}}`` from the per-server histograms."""
        out: dict = {}
        for entries in (
                self.metrics.to_json().get("server_request_seconds") or []):
            snap = {k: v for k, v in entries.items()
                    if k not in ("labels", "type")}
            out[entries["labels"]["op"]] = snap
        return out

    def stats(self) -> dict:
        # walls are RAW float seconds — a typical batched query runs tens of
        # microseconds, so any fixed rounding here would zero real signal;
        # presentation (launch/kcore_serve) formats, this layer measures
        return {
            "n": self.engine.n,
            "m": self.engine.m,
            "max_k": self.max_k(),
            "queries_served": self.queries_served,
            "clients_answered": self.clients_answered,
            "errors_returned": self.errors_returned,
            "updates_applied": self.updates_applied,
            "update_messages": self.update_messages,
            "update_rounds": self.update_rounds,
            "query_wall_s": self.query_wall_s,
            "update_wall_s": self.update_wall_s,
            "asof_boundaries": len(self.asof_ring),
            "latency": self.latency(),
        }

    # ---------------- warm restart ------------------------------------- #
    def state_dict(self) -> dict:
        """Checkpointable pytree of everything a warm restart needs.

        Windowed mode captures the full windowed engine (inner streaming
        engine + window cursor); static mode the streaming engine alone.
        The as-of ring rides along so historical ``core_asof`` boundaries
        survive a restart. Counters/latency are NOT state — a restarted
        server reports fresh telemetry. Feed to
        ``repro.checkpoint.save_checkpoint``; restore onto a compatibly
        CONSTRUCTED server with ``load_state_dict`` (config, mode, and
        mesh are construction arguments, not state).
        """
        if self.windowed is not None:
            state = {"windowed": self.windowed.state_dict()}
        else:
            state = {"engine": self.engine.state_dict()}
        state["asof"] = self.asof_ring.state_dict()
        return state

    def load_state_dict(self, state: dict) -> None:
        """Restore ``state_dict`` output in place (same serving mode).

        The restored cores ARE the fixpoint of the restored CSR, so no
        decomposition runs — the server resumes the stream exactly where
        the checkpointed one stopped (continuation is bit-equal in cores
        AND message bills; tested in tests/test_concurrent_serving.py).
        """
        if self.windowed is not None:
            if "windowed" not in state:
                raise ValueError("checkpoint was taken from a static "
                                 "server; this one is windowed")
            self.windowed.load_state_dict(state["windowed"])
            self.engine = self.windowed.engine
        else:
            if "engine" not in state:
                raise ValueError("checkpoint was taken from a windowed "
                                 "server; this one is static")
            self.engine = StreamingKCoreEngine.from_state_dict(
                state["engine"], config=self.engine.config,
                mesh=self.engine.mesh, axis_names=self.engine.axis_names)
        self.asof_ring.load_state(state["asof"])
