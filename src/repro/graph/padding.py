"""Shared shape-padding helpers.

Every layer that feeds jit-compiled programs pads its arrays so XLA sees
few distinct shapes: shard blocks round up to a multiple (``round_up``),
and streaming/temporal arrays whose sizes drift per batch round up to
powers of two (``next_pow2``) so a whole churn stream compiles O(log)
distinct signatures instead of one per size. These two functions are THE
padding policy — graph/structs, graph/partition, and streaming/engine all
import from here rather than growing private copies.
"""

from __future__ import annotations


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= x (identity when mult <= 0)."""
    return ((x + mult - 1) // mult) * mult if mult > 0 else x


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (1 for x <= 1)."""
    return 1 << max(int(x) - 1, 0).bit_length()
