"""Core graph data structures.

The paper's Go implementation stores, per vertex-goroutine, a neighbor channel
list. On TPU we replace pointer-chasing with two dense layouts:

  * COO/CSR ("segment") layout — arcs (both directions of every undirected
    edge) sorted by source, with CSR offsets. All vertex-centric updates are
    `jax.ops.segment_sum` over the arc array.
  * Degree-bucketed ELL layout — vertices bucketed by degree, neighbor lists
    padded to the bucket width, producing rectangular (rows × width) tiles
    that map onto VMEM/VPU. This feeds the Pallas `kcore_hindex` kernel.

Construction follows the paper's dataCleanse rules (§III.A / §IV.B):
no self-loops, no multi-edges, directed input symmetrized to undirected.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graph.padding import round_up as _round_up  # shared padding policy


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph in sorted-COO + CSR form (numpy, host-side)."""

    n: int                 # number of vertices
    m: int                 # number of undirected edges
    src: np.ndarray        # (2m,) int32 — arc sources, sorted ascending
    dst: np.ndarray        # (2m,) int32 — arc destinations
    offsets: np.ndarray    # (n+1,) int64 — CSR row offsets into src/dst
    deg: np.ndarray        # (n,) int32  — vertex degrees

    # ------------------------------------------------------------------ #
    @classmethod
    def from_edges(cls, edges: np.ndarray | Sequence[tuple[int, int]],
                   n: int | None = None) -> "Graph":
        """Build from an (E, 2) array of (possibly directed / duplicated)
        edges, applying the paper's dataCleanse rules."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if edges.size == 0:
            nn = int(n or 0)
            return cls(
                n=nn, m=0,
                src=np.zeros(0, np.int32), dst=np.zeros(0, np.int32),
                offsets=np.zeros(nn + 1, np.int64), deg=np.zeros(nn, np.int32),
            )
        # Rule 1: a vertex cannot connect to itself.
        edges = edges[edges[:, 0] != edges[:, 1]]
        # Rule 3 (symmetrize): undirected — keep canonical (min, max) ...
        canon = np.stack([edges.min(axis=1), edges.max(axis=1)], axis=1)
        # Rule 2: each pair connects with at most one edge.
        canon = np.unique(canon, axis=0)
        nn = int(n if n is not None else (canon.max() + 1 if canon.size else 0))
        m = canon.shape[0]
        # Both arc directions, sorted by src (ties by dst for determinism).
        src = np.concatenate([canon[:, 0], canon[:, 1]])
        dst = np.concatenate([canon[:, 1], canon[:, 0]])
        order = np.lexsort((dst, src))
        src, dst = src[order].astype(np.int32), dst[order].astype(np.int32)
        deg = np.bincount(src, minlength=nn).astype(np.int32)
        offsets = np.zeros(nn + 1, np.int64)
        np.cumsum(deg, out=offsets[1:])
        return cls(n=nn, m=m, src=src, dst=dst, offsets=offsets, deg=deg)

    # ------------------------------------------------------------------ #
    @property
    def num_arcs(self) -> int:
        return int(self.src.shape[0])

    @property
    def max_deg(self) -> int:
        return int(self.deg.max()) if self.n else 0

    @property
    def avg_deg(self) -> float:
        return float(self.deg.mean()) if self.n else 0.0

    def neighbors(self, u: int) -> np.ndarray:
        return self.dst[self.offsets[u]:self.offsets[u + 1]]

    def validate(self) -> None:
        assert self.src.shape == self.dst.shape
        assert self.num_arcs == 2 * self.m
        assert (self.src[:-1] <= self.src[1:]).all(), "arcs must be sorted by src"
        assert int(self.deg.sum()) == self.num_arcs
        assert self.offsets[-1] == self.num_arcs


# ---------------------------------------------------------------------- #
# Shard padding
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class PaddedGraph:
    """Graph padded so vertex count and arc count divide a shard count.

    Padding arcs use src = dst = n_pad - 1 only if a padding vertex exists;
    they always point at the *sentinel* vertex (index ``n_real``.. are
    padding, degree 0, estimate 0) so they never change a real count:
    a padding arc contributes to the segment of a padding vertex only.
    """

    n_real: int
    n_pad: int            # padded vertex count (multiple of shards)
    num_arcs_real: int
    num_arcs_pad: int     # padded arc count (multiple of shards)
    src: np.ndarray       # (num_arcs_pad,) int32
    dst: np.ndarray       # (num_arcs_pad,) int32
    deg: np.ndarray       # (n_pad,) int32, zeros in padding
    arc_mask: np.ndarray  # (num_arcs_pad,) bool — True for real arcs


def pad_graph_for_shards(g: Graph, n_shards: int) -> PaddedGraph:
    """Pad vertices and arcs to multiples of ``n_shards``.

    Arc padding is appended at the end with src pointing into the padding
    vertex range, keeping the src-sorted property (padding vertices have the
    largest indices).
    """
    n_pad = max(_round_up(g.n, n_shards), n_shards)
    arcs_pad = max(_round_up(g.num_arcs, n_shards), n_shards)
    extra = arcs_pad - g.num_arcs
    sentinel = n_pad - 1  # a padding vertex (deg 0) unless n_pad == n; then
    # fall back to a self-arc on the last vertex which is masked & points to
    # a zero-degree contribution via arc_mask handling in the engine.
    src = np.concatenate([g.src, np.full(extra, sentinel, np.int32)])
    dst = np.concatenate([g.dst, np.full(extra, sentinel, np.int32)])
    deg = np.concatenate([g.deg, np.zeros(n_pad - g.n, np.int32)])
    mask = np.concatenate([np.ones(g.num_arcs, bool), np.zeros(extra, bool)])
    return PaddedGraph(
        n_real=g.n, n_pad=n_pad,
        num_arcs_real=g.num_arcs, num_arcs_pad=arcs_pad,
        src=src, dst=dst, deg=deg, arc_mask=mask,
    )


# ---------------------------------------------------------------------- #
# Degree-bucketed ELL layout (Pallas hot path)
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class EllBucket:
    width: int            # padded neighbor-list width (power of two-ish)
    ids: np.ndarray       # (rows,) int32 vertex ids (padded rows use n — the
                          # sentinel row; their results are discarded)
    nbrs: np.ndarray      # (rows, width) int32 neighbor ids, padding = n
    rows_real: int


@dataclasses.dataclass(frozen=True)
class EllGraph:
    """Degree-bucketed ELL: per bucket a dense (rows, width) neighbor table.

    Estimate lookups use an extended estimate vector ``est_ext`` of length
    n + 1 whose last entry is 0 (the sentinel), so padded neighbor slots never
    satisfy ``est >= k`` for k >= 1.
    """

    n: int
    buckets: tuple[EllBucket, ...]

    @property
    def padded_slots(self) -> int:
        return sum(b.nbrs.size for b in self.buckets)

    @property
    def fill_ratio(self) -> float:
        real = sum(int((b.nbrs != self.n).sum()) for b in self.buckets)
        return real / max(self.padded_slots, 1)


def build_ell(g: Graph, widths: Sequence[int] = (8, 32, 128, 512, 2048),
              row_multiple: int = 8) -> EllGraph:
    """Bucket vertices by degree; pad neighbor lists to the bucket width.

    Vertices with degree above the largest width land in a final bucket sized
    to the (row_multiple-rounded) max degree. Degree-0 vertices are skipped —
    their core number is 0 and the engine fixes them up directly.
    """
    widths = sorted(set(int(w) for w in widths))
    if g.n == 0:
        return EllGraph(n=0, buckets=())
    maxd = g.max_deg
    if maxd > widths[-1]:
        widths.append(_round_up(maxd, 128))
    buckets: list[EllBucket] = []
    degs = g.deg
    # Per-arc column index = position of the arc within its source's CSR row.
    arc_col = np.arange(g.num_arcs, dtype=np.int64) - g.offsets[g.src]
    lo = 1
    for w in widths:
        sel = np.where((degs >= lo) & (degs <= w))[0]
        lo = w + 1
        if sel.size == 0:
            continue
        rows = max(_round_up(sel.size, row_multiple), row_multiple)
        ids = np.full(rows, g.n, np.int32)
        ids[: sel.size] = sel.astype(np.int32)
        # Vectorized fill: row index of each selected vertex, gathered per arc.
        row_of = np.full(g.n, -1, np.int64)
        row_of[sel] = np.arange(sel.size)
        arc_sel = row_of[g.src] >= 0
        nbrs = np.full((rows, w), g.n, np.int32)
        nbrs[row_of[g.src[arc_sel]], arc_col[arc_sel]] = g.dst[arc_sel]
        buckets.append(EllBucket(width=w, ids=ids, nbrs=nbrs,
                                 rows_real=int(sel.size)))
    return EllGraph(n=g.n, buckets=tuple(buckets))
