"""Edge/vertex partitioning for the distributed (shard_map) graph engines.

Layout contract (used by core/kcore.py and models/gnn for full-batch runs):

  * Vertices are partitioned into ``n_shards`` contiguous ranges of equal
    (padded) size V = n_pad / n_shards; device d owns vertices
    [d*V, (d+1)*V).
  * Arcs are sorted by src, so each device's *outgoing* arcs form one
    contiguous run. Runs are padded to the max run length A with sentinel
    arcs (src = dst = sentinel vertex in the owner's padding range) so every
    device holds an identical-shape (A,) arc block — the shard_map shape.
  * Per-round cross-device traffic = one all_gather of the (V,)-sharded
    vertex state. Counts (segment sums) are then purely device-local, since
    every arc's source lives on its device.

This mirrors the paper's one-to-one model at pod scale: a device plays the
role of a *district* of vertex-clients; the all_gather is the message
broadcast between districts.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structs import Graph, _round_up


@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    n_shards: int
    n_real: int
    verts_per_shard: int       # V
    arcs_per_shard: int        # A
    src: np.ndarray            # (n_shards, A) int32 — LOCAL vertex index [0, V)
    dst: np.ndarray            # (n_shards, A) int32 — GLOBAL vertex index
    arc_mask: np.ndarray       # (n_shards, A) bool
    deg: np.ndarray            # (n_shards, V) int32
    vert_mask: np.ndarray      # (n_shards, V) bool — True = real vertex

    @property
    def n_pad(self) -> int:
        return self.n_shards * self.verts_per_shard


def shard_graph(g: Graph, n_shards: int, arc_multiple: int = 8) -> ShardedGraph:
    V = max(_round_up(g.n, n_shards) // n_shards, 1)
    n_pad = V * n_shards
    # Arc run per shard.
    bounds = np.searchsorted(g.src, np.arange(0, n_pad + 1, V))
    run_len = np.diff(bounds)
    A = max(_round_up(int(run_len.max()) if len(run_len) else 1, arc_multiple),
            arc_multiple)
    src = np.zeros((n_shards, A), np.int32)
    dst = np.zeros((n_shards, A), np.int32)
    mask = np.zeros((n_shards, A), bool)
    deg = np.zeros((n_shards, V), np.int32)
    vmask = np.zeros((n_shards, V), bool)
    for d in range(n_shards):
        lo, hi = bounds[d], bounds[d + 1]
        k = hi - lo
        # local src index within the shard's vertex range
        src[d, :k] = g.src[lo:hi] - d * V
        dst[d, :k] = g.dst[lo:hi]
        mask[d, :k] = True
        # padding arcs: local sentinel = V-1's padding slot if it exists,
        # else point at local vertex 0 with mask False (engine multiplies by
        # mask before any segment op, so value never matters).
        src[d, k:] = V - 1
        dst[d, k:] = min(d * V + V - 1, n_pad - 1)
        vr_lo, vr_hi = d * V, min((d + 1) * V, g.n)
        if vr_hi > vr_lo:
            deg[d, : vr_hi - vr_lo] = g.deg[vr_lo:vr_hi]
            vmask[d, : vr_hi - vr_lo] = True
    return ShardedGraph(
        n_shards=n_shards, n_real=g.n, verts_per_shard=V, arcs_per_shard=A,
        src=src, dst=dst, arc_mask=mask, deg=deg, vert_mask=vmask,
    )


def balance_report(sg: ShardedGraph) -> dict:
    """Arc-count balance across shards (straggler diagnosis)."""
    real = sg.arc_mask.sum(axis=1)
    return {
        "arcs_per_shard_max": int(real.max()),
        "arcs_per_shard_min": int(real.min()),
        "arcs_per_shard_mean": float(real.mean()),
        "imbalance": float(real.max() / max(real.mean(), 1e-9)),
        "padded_A": sg.arcs_per_shard,
    }
