"""Edge/vertex partitioning for the distributed (shard_map) graph engines.

Layout contract (used by core/kcore.py and models/gnn for full-batch runs):

  * Vertices are partitioned into ``n_shards`` contiguous ranges of equal
    (padded) size V = n_pad / n_shards; device d owns vertices
    [d*V, (d+1)*V).
  * Arcs are sorted by src, so each device's *outgoing* arcs form one
    contiguous run. Runs are padded to the max run length A with sentinel
    arcs (src = dst = sentinel vertex in the owner's padding range) so every
    device holds an identical-shape (A,) arc block — the shard_map shape.
  * Per-round cross-device traffic = one all_gather of the (V,)-sharded
    vertex state. Counts (segment sums) are then purely device-local, since
    every arc's source lives on its device.

This mirrors the paper's one-to-one model at pod scale: a device plays the
role of a *district* of vertex-clients; the all_gather is the message
broadcast between districts.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.padding import next_pow2 as _next_pow2
from repro.graph.padding import round_up as _round_up
from repro.graph.structs import Graph


@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    n_shards: int
    n_real: int
    verts_per_shard: int       # V
    arcs_per_shard: int        # A
    src: np.ndarray            # (n_shards, A) int32 — LOCAL vertex index [0, V)
    dst: np.ndarray            # (n_shards, A) int32 — GLOBAL vertex index
    arc_mask: np.ndarray       # (n_shards, A) bool
    deg: np.ndarray            # (n_shards, V) int32
    vert_mask: np.ndarray      # (n_shards, V) bool — True = real vertex

    @property
    def n_pad(self) -> int:
        return self.n_shards * self.verts_per_shard


def shard_layout(n: int, src: np.ndarray, n_shards: int,
                 arc_multiple: int = 8, pow2: bool = False,
                 min_arcs_per_shard: int = 0) -> tuple[int, int, np.ndarray]:
    """The shared block geometry of the layout contract above.

    Returns ``(V, A, bounds)``: per-shard (padded) vertex count V, per-shard
    (padded) arc-block length A, and the ``(n_shards + 1,)`` arc-run bounds
    into the src-sorted arc arrays (shard d owns arcs
    ``[bounds[d], bounds[d+1])``). Shared by the in-memory partitioner
    (``shard_arc_arrays``) and the out-of-core block store
    (``repro.graph.blockstore``) so a spilled block is bit-identical to the
    shard the mesh engines would have staged.
    """
    V = max(_round_up(n, n_shards) // n_shards, 1)
    if pow2:
        V = _next_pow2(V)
    n_pad = V * n_shards
    # Arc run per shard.
    bounds = np.searchsorted(src, np.arange(0, n_pad + 1, V))
    run_len = np.diff(bounds)
    A = max(_round_up(int(run_len.max()) if len(run_len) else 1, arc_multiple),
            arc_multiple)
    if pow2:
        A = _next_pow2(A)
    A = max(A, int(min_arcs_per_shard))
    return V, A, bounds


def shard_arc_arrays(n: int, src: np.ndarray, dst: np.ndarray,
                     arc_mask: np.ndarray, deg: np.ndarray, n_shards: int,
                     arc_multiple: int = 8, pow2: bool = False,
                     min_arcs_per_shard: int = 0) -> ShardedGraph:
    """Shard raw src-sorted arc arrays (the layout contract above).

    ``src`` must be non-decreasing but MAY contain dead slots (``arc_mask``
    False) — the streaming engine's slack-padded CSR storage shards without
    re-sorting because its row-major slot order is already src order. With
    ``pow2`` the per-shard vertex and arc blocks are padded to powers of two
    so jit sees O(log) distinct shapes over a whole update stream.
    ``min_arcs_per_shard`` floors the padded arc block A — the streaming
    engine passes its high-water A so per-batch degree fluctuations never
    shrink the shape (shrinking would mint fresh jit signatures).
    """
    V, A, bounds = shard_layout(n, src, n_shards, arc_multiple=arc_multiple,
                                pow2=pow2,
                                min_arcs_per_shard=min_arcs_per_shard)
    n_pad = V * n_shards
    src_s = np.zeros((n_shards, A), np.int32)
    dst_s = np.zeros((n_shards, A), np.int32)
    mask_s = np.zeros((n_shards, A), bool)
    deg_s = np.zeros((n_shards, V), np.int32)
    vmask = np.zeros((n_shards, V), bool)
    for d in range(n_shards):
        lo, hi = bounds[d], bounds[d + 1]
        k = hi - lo
        # local src index within the shard's vertex range
        src_s[d, :k] = src[lo:hi] - d * V
        dst_s[d, :k] = dst[lo:hi]
        mask_s[d, :k] = arc_mask[lo:hi]
        # padding arcs: local sentinel = V-1's padding slot if it exists,
        # else point at local vertex 0 with mask False (engine multiplies by
        # mask before any segment op, so value never matters).
        src_s[d, k:] = V - 1
        dst_s[d, k:] = min(d * V + V - 1, n_pad - 1)
        vr_lo, vr_hi = d * V, min((d + 1) * V, n)
        if vr_hi > vr_lo:
            deg_s[d, : vr_hi - vr_lo] = deg[vr_lo:vr_hi]
            vmask[d, : vr_hi - vr_lo] = True
    return ShardedGraph(
        n_shards=n_shards, n_real=n, verts_per_shard=V, arcs_per_shard=A,
        src=src_s, dst=dst_s, arc_mask=mask_s, deg=deg_s, vert_mask=vmask,
    )


def shard_graph(g: Graph, n_shards: int, arc_multiple: int = 8) -> ShardedGraph:
    return shard_arc_arrays(g.n, g.src, g.dst,
                            np.ones(g.num_arcs, bool), g.deg, n_shards,
                            arc_multiple=arc_multiple)


def balance_from_counts(real: np.ndarray, padded_A: int) -> dict:
    """Arc-count balance metrics from per-shard live-arc counts.

    ``imbalance`` = max/mean — the straggler factor: a round's wall is the
    slowest shard's, so this is the multiplier block skew costs before it
    shows up in wall-clock. Shared by ``balance_report`` (in-memory shards)
    and the out-of-core block store.
    """
    real = np.asarray(real, np.int64)
    if real.size == 0:
        real = np.zeros(1, np.int64)
    return {
        "arcs_per_shard_max": int(real.max()),
        "arcs_per_shard_min": int(real.min()),
        "arcs_per_shard_mean": float(real.mean()),
        "imbalance": float(real.max() / max(real.mean(), 1e-9)),
        "padded_A": int(padded_A),
    }


def balance_report(sg: ShardedGraph) -> dict:
    """Arc-count balance across shards (straggler diagnosis)."""
    return balance_from_counts(sg.arc_mask.sum(axis=1), sg.arcs_per_shard)
