"""Fanout neighbor sampler (GraphSAGE-style) for the ``minibatch_lg`` shape.

Host-side (numpy) sampling producing fixed-shape padded blocks so the jitted
train step never recompiles. Layout per hop h (fanout f_h):

  nodes[h]   : (N_h,) int32 global ids of frontier nodes (padded with -1)
  edges[h]   : (N_h * f_h, 2) int32 (local_dst_index, local_src_index) pairs
               into nodes[h] / nodes[h+1], padded with (0, 0) + mask

N_0 = batch seeds; N_{h+1} = N_h * f_h. The GNN consumes hops deepest-first.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structs import Graph


@dataclasses.dataclass
class SampledBlock:
    """One hop: messages flow nodes[h+1] (src) -> nodes[h] (dst)."""
    dst_index: np.ndarray   # (E_h,) int32 index into layer-h node array
    src_index: np.ndarray   # (E_h,) int32 index into layer-(h+1) node array
    mask: np.ndarray        # (E_h,) bool


@dataclasses.dataclass
class SampledSubgraph:
    seeds: np.ndarray                 # (B,) int32
    layer_nodes: list[np.ndarray]     # len = hops+1; layer_nodes[0] == seeds
    blocks: list[SampledBlock]        # len = hops
    node_mask: list[np.ndarray]       # per-layer validity


def sample_subgraph(g: Graph, seeds: np.ndarray, fanouts: tuple[int, ...],
                    seed: int = 0) -> SampledSubgraph:
    rng = np.random.default_rng(seed)
    layer_nodes = [seeds.astype(np.int32)]
    node_mask = [seeds >= 0]
    blocks: list[SampledBlock] = []
    for f in fanouts:
        cur = layer_nodes[-1]
        cur_mask = node_mask[-1]
        N = cur.shape[0]
        nxt = np.full(N * f, -1, np.int32)
        dst_index = np.repeat(np.arange(N, dtype=np.int32), f)
        src_index = np.arange(N * f, dtype=np.int32)
        mask = np.zeros(N * f, bool)
        # Vectorized uniform-with-replacement sampling from each CSR row.
        deg = np.where(cur_mask, g.deg[np.where(cur_mask, cur, 0)], 0)
        offs = g.offsets[np.where(cur_mask, cur, 0)]
        r = rng.integers(0, np.maximum(deg, 1)[:, None], size=(N, f))
        picks = g.dst[np.minimum(offs[:, None] + r,
                                 len(g.dst) - 1 if len(g.dst) else 0)] \
            if g.num_arcs else np.zeros((N, f), np.int32)
        valid = np.repeat(((deg > 0) & cur_mask)[:, None], f, axis=1)
        nxt = np.where(valid, picks, -1).reshape(-1).astype(np.int32)
        mask = valid.reshape(-1)
        blocks.append(SampledBlock(dst_index=dst_index, src_index=src_index,
                                   mask=mask))
        layer_nodes.append(nxt)
        node_mask.append(nxt >= 0)
    return SampledSubgraph(seeds=layer_nodes[0], layer_nodes=layer_nodes,
                           blocks=blocks, node_mask=node_mask)


def minibatch_stream(g: Graph, batch: int, fanouts: tuple[int, ...],
                     seed: int = 0, epochs: int = 1):
    """Yield SampledSubgraph batches over shuffled vertex ids."""
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        order = rng.permutation(g.n)
        for i in range(0, g.n - batch + 1, batch):
            yield sample_subgraph(g, order[i:i + batch], fanouts,
                                  seed=seed + ep * 1_000_003 + i)
