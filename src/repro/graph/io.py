"""Graph IO — the paper's ``dataCleanse`` procedure.

Supports the two on-disk formats the paper mentions:
  * SNAP-style edge lists (``u<TAB>v`` per line, ``#`` comments), directed or
    undirected — converted to undirected per the paper's rules;
  * the JSON adjacency format the paper converts graphs into
    (``{"0": [1, 2], "1": [0], ...}``).
"""

from __future__ import annotations

import json

import numpy as np

from repro.graph.structs import Graph


def parse_edge_list(text: str, n: int | None = None) -> Graph:
    rows = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith(("#", "%")):
            continue
        parts = line.replace(",", " ").split()
        rows.append((int(parts[0]), int(parts[1])))
    return Graph.from_edges(np.asarray(rows, np.int64).reshape(-1, 2), n=n)


def load_edge_list(path: str, n: int | None = None) -> Graph:
    with open(path) as f:
        return parse_edge_list(f.read(), n=n)


def parse_json_adjacency(text: str) -> Graph:
    adj = json.loads(text)
    edges = []
    max_id = -1
    for u, nbrs in adj.items():
        ui = int(u)
        max_id = max(max_id, ui)
        for v in nbrs:
            vi = int(v)
            max_id = max(max_id, vi)
            edges.append((ui, vi))
    # n must cover vertices appearing only as neighbor values (an adjacency
    # like {"0": [5]} is legal and means n = 6), not just the keys.
    n = max_id + 1
    return Graph.from_edges(np.asarray(edges, np.int64).reshape(-1, 2), n=n)


def to_json_adjacency(g: Graph) -> str:
    adj = {str(u): [int(v) for v in g.neighbors(u)] for u in range(g.n)}
    return json.dumps(adj)


def save_json_adjacency(g: Graph, path: str) -> None:
    with open(path, "w") as f:
        f.write(to_json_adjacency(g))
