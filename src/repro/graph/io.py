"""Graph IO — the paper's ``dataCleanse`` procedure.

Supports the two on-disk formats the paper mentions:
  * SNAP-style edge lists (``u<TAB>v`` per line, ``#`` comments), directed or
    undirected — converted to undirected per the paper's rules;
  * the JSON adjacency format the paper converts graphs into
    (``{"0": [1, 2], "1": [0], ...}``).
"""

from __future__ import annotations

import json

import numpy as np

from repro.graph.structs import Graph


def parse_edge_list(text: str, n: int | None = None) -> Graph:
    edges = _parse_edge_lines(text.splitlines())
    return Graph.from_edges(edges, n=n)


def _parse_edge_lines(lines) -> np.ndarray:
    """(k, 2) int64 edges from raw edge-list lines (comments dropped).

    Fast path: when every data line has the same column count the whole
    batch is one vectorized ``np.array`` over the flat token stream — no
    per-line int() loop, no ``np.loadtxt``. Ragged inputs (mixed column
    counts) fall back to per-line parsing, keeping the first two columns
    like the paper's dataCleanse.
    """
    toks = [s.replace(",", " ").split()
            for s in (ln.strip() for ln in lines) if s and s[0] not in "#%"]
    if not toks:
        return np.zeros((0, 2), np.int64)
    cols = len(toks[0])
    if cols >= 2 and all(len(t) == cols for t in toks):
        # rectangular: ONE vectorized str->int64 conversion for the batch
        return np.array(toks, np.int64)[:, :2]
    return np.array([t[:2] for t in toks], np.int64)


def iter_edge_chunks(path: str, chunk_bytes: int = 1 << 24):
    """Yield (k, 2) int64 edge arrays from a file, ~chunk_bytes at a time.

    The streaming primitive under ``load_edge_list``: only one chunk of
    text is ever resident, so parsing a million-edge SNAP list costs the
    edge arrays — not the file's text plus per-line Python tuples on top.
    """
    with open(path) as f:
        while True:
            lines = f.readlines(chunk_bytes)
            if not lines:
                return
            edges = _parse_edge_lines(lines)
            if edges.size:
                yield edges


def load_edge_list(path: str, n: int | None = None,
                   chunk_bytes: int = 1 << 24) -> Graph:
    """Load a SNAP-style edge list with bounded parse memory.

    Streams the file through ``iter_edge_chunks`` instead of slurping it:
    peak RSS is the int64 edge array (plus one text chunk), where the old
    path held the entire file text AND a Python tuple per edge before the
    first numpy array existed.
    """
    chunks = list(iter_edge_chunks(path, chunk_bytes))
    edges = (np.concatenate(chunks) if chunks
             else np.zeros((0, 2), np.int64))
    return Graph.from_edges(edges, n=n)


def parse_json_adjacency(text: str) -> Graph:
    adj = json.loads(text)
    edges = []
    max_id = -1
    for u, nbrs in adj.items():
        ui = int(u)
        max_id = max(max_id, ui)
        for v in nbrs:
            vi = int(v)
            max_id = max(max_id, vi)
            edges.append((ui, vi))
    # n must cover vertices appearing only as neighbor values (an adjacency
    # like {"0": [5]} is legal and means n = 6), not just the keys.
    n = max_id + 1
    return Graph.from_edges(np.asarray(edges, np.int64).reshape(-1, 2), n=n)


def to_json_adjacency(g: Graph) -> str:
    adj = {str(u): [int(v) for v in g.neighbors(u)] for u in range(g.n)}
    return json.dumps(adj)


def save_json_adjacency(g: Graph, path: str) -> None:
    with open(path, "w") as f:
        f.write(to_json_adjacency(g))
