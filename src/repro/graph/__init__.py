"""Graph substrate: dense-layout graph structs, generators, partitioning,
neighbor sampling, and IO following the paper's dataCleanse rules."""

from repro.graph.structs import Graph, EllGraph, build_ell, pad_graph_for_shards
from repro.graph.blockstore import Block, BlockCache, BlockStore, plan_blocks
from repro.graph import generators, io, partition, sampler

__all__ = [
    "Graph",
    "EllGraph",
    "build_ell",
    "pad_graph_for_shards",
    "Block",
    "BlockCache",
    "BlockStore",
    "plan_blocks",
    "generators",
    "io",
    "partition",
    "sampler",
]
