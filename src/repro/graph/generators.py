"""Deterministic graph generators.

The paper evaluates on 14 SNAP graphs (Table I). This container has no
network access, so benchmarks run on *SNAP analogues*: synthetic graphs whose
generator + parameters are chosen to match each original's vertex count, edge
count and degree law (scaled by ``--scale`` to stay CPU-feasible). The exact
Table-I statistics of the originals are kept in ``SNAP_TABLE`` so Table-I
reports can show original vs. analogue side by side.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structs import Graph


# ---------------------------------------------------------------------- #
# Small deterministic graphs
# ---------------------------------------------------------------------- #

def chain(n: int) -> Graph:
    """Path graph — the paper's worst case (depth = Θ(n) rounds)."""
    e = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    return Graph.from_edges(e, n=n)


def cycle(n: int) -> Graph:
    e = np.stack([np.arange(n), (np.arange(n) + 1) % n], axis=1)
    return Graph.from_edges(e, n=n)


def complete(n: int) -> Graph:
    iu = np.triu_indices(n, k=1)
    return Graph.from_edges(np.stack(iu, axis=1), n=n)


def star(n: int) -> Graph:
    e = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], axis=1)
    return Graph.from_edges(e, n=n)


def fig1_example() -> tuple[Graph, np.ndarray]:
    """The paper's Fig. 1 example (nodes A..H = 0..7).

    K4 on {A,B,E,F} (3-core); G,H attached with degree 2 (2-core);
    C,D pendant chain (1-core). Returns (graph, expected core numbers).
    """
    A, B, C, D, E, F, G, H = range(8)
    edges = [
        (A, B), (A, E), (A, F), (B, E), (B, F), (E, F),   # K4
        (G, A), (G, H), (H, B),                            # 2-core fringe
        (C, A), (C, D),                                    # 1-core tail
    ]
    expect = np.array([3, 3, 1, 1, 3, 3, 2, 2], np.int32)
    return Graph.from_edges(edges, n=8), expect


# ---------------------------------------------------------------------- #
# Random families
# ---------------------------------------------------------------------- #

def erdos_renyi(n: int, m: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    # Oversample then dedupe to hit ~m edges.
    k = int(m * 1.3) + 16
    e = rng.integers(0, n, size=(k, 2), dtype=np.int64)
    g = Graph.from_edges(e, n=n)
    return g


def barabasi_albert(n: int, m_attach: int, seed: int = 0) -> Graph:
    """Preferential attachment (power-law degrees), vectorized repeated-node
    trick: new vertex attaches to ``m_attach`` targets sampled from the
    degree-weighted repeated-endpoint list."""
    rng = np.random.default_rng(seed)
    m_attach = max(1, min(m_attach, n - 1))
    repeated = list(range(m_attach))  # seed clique-ish endpoints
    edges = []
    for v in range(m_attach, n):
        pool = np.asarray(repeated)
        targets = np.unique(rng.choice(pool, size=m_attach))
        for t in targets:
            edges.append((v, int(t)))
        repeated.extend(targets.tolist())
        repeated.extend([v] * len(targets))
    return Graph.from_edges(np.asarray(edges, np.int64), n=n)


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> Graph:
    """R-MAT / Graph500-style power-law generator, fully vectorized."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities: a (0,0), b (0,1), c (1,0), d (1,1)
        go_right = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        go_down = r >= a + b
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    return Graph.from_edges(np.stack([src, dst], axis=1), n=n)


def community(n: int, n_blocks: int, deg_in: float, deg_out: float,
              seed: int = 0) -> Graph:
    """Stochastic block model (social-network analogue)."""
    rng = np.random.default_rng(seed)
    block = rng.integers(0, n_blocks, n)
    m_in = int(n * deg_in / 2)
    m_out = int(n * deg_out / 2)
    # intra-block edges: pick a vertex, then a partner in the same block
    order = np.argsort(block, kind="stable")
    counts = np.bincount(block, minlength=n_blocks)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    u = rng.integers(0, n, size=m_in)
    bu = block[u]
    offs = rng.integers(0, np.maximum(counts[bu], 1))
    v = order[starts[bu] + offs % np.maximum(counts[bu], 1)]
    intra = np.stack([u, v], axis=1)
    inter = rng.integers(0, n, size=(m_out, 2))
    return Graph.from_edges(np.concatenate([intra, inter]), n=n)


# ---------------------------------------------------------------------- #
# SNAP Table-I analogues
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class SnapEntry:
    name: str
    abbrev: str
    category: str
    directed: bool
    n: int
    m: int
    avg_deg: int
    max_deg: int
    max_core: int        # Table I MaxCore of the original
    family: str          # generator family for the analogue


SNAP_TABLE: tuple[SnapEntry, ...] = (
    SnapEntry("soc-pokec-relationships", "SPR", "Social", True, 1_632_803, 30_622_564, 29, 14739, 118, "rmat"),
    SnapEntry("musae-PTBR-features", "PTBR", "Social", False, 1_912, 31_299, 24, 1635, 21, "ba"),
    SnapEntry("facebook-combined", "FC", "Social", False, 4_039, 88_234, 46, 986, 118, "ba"),
    SnapEntry("musae-git-features", "MGF", "Social", False, 37_700, 289_003, 36, 28191, 29, "rmat"),
    SnapEntry("soc-LiveJournal1", "LJ1", "Social", True, 4_847_571, 68_993_773, 19, 20314, 376, "rmat"),
    SnapEntry("email-Enron", "EEN", "Communication", False, 36_692, 183_831, 10, 1383, 49, "ba"),
    SnapEntry("email-EuAll", "EEU", "Communication", True, 265_214, 420_045, 2, 7631, 44, "star-law"),
    SnapEntry("p2p-Gnutella31", "G31", "P2P", True, 62_586, 147_892, 7, 68, 9, "er"),
    SnapEntry("com-lj", "CLJ", "Communities", False, 3_997_962, 34_681_189, 25, 14208, 360, "rmat"),
    SnapEntry("com-amazon", "CA", "Communities", False, 334_863, 925_872, 5, 546, 8, "community"),
    SnapEntry("web-Stanford", "WS", "Web", True, 281_903, 2_312_497, 14, 38625, 75, "rmat"),
    SnapEntry("web-Google", "WG", "Web", True, 875_713, 5_105_039, 10, 6331, 44, "rmat"),
    SnapEntry("amazon0505", "A0505", "Co-purchase", True, 410_236, 3_356_824, 12, 2760, 15, "community"),
    SnapEntry("soc-Slashdot0811", "S0811", "Signed", True, 77_357, 516_575, 13, 2540, 59, "ba"),
)

SNAP_BY_ABBREV = {e.abbrev: e for e in SNAP_TABLE}


def snap_analogue(abbrev: str, scale: float = 1.0, seed: int = 0) -> Graph:
    """Synthetic analogue of a Table-I graph at ``scale`` of its size.

    Matches n and average degree; the family reproduces the degree law
    (power-law for social/web, near-uniform for P2P, hub-dominated for EEU).
    """
    e = SNAP_BY_ABBREV[abbrev]
    n = max(int(e.n * scale), 64)
    m = max(int(e.m * scale), n)
    if e.family == "er":
        return erdos_renyi(n, m, seed=seed)
    if e.family == "ba":
        return barabasi_albert(n, max(1, round(m / n)), seed=seed)
    if e.family == "community":
        return community(n, max(2, n // 64), deg_in=1.6 * m / n, deg_out=0.4 * m / n, seed=seed)
    if e.family == "star-law":
        # Hub-dominated: low average degree, few huge hubs (email-EuAll).
        rng = np.random.default_rng(seed)
        hubs = rng.integers(0, max(n // 1000, 1), size=m)
        leaves = rng.integers(0, n, size=m)
        return Graph.from_edges(np.stack([hubs, leaves], axis=1), n=n)
    # rmat: choose scale bits to cover n, then subsample vertices to n
    bits = int(np.ceil(np.log2(max(n, 2))))
    g = rmat(bits, max(1, round(m / (1 << bits))), seed=seed)
    return g
