"""Spill-to-disk partitioned graph store — the out-of-core tier's bottom layer.

The source paper's premise is graphs too large for one machine's memory;
Gao et al. ("K-Core Decomposition on Super Large Graphs with Limited
Resources", PAPERS.md) show that the locality iteration tolerates cycling
disk-resident graph *blocks* through a small compute tier. This module is
that disk tier:

  * ``BlockStore.create`` partitions src-sorted arc arrays into the EXACT
    ``partition.shard_arc_arrays`` layout (same ``shard_layout`` geometry:
    contiguous vertex ranges of V, arc runs bounded by searchsorted, one
    store-wide padded arc length A) and writes each block's REAL arc run as
    raw little-endian ``.npy`` arrays keyed by partition id — no padding on
    disk, so store bytes track live arcs, not the straggler block.
  * ``BlockStore.open`` memory-maps those arrays (``np.load(mmap_mode="r")``)
    — opening a store touches the manifest only; block bytes are paged in
    when a block is materialized.
  * ``BlockStore.block(b)`` materializes one padded ``Block`` — bit-identical
    rows to what ``shard_arc_arrays`` would have staged for shard ``b``
    (local src, global dst, sentinel-padded to A) — which is the unit the
    out-of-core driver ships to the device.
  * ``BlockCache`` is an LRU over materialized blocks bounded by a byte
    budget: the knob that makes "device memory provably smaller than the
    arc arrays" a configured fact instead of an accident.

Vertex-indexed state (degrees, estimates) stays dense on the host — at
int32 it is two orders of magnitude smaller than the arc arrays and is the
out-of-core driver's halo buffer.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
from collections import OrderedDict

import numpy as np

from repro.graph.partition import balance_from_counts, shard_layout
from repro.graph.structs import Graph

MANIFEST = "manifest.json"
FORMAT_VERSION = 1

# bytes per padded arc slot when a block is materialized: src int32 + dst
# int32 + mask bool — the unit every budget computation uses
ARC_SLOT_BYTES = 9


def _block_prefix(d: pathlib.Path, b: int) -> pathlib.Path:
    return d / f"block_{b:05d}"


@dataclasses.dataclass(frozen=True)
class Block:
    """One materialized (padded) partition — the device-resident unit.

    Rows are bit-identical to ``shard_arc_arrays``'s shard ``bid``: ``src``
    holds LOCAL vertex indices in [0, V), ``dst`` GLOBAL indices, padding
    slots carry the same sentinels (src = V-1, dst = the owner's last
    padding vertex) with ``mask`` False so they never enter a segment op.
    """

    bid: int
    src: np.ndarray  # (A,) int32 — local vertex index [0, V)
    dst: np.ndarray  # (A,) int32 — global vertex index
    mask: np.ndarray  # (A,) bool — True = real (live) arc
    arcs_real: int  # live arcs (mask.sum())

    @property
    def nbytes(self) -> int:
        return self.src.nbytes + self.dst.nbytes + self.mask.nbytes


class BlockStore:
    """Directory of mmap-able arc blocks in the shard_arc_arrays layout."""

    def __init__(self, path: str | pathlib.Path, manifest: dict):
        self.path = pathlib.Path(path)
        self.n = int(manifest["n"])
        self.n_blocks = int(manifest["n_blocks"])
        self.V = int(manifest["V"])
        self.A = int(manifest["A"])
        self.num_arcs = int(manifest["num_arcs"])
        self.arcs_per_block = np.asarray(manifest["arcs_per_block"], np.int64)
        self.live_per_block = np.asarray(manifest["live_per_block"], np.int64)
        self._manifest = manifest

    # -------------------------------------------------------------- #
    # creation
    # -------------------------------------------------------------- #
    @classmethod
    def create(cls, path: str | pathlib.Path, g: Graph | None = None, *,
               n: int | None = None, src: np.ndarray | None = None,
               dst: np.ndarray | None = None,
               arc_mask: np.ndarray | None = None, n_blocks: int = 8,
               arc_multiple: int = 8, overwrite: bool = False) -> "BlockStore":
        """Write a store from a Graph or raw src-sorted arc arrays.

        Per block only the REAL arc run ``[bounds[b], bounds[b+1])`` is
        written (three .npy files: local src, global dst, mask) — padding to
        the store-wide A happens at materialization. Writing slices the
        input arrays block by block, so peak memory is the inputs plus one
        block, never a padded (n_blocks, A) tensor.
        """
        if g is not None:
            n, src, dst = g.n, g.src, g.dst
            arc_mask = np.ones(g.num_arcs, bool)
        if n is None or src is None or dst is None:
            raise ValueError("pass a Graph or n/src/dst arrays")
        if arc_mask is None:
            arc_mask = np.ones(src.shape[0], bool)
        n_blocks = max(int(n_blocks), 1)
        d = pathlib.Path(path)
        if d.exists():
            if not overwrite:
                raise FileExistsError(f"{d} exists (overwrite=False)")
            shutil.rmtree(d)
        d.mkdir(parents=True)
        V, A, bounds = shard_layout(n, src, n_blocks,
                                    arc_multiple=arc_multiple)
        live_per_block = []
        for b in range(n_blocks):
            lo, hi = int(bounds[b]), int(bounds[b + 1])
            p = _block_prefix(d, b)
            np.save(f"{p}.src.npy",
                    (src[lo:hi] - b * V).astype(np.int32, copy=False))
            np.save(f"{p}.dst.npy", dst[lo:hi].astype(np.int32, copy=False))
            np.save(f"{p}.mask.npy", arc_mask[lo:hi].astype(bool, copy=False))
            live_per_block.append(int(arc_mask[lo:hi].sum()))
        manifest = {
            "version": FORMAT_VERSION,
            "n": int(n),
            "n_blocks": n_blocks,
            "V": V,
            "A": A,
            "num_arcs": int(src.shape[0]),
            "arcs_per_block": np.diff(bounds).astype(np.int64).tolist(),
            "live_per_block": live_per_block,
        }
        (d / MANIFEST).write_text(json.dumps(manifest))
        return cls(d, manifest)

    @classmethod
    def open(cls, path: str | pathlib.Path) -> "BlockStore":
        d = pathlib.Path(path)
        manifest = json.loads((d / MANIFEST).read_text())
        if manifest.get("version") != FORMAT_VERSION:
            raise ValueError(f"unsupported blockstore version "
                             f"{manifest.get('version')!r}")
        return cls(d, manifest)

    # -------------------------------------------------------------- #
    # geometry / reporting
    # -------------------------------------------------------------- #
    @property
    def n_pad(self) -> int:
        return self.n_blocks * self.V

    @property
    def total_arc_bytes(self) -> int:
        """Bytes the arc arrays would occupy fully materialized (the
        in-memory modes' device footprint): src + dst + mask per real slot."""
        return int(self.num_arcs) * ARC_SLOT_BYTES

    @property
    def block_arc_bytes(self) -> int:
        """Bytes of ONE materialized (padded) block — the out-of-core
        driver's peak device-resident arc footprint."""
        return int(self.A) * ARC_SLOT_BYTES

    def balance(self) -> dict:
        """`partition.balance_report` twin over the stored blocks."""
        return balance_from_counts(self.live_per_block, self.A)

    def vertex_range(self, b: int) -> tuple[int, int]:
        return b * self.V, (b + 1) * self.V

    # -------------------------------------------------------------- #
    # block access
    # -------------------------------------------------------------- #
    def block_raw(self, b: int):
        """Memory-mapped REAL-length (unpadded) arrays of block ``b``."""
        p = _block_prefix(self.path, b)
        return (np.load(f"{p}.src.npy", mmap_mode="r"),
                np.load(f"{p}.dst.npy", mmap_mode="r"),
                np.load(f"{p}.mask.npy", mmap_mode="r"))

    def block(self, b: int) -> Block:
        """Materialize block ``b`` padded to the store-wide A.

        Padding sentinels match ``shard_arc_arrays`` exactly: local src =
        V-1, dst = the owner's last padding slot clamped to n_pad-1, mask
        False — so a materialized block row-for-row equals the shard the
        mesh engines would have staged (tested in tests/test_blockstore.py).
        """
        if not 0 <= b < self.n_blocks:
            raise IndexError(f"block {b} out of range [0, {self.n_blocks})")
        raw_src, raw_dst, raw_mask = self.block_raw(b)
        k = raw_src.shape[0]
        V, A = self.V, self.A
        src = np.full(A, V - 1, np.int32)
        dst = np.full(A, min(b * V + V - 1, self.n_pad - 1), np.int32)
        mask = np.zeros(A, bool)
        src[:k] = raw_src
        dst[:k] = raw_dst
        mask[:k] = raw_mask
        return Block(bid=b, src=src, dst=dst, mask=mask,
                     arcs_real=int(self.live_per_block[b]))

    def delete(self) -> None:
        shutil.rmtree(self.path, ignore_errors=True)


# ------------------------------------------------------------------ #
# Bounded LRU block cache
# ------------------------------------------------------------------ #

class BlockCache:
    """LRU cache of materialized blocks bounded by a byte budget.

    ``budget_bytes`` caps the SUM of cached block bytes; loading past it
    evicts least-recently-used blocks first. The block being returned is
    always retained even when it alone exceeds the budget (you cannot
    compute on less than one block) — ``over_budget`` flags that case so
    callers can surface an impossible budget instead of silently ignoring
    it. ``budget_bytes=None`` means unbounded (pure read-through cache).
    """

    def __init__(self, store: BlockStore, budget_bytes: int | None = None):
        self.store = store
        self.budget_bytes = None if budget_bytes is None else int(budget_bytes)
        self._lru: OrderedDict[int, Block] = OrderedDict()
        self.bytes = 0
        self.loads = 0
        self.hits = 0
        self.evictions = 0
        self.peak_bytes = 0
        self.over_budget = (self.budget_bytes is not None
                            and store.block_arc_bytes > self.budget_bytes)

    def get(self, b: int) -> Block:
        blk = self._lru.get(b)
        if blk is not None:
            self.hits += 1
            self._lru.move_to_end(b)
            return blk
        blk = self.store.block(b)
        self.loads += 1
        self._lru[b] = blk
        self.bytes += blk.nbytes
        self.peak_bytes = max(self.peak_bytes, self.bytes)
        if self.budget_bytes is not None:
            while self.bytes > self.budget_bytes and len(self._lru) > 1:
                _, victim = self._lru.popitem(last=False)
                self.bytes -= victim.nbytes
                self.evictions += 1
        return blk

    def stats(self) -> dict:
        return {
            "loads": self.loads,
            "hits": self.hits,
            "evictions": self.evictions,
            "resident_blocks": len(self._lru),
            "resident_bytes": self.bytes,
            "peak_bytes": self.peak_bytes,
            "budget_bytes": self.budget_bytes,
            "over_budget": self.over_budget,
        }


def plan_blocks(n: int, src: np.ndarray, mem_budget: int | None,
                arc_multiple: int = 8, resident_target: int = 2,
                max_blocks: int = 4096) -> int:
    """Pick a block count whose padded blocks fit the byte budget.

    Returns the smallest power-of-two ``n_blocks`` such that
    ``resident_target`` materialized blocks fit in ``mem_budget`` (the LRU
    must hold at least two blocks for cycling to beat thrashing), probing
    the REAL layout via ``shard_layout`` so skew — which inflates the padded
    A — is accounted for, not estimated. Falls back to the largest probed
    count when even it cannot fit: the driver still runs, with
    ``BlockCache.over_budget`` flagging the impossible budget.
    """
    if mem_budget is None:
        return min(8, max_blocks)
    nb = 1
    while nb <= max_blocks:
        _V, A, _bounds = shard_layout(n, src, nb, arc_multiple=arc_multiple)
        if resident_target * A * ARC_SLOT_BYTES <= mem_budget:
            return nb
        if nb >= min(max_blocks, max(n, 1)):
            break
        nb *= 2
    return nb
