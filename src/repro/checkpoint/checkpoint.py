"""Sharded checkpointing with atomic commit + elastic restore.

Layout:  <dir>/step_<N>.tmp/ -> (write all shards + manifest) -> atomic
rename to <dir>/step_<N>/ . A crash mid-write leaves only a .tmp directory,
which restore ignores — the previous complete step is used instead (the
fault-tolerance contract: training resumes from the last COMMITTED step).

Elastic restore: arrays are written as full (unsharded) npz per pytree leaf
(host-gathered). Restoring onto any mesh re-shards via the target step's
in_shardings — a checkpoint taken on 256 chips restarts on 512 or on 1
(used by tests). For multi-TB runs the natural extension is per-shard files
keyed by (leaf, shard-index); the manifest format already carries the
tree structure so only the writer changes.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str | os.PathLike, step: int, state) -> str:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"step_{step:09d}.tmp"
    final = d / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic commit
    return str(final)


def latest_step(directory: str | os.PathLike) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str | os.PathLike, like, step: int |
                       None = None, shardings=None):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings for elastic placement on the current mesh."""
    d = pathlib.Path(directory)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {d}")
    final = d / f"step_{step:09d}"
    data = np.load(final / "arrays.npz")
    leaves, treedef = _flatten(like)
    if len(leaves) != len(data.files):
        raise ValueError(f"leaf count mismatch: ckpt {len(data.files)} "
                         f"vs target {len(leaves)}")
    out = [data[f"leaf_{i}"] for i in range(len(leaves))]
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        out = [jax.device_put(a, s) for a, s in zip(out, sh_leaves)]
    else:
        out = [jax.numpy.asarray(a) for a in out]
    return treedef.unflatten(out), step
