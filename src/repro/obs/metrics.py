"""Process-wide metrics: counters, gauges, and reservoir histograms.

The serving/runtime counterpart of repro.obs.trace: where spans answer
"where did THIS wall-clock go", metrics answer "what are the p50/p99 and
totals over the whole run". Stdlib-only, thread-safe, exportable two ways:

  * ``to_json()``      — structured dict (the ``BENCH_*.json`` /
    ``--metrics`` payload);
  * ``to_prometheus()``— Prometheus text exposition format (counters and
    gauges as-is, histograms as summaries with ``{quantile=...}`` series
    plus ``_count`` / ``_sum``), so a real scrape endpoint only has to
    serve the string.

Histograms use fixed-size uniform reservoir sampling (Vitter's algorithm
R, deterministic per-histogram RNG) so memory stays bounded no matter how
many requests a server answers, while quantiles stay unbiased estimates
of the full stream. Exact count / sum / min / max are tracked alongside
the reservoir.

``KCoreServer`` owns a private registry (two servers in one process must
not merge their latency distributions); engine/runtime-level totals go to
the process-wide default registry (``repro.obs.metrics.counter(...)``),
dumped by the ``--metrics`` CLI flags.
"""

from __future__ import annotations

import math
import random
import re
import threading

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    """Sanitize a metric name for the Prometheus exposition format."""
    name = _NAME_RE.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _prom_label_value(value) -> str:
    """Escape a label value per the exposition-format spec: backslash,
    double-quote, and newline must be escaped inside the quotes."""
    return (str(value).replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


def _prom_labels(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_prom_name(k)}="{_prom_label_value(v)}"'
                     for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """Monotone counter."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a gauge")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Uniform-reservoir histogram with p50/p95/p99 quantile estimates.

    ``observe`` is O(1); quantiles sort the bounded reservoir on demand.
    The reservoir (default 1024 samples) is an unbiased uniform sample of
    the whole observation stream (algorithm R); count / sum / min / max
    are exact.
    """

    __slots__ = ("_reservoir", "_size", "_count", "_sum", "_min", "_max",
                 "_rng", "_lock")

    QUANTILES = (0.5, 0.95, 0.99)

    def __init__(self, reservoir_size: int = 1024):
        if reservoir_size < 1:
            raise ValueError("reservoir_size must be >= 1")
        self._reservoir: list[float] = []
        self._size = int(reservoir_size)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        # deterministic per-histogram stream: benchmarks and tests see
        # reproducible quantiles for a fixed observation sequence
        self._rng = random.Random(0x5EED)
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            self._count += 1
            self._sum += value
            self._min = min(self._min, value)
            self._max = max(self._max, value)
            if len(self._reservoir) < self._size:
                self._reservoir.append(value)
            else:
                j = self._rng.randrange(self._count)
                if j < self._size:
                    self._reservoir[j] = value

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """Linear-interpolated quantile estimate over the reservoir."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("quantile must be in [0, 1]")
        with self._lock:
            sample = sorted(self._reservoir)
        if not sample:
            return math.nan
        pos = q * (len(sample) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(sample) - 1)
        frac = pos - lo
        return sample[lo] * (1.0 - frac) + sample[hi] * frac

    def snapshot(self) -> dict:
        with self._lock:
            empty = self._count == 0
            out = {
                "type": "histogram",
                "count": self._count,
                "sum": self._sum,
                "min": None if empty else self._min,
                "max": None if empty else self._max,
                "mean": None if empty else self._sum / self._count,
            }
        for q in self.QUANTILES:
            v = self.quantile(q)
            out[f"p{int(q * 100)}"] = None if math.isnan(v) else v
        return out


class MetricsRegistry:
    """Get-or-create registry keyed by (name, sorted label items)."""

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: dict, **kwargs):
        key = (name, tuple(sorted(labels.items())))
        with self._lock:
            metric = self._metrics.get(key)
            if metric is None:
                metric = self._metrics[key] = cls(**kwargs)
            elif not isinstance(metric, cls):
                raise TypeError(
                    f"metric {name!r}{labels} already registered as "
                    f"{type(metric).__name__}")
        return metric

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, reservoir_size: int = 1024,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels,
                         reservoir_size=reservoir_size)

    def reset(self) -> None:
        with self._lock:
            self._metrics = {}

    # ------------------------------------------------------------------ #
    def _items(self) -> list[tuple[str, tuple, object]]:
        with self._lock:
            items = list(self._metrics.items())
        return sorted(((name, labels, m) for (name, labels), m in items))

    def to_json(self) -> dict:
        """``{name: [{labels: {...}, **snapshot}, ...]}`` — every metric."""
        out: dict = {}
        for name, labels, metric in self._items():
            entry = {"labels": dict(labels)}
            entry.update(metric.snapshot())
            out.setdefault(name, []).append(entry)
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (histograms as summaries)."""
        lines: list[str] = []
        seen_types: set[str] = set()
        for name, labels, metric in self._items():
            pname = _prom_name(name)
            if isinstance(metric, Counter):
                if pname not in seen_types:
                    lines.append(f"# TYPE {pname} counter")
                    seen_types.add(pname)
                lines.append(f"{pname}{_prom_labels(labels)} {metric.value}")
            elif isinstance(metric, Gauge):
                if pname not in seen_types:
                    lines.append(f"# TYPE {pname} gauge")
                    seen_types.add(pname)
                lines.append(f"{pname}{_prom_labels(labels)} {metric.value}")
            else:  # Histogram -> summary series
                if pname not in seen_types:
                    lines.append(f"# TYPE {pname} summary")
                    seen_types.add(pname)
                for q in Histogram.QUANTILES:
                    v = metric.quantile(q)
                    qlabels = labels + (("quantile", q),)
                    val = "NaN" if math.isnan(v) else repr(v)
                    lines.append(f"{pname}{_prom_labels(qlabels)} {val}")
                lines.append(
                    f"{pname}_sum{_prom_labels(labels)} {metric.sum}")
                lines.append(
                    f"{pname}_count{_prom_labels(labels)} {metric.count}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------- #
# Process-wide default registry.
# ---------------------------------------------------------------------- #

_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _DEFAULT


def counter(name: str, **labels) -> Counter:
    return _DEFAULT.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _DEFAULT.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return _DEFAULT.histogram(name, **labels)


def to_json() -> dict:
    return _DEFAULT.to_json()


def to_prometheus() -> str:
    return _DEFAULT.to_prometheus()


def reset() -> None:
    _DEFAULT.reset()
