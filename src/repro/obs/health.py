"""Online invariant monitor over the convergence flight stream.

The locality iteration has invariants the paper's correctness argument
rests on, and this module checks them AS ROUNDS COMPLETE rather than after
the fact:

* **monotone non-increasing estimates** — a vertex estimate never rises
  within a convergence run (the h-index update only peels);
* **frontier shrinkage implies termination progress** — a round with
  messages but zero estimate changes, a changed-count exceeding the
  frontier, or a frontier that stops reaching new minima for a long
  stretch all indicate a wedged or mis-accounted run;
* **message-bill mode-invariance** — the same (graph, batch) converged
  under two execution modes must bill the identical message total
  (the repo's bit-equality contract, checked live via ``observe_bill``).

Anomalies are emitted as structured events into the PR 6 tracer
(``trace.record("health.anomaly", ...)``), counted per-kind in the metrics
registry (``obs_health_anomalies_total{kind}``), and collapsed into a
single health gauge (``obs_health_status``: 1 ok / 0 anomalous) that the
``/healthz`` endpoint serves.

The monitor subscribes to a ``FlightRecorder`` via its observer hook, so
it costs nothing unless flight recording is enabled; ``install()`` wires
the process-default monitor to the process-default recorder (idempotent).
This module imports ``flight`` — flight must never import health.
"""

from __future__ import annotations

import threading

from repro.obs import flight, metrics, trace

# a frontier that hasn't reached a new minimum for this many consecutive
# rounds is flagged as stalled (the locality iteration on any real graph
# converges in far fewer; see the paper's round counts)
STALL_ROUNDS = 256

_MAX_RUNS_TRACKED = 64
_MAX_BILLS_TRACKED = 256


class InvariantMonitor:
    """Validates convergence invariants on a stream of flight events."""

    def __init__(self, registry: metrics.MetricsRegistry | None = None,
                 stall_rounds: int = STALL_ROUNDS):
        self._registry = registry
        self.stall_rounds = int(stall_rounds)
        self._lock = threading.RLock()
        self._runs: dict[int, dict] = {}
        self._bills: dict = {}
        self.anomalies = 0
        self.kinds: dict[str, int] = {}
        self.last: dict | None = None
        self.runs_seen = 0
        self._set_gauge()

    # -------------------------------------------------------------- #
    # event intake (FlightRecorder observer protocol)
    # -------------------------------------------------------------- #
    def __call__(self, event: dict) -> None:
        kind = event.get("kind")
        if kind == "round":
            self.check_record(event["record"])
        elif kind == "run_start":
            with self._lock:
                self.runs_seen += 1
                self._runs[event["run"]] = {
                    "min_frontier": None, "since_min": 0,
                    "last_est_sum": None, "rises": 0, "stalled": False,
                }
                if len(self._runs) > _MAX_RUNS_TRACKED:
                    self._runs.pop(next(iter(self._runs)))
        elif kind == "run_end":
            self._on_run_end(event)

    def check_record(self, rec) -> None:
        """Check one FlightRecord; public so tests can inject records."""
        with self._lock:
            st = self._runs.setdefault(rec.run, {
                "min_frontier": None, "since_min": 0,
                "last_est_sum": None, "rises": 0, "stalled": False,
            })
            if rec.est_rises > 0:
                st["rises"] += rec.est_rises
                self._anomaly("non_monotone_estimate", run=rec.run,
                              round=rec.round, rises=rec.est_rises,
                              mode=rec.mode)
            if rec.est_sum is not None:
                prev = st["last_est_sum"]
                if prev is not None and rec.est_sum > prev:
                    self._anomaly("non_monotone_estimate", run=rec.run,
                                  round=rec.round, est_sum=rec.est_sum,
                                  prev_est_sum=prev, mode=rec.mode)
                st["last_est_sum"] = rec.est_sum
            if rec.round >= 1:
                if rec.changed == 0 and rec.messages > 0:
                    self._anomaly("messages_without_change", run=rec.run,
                                  round=rec.round, messages=rec.messages,
                                  mode=rec.mode)
                if rec.changed > rec.frontier:
                    self._anomaly("changed_exceeds_frontier", run=rec.run,
                                  round=rec.round, changed=rec.changed,
                                  frontier=rec.frontier, mode=rec.mode)
                mn = st["min_frontier"]
                if mn is None or rec.frontier < mn:
                    st["min_frontier"] = rec.frontier
                    st["since_min"] = 0
                else:
                    st["since_min"] += 1
                    if (st["since_min"] >= self.stall_rounds
                            and not st["stalled"]):
                        st["stalled"] = True
                        self._anomaly("frontier_stall", run=rec.run,
                                      round=rec.round,
                                      frontier=rec.frontier, mode=rec.mode)

    def _on_run_end(self, event: dict) -> None:
        with self._lock:
            st = self._runs.pop(event["run"], None)
            if event.get("converged") is False:
                self._anomaly("unconverged_run", run=event["run"],
                              rounds=event.get("rounds"),
                              mode=event.get("mode", ""))
            rises = int(event.get("est_rises", 0) or 0)
            if rises > 0 and (st is None or st["rises"] == 0):
                self._anomaly("non_monotone_estimate", run=event["run"],
                              rises=rises, mode=event.get("mode", ""))

    def observe_bill(self, key, mode: str, total: int) -> None:
        """Check message-bill mode-invariance: the same ``key`` (e.g. a
        (trace, batch) pair) converged under different modes must bill the
        identical total."""
        with self._lock:
            seen = self._bills.get(key)
            if seen is None:
                self._bills[key] = (str(mode), int(total))
                if len(self._bills) > _MAX_BILLS_TRACKED:
                    self._bills.pop(next(iter(self._bills)))
            elif seen[1] != int(total):
                self._anomaly("mode_bill_mismatch", key=str(key),
                              mode=str(mode), total=int(total),
                              other_mode=seen[0], other_total=seen[1])

    # -------------------------------------------------------------- #
    # anomaly emission + verdict
    # -------------------------------------------------------------- #
    def _anomaly(self, kind: str, **attrs) -> None:
        self.anomalies += 1
        self.kinds[kind] = self.kinds.get(kind, 0) + 1
        self.last = {"kind": kind, **attrs}
        trace.record("health.anomaly", 0.0, kind=kind, **attrs)
        self._counter(kind)
        self._set_gauge()

    def _counter(self, kind: str) -> None:
        reg = self._registry if self._registry is not None \
            else metrics.get_registry()
        reg.counter("obs_health_anomalies_total", kind=kind).inc()

    def _set_gauge(self) -> None:
        val = 1.0 if self.anomalies == 0 else 0.0
        if self._registry is not None:
            self._registry.gauge("obs_health_status").set(val)
        else:
            metrics.gauge("obs_health_status").set(val)

    @property
    def ok(self) -> bool:
        return self.anomalies == 0

    def verdict(self) -> dict:
        with self._lock:
            return {
                "status": "ok" if self.anomalies == 0 else "anomalous",
                "anomalies": self.anomalies,
                "kinds": dict(self.kinds),
                "last": self.last,
                "runs_seen": self.runs_seen,
            }

    def reset(self) -> None:
        with self._lock:
            self._runs.clear()
            self._bills.clear()
            self.anomalies = 0
            self.kinds = {}
            self.last = None
            self.runs_seen = 0
            self._set_gauge()


# ------------------------------------------------------------------ #
# Process-wide default monitor.
# ------------------------------------------------------------------ #

_DEFAULT = InvariantMonitor()
_installed = False


def get_monitor() -> InvariantMonitor:
    return _DEFAULT


def install(recorder: flight.FlightRecorder | None = None) -> InvariantMonitor:
    """Attach the default monitor to the (default) flight recorder so it
    sees every run/round event. Idempotent."""
    global _installed
    rec = recorder if recorder is not None else flight.get_recorder()
    rec.add_observer(_DEFAULT)
    _installed = True
    return _DEFAULT


def verdict() -> dict:
    return _DEFAULT.verdict()


def ok() -> bool:
    return _DEFAULT.ok


def reset() -> None:
    _DEFAULT.reset()
