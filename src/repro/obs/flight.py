"""Convergence flight recorder: a bounded ring of per-round records.

The paper's contribution is *measurement* of the distributed locality
iteration — yet spans (repro.obs.trace) only answer "where did the wall go"
and metrics (repro.obs.metrics) only answer "what are the totals". This
module records WHAT THE CONVERGENCE DID, round by round, in every execution
mode: frontier size, messages, changed/sender count, the estimate-decrease
histogram, device vs host wall, dispatch mode, and compile events — one
``FlightRecord`` per accounting round, held in a bounded ring so a
long-running server keeps the recent convergence history resident without
unbounded growth.

Capture points (all guarded by ``recorder().active`` — see below):

* the static host round loops (``core/kcore.py``: segment / ell / block_gs
  backends and the sharded superstep loop) record ONLINE, one record per
  productive round, with an exact per-round estimate-decrease histogram
  computed from the host estimate vectors;
* the fused while_loop modes record POST-HOC from the device stat buffers
  (``core/runtime.py`` — the single layer every fused path flows through):
  per-round messages/changed/frontier are bit-equal to the host loops by
  construction, the device wall is amortized over the rounds, and the
  estimate-decrease histogram is the aggregate seed-vs-final drop (the
  while_loop never surfaces intermediate estimates — buffering them would
  change the jitted program, which observability must never do);
* the streaming engine (``streaming/engine.py``) opens one run per churn
  batch (round 0 = the seed rebroadcast + link handshakes), and temporal
  window advances label those runs via ``set_context``.

The per-round ``frontier`` is the ACCOUNTING active series
(``MessageStats.active_per_round``) — identical across host, fused, and
sharded modes by the repo's bit-equality contract — so a flight ring
recorded under any mode is directly comparable to any other
(property-tested in tests/test_flight.py).

Opt-in per-vertex trajectories: ``watch(ids)`` selects a watchlist of
vertex ids (the paper's "each vertex is a client" view) whose estimate is
sampled at every round where a host estimate vector is available;
``timelines()`` replays them as a per-client message timeline.

Zero cost when disabled — the same contract as ``trace.NULL_SPAN``:
``recorder()`` returns a shared no-op ``NULL_RECORDER`` whose ``.active``
is False, and every engine guards its estimate-vector device syncs and
per-round clock reads behind that flag. The disabled path adds exactly
zero device syncs and no per-round allocation.

An observer hook (``add_observer``) streams run/round/run-end events to
the online invariant monitor (repro.obs.health) as rounds complete.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque

import numpy as np

# estimate-decrease buckets: drops of exactly 1, 2, 3-4, 5-8, and >8 —
# log-spaced because the h-index cascade's tail is what distinguishes a
# local repair from a core-structure collapse
DROP_BUCKETS = (1, 2, 4, 8)


def drop_histogram(prev_est, est) -> tuple[int, ...]:
    """Bucketed histogram of per-vertex estimate decreases prev -> new.

    Returns ``(=1, =2, <=4, <=8, >8)`` counts over vertices that dropped.
    Rises are NOT counted here — they are reported separately as
    ``est_rises`` (a monotonicity violation, repro.obs.health's job).
    """
    drop = np.asarray(prev_est, np.int64) - np.asarray(est, np.int64)
    drop = drop[drop > 0]
    if not drop.size:
        return (0,) * (len(DROP_BUCKETS) + 1)
    out = []
    lo = 0
    for b in DROP_BUCKETS:
        out.append(int(((drop > lo) & (drop <= b)).sum()))
        lo = b
    out.append(int((drop > lo).sum()))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class FlightRecord:
    """One accounting round of one convergence run (flat — JSON-ready)."""

    seq: int                # monotone over the recorder's lifetime
    run: int                # run id (one run = one convergence / batch)
    engine: str             # "static" | "streaming" | "temporal" | ...
    mode: str               # execution mode ("jacobi/segment", "fused", ...)
    batch: int | None       # batch / window-step id, None for static runs
    round: int              # accounting round index (0 = seed broadcast)
    frontier: int           # accounting active count this round
    messages: int
    changed: int            # senders (estimate decreases) this round
    est_rises: int          # vertices whose estimate ROSE (must be 0)
    drop_hist: tuple[int, ...] | None   # see drop_histogram; None = unknown
    est_sum: int | None     # sum of the estimate vector after the round
    host_s: float           # host wall of this round (0 when amortized)
    device_s: float         # device wall share of this round
    dispatch: str           # "xla" | "pallas" | ""
    compiles: int           # fresh XLA compiles attributed to this round
    t: float                # perf_counter timestamp at record time

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        if d["drop_hist"] is not None:
            d["drop_hist"] = list(d["drop_hist"])
        return d


class _NullRecorder:
    """Shared no-op recorder returned while flight recording is disabled.

    ``active`` is False: engines check it ONCE per run and skip every
    estimate-vector sync / clock read on the disabled path.
    """

    __slots__ = ()
    active = False

    def set_context(self, **ctx) -> None:
        pass

    def start_run(self, *a, **kw) -> int:
        return -1

    def record_round(self, *a, **kw) -> None:
        pass

    def record_fused_rounds(self, *a, **kw) -> None:
        pass

    def note_event(self, *a, **kw) -> None:
        pass

    def end_run(self, *a, **kw) -> None:
        pass


NULL_RECORDER = _NullRecorder()


class FlightRecorder:
    """Bounded ring of FlightRecords plus per-run bookkeeping."""

    active = True

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError("flight ring capacity must be >= 1")
        self.capacity = int(capacity)
        self._ring: deque[FlightRecord] = deque(maxlen=self.capacity)
        self._lock = threading.RLock()
        self._seq = 0
        self._runs = 0
        self._run: dict | None = None      # open-run state
        self._context: dict = {}           # merged into the next start_run
        self._watch: np.ndarray = np.zeros(0, np.int64)
        self._timelines: dict[int, list] = {}
        # out-of-band events (snapshot flips, checkpoint saves, ...) — a
        # separate small ring so they never evict convergence rounds
        self._events: deque[dict] = deque(maxlen=self.capacity)
        self._observers: list = []
        self.last_run_rounds = 0           # rounds of the last FINISHED run
        self.rounds_recorded = 0           # total rounds ever recorded

    # -------------------------------------------------------------- #
    # run lifecycle
    # -------------------------------------------------------------- #
    def set_context(self, **ctx) -> None:
        """Stash context merged into the NEXT ``start_run`` (then cleared).

        The temporal layer uses this to label the streaming engine's runs
        (``engine="temporal"``, the window step) without the engine knowing
        who drives it.
        """
        with self._lock:
            self._context.update(ctx)

    def start_run(self, engine: str, mode: str = "", batch: int | None = None,
                  dispatch: str = "", n: int = 0) -> int:
        """Open a convergence run; returns its id. An unfinished previous
        run is closed implicitly (converged=None stays unreported)."""
        with self._lock:
            if self._run is not None:
                self._finish_run(converged=None)
            ctx = self._context
            self._context = {}
            run_id = self._runs
            self._runs += 1
            self._run = {
                "id": run_id,
                "engine": str(ctx.get("engine", engine)),
                "mode": mode,
                "batch": ctx.get("step", batch),
                "dispatch": dispatch,
                "n": int(n),
                "rounds": 0,
            }
            self._notify({"kind": "run_start", "run": run_id,
                          "engine": self._run["engine"], "mode": mode,
                          "batch": self._run["batch"], "n": int(n)})
            return run_id

    def annotate_run(self, **kw) -> None:
        """Update open-run fields (e.g. dispatch resolved after start)."""
        with self._lock:
            if self._run is not None:
                self._run.update(kw)

    def record_round(self, frontier: int, messages: int, changed: int, *,
                     round: int | None = None, est=None, prev_est=None,
                     host_s: float = 0.0, device_s: float = 0.0,
                     compiles: int = 0, dispatch: str | None = None) -> None:
        """Record one accounting round of the open run.

        ``est``/``prev_est`` are OPTIONAL host int vectors: when given, the
        estimate-decrease histogram, rise count, estimate sum, and watchlist
        samples are computed from them (numpy, O(n) — the callers only
        convert device arrays when ``recorder().active``).
        """
        with self._lock:
            if self._run is None:
                self.start_run("unknown")
            run = self._run
            rnd = run["rounds"] if round is None else int(round)
            run["rounds"] = rnd + 1
            est_rises = 0
            hist = None
            est_sum = None
            if est is not None:
                est = np.asarray(est)
                est_sum = int(est.sum())
                if prev_est is not None:
                    prev = np.asarray(prev_est)
                    est_rises = int((est > prev).sum())
                    hist = drop_histogram(prev, est)
                self._sample_watch(run, rnd, est)
            rec = FlightRecord(
                seq=self._seq, run=run["id"], engine=run["engine"],
                mode=run["mode"], batch=run["batch"], round=rnd,
                frontier=int(frontier), messages=int(messages),
                changed=int(changed), est_rises=est_rises, drop_hist=hist,
                est_sum=est_sum, host_s=float(host_s),
                device_s=float(device_s),
                dispatch=run["dispatch"] if dispatch is None else dispatch,
                compiles=int(compiles), t=time.perf_counter())
            self._seq += 1
            self.rounds_recorded += 1
            self._ring.append(rec)
            self._notify({"kind": "round", "record": rec})

    def record_fused_rounds(self, msgs, changed, recv, *, frontier1: int,
                            device_s: float = 0.0, compiles: int = 0,
                            dispatch: str = "", seed=None,
                            final=None) -> None:
        """Post-hoc recording of a fused convergence's productive rounds.

        ``msgs``/``changed``/``recv`` are the host-reconstructed per-round
        arrays (``FusedOutcome`` / ``fused_round_stats``) — bit-equal to the
        host loops' accounting. ``frontier1`` is the accounting round-1
        active count (the while_loop's arg mask can differ from the
        accounting convention — the static engine activates everyone but
        bills ``(deg>0)``). The device wall is amortized uniformly over the
        rounds; the seed-vs-final estimate drop histogram is attached to
        the LAST round (per-round estimates never leave the device).
        """
        k = len(msgs)
        if k == 0:
            return
        with self._lock:
            per_round = float(device_s) / k
            for i in range(k):
                frontier = int(frontier1) if i == 0 else int(recv[i - 1])
                last = i == k - 1
                self.record_round(
                    frontier, int(msgs[i]), int(changed[i]),
                    est=np.asarray(final) if last and final is not None
                    else None,
                    prev_est=np.asarray(seed) if last and seed is not None
                    else None,
                    device_s=per_round, compiles=compiles if i == 0 else 0,
                    dispatch=dispatch or None)

    def note_event(self, kind: str, **attrs) -> None:
        """Record an out-of-band serving event (e.g. a snapshot buffer
        flip or a checkpoint save) alongside the convergence rounds.

        Events live in their own bounded ring, are exported under
        ``"events"`` in ``to_json()``, and stream to observers as
        ``{"kind": "event", ...}`` — so the health monitor and the
        ``/debug/flight`` endpoint see buffer flips in sequence with the
        re-convergence they raced against.
        """
        with self._lock:
            ev = {"kind": str(kind), "t": time.perf_counter(), **attrs}
            self._events.append(ev)
            self._notify({"kind": "event", "event": ev})

    def events(self, last: int | None = None) -> list[dict]:
        with self._lock:
            evs = list(self._events)
        return evs if last is None else evs[-int(last):]

    def end_run(self, converged: bool = True, **attrs) -> None:
        with self._lock:
            self._finish_run(converged=bool(converged), **attrs)

    def _finish_run(self, converged, **attrs) -> None:
        run, self._run = self._run, None
        if run is None:
            return
        self.last_run_rounds = run["rounds"]
        self._notify({"kind": "run_end", "run": run["id"],
                      "engine": run["engine"], "mode": run["mode"],
                      "batch": run["batch"], "rounds": run["rounds"],
                      "converged": converged, **attrs})

    # -------------------------------------------------------------- #
    # watchlist (per-vertex trajectories)
    # -------------------------------------------------------------- #
    def watch(self, ids) -> None:
        """Select vertex ids whose estimate trajectory is captured at every
        round where a host estimate vector is available."""
        with self._lock:
            self._watch = np.unique(np.asarray(ids, np.int64).reshape(-1))
            for v in self._watch:
                self._timelines.setdefault(int(v), [])

    @property
    def watchlist(self) -> np.ndarray:
        return self._watch

    def _sample_watch(self, run: dict, rnd: int, est: np.ndarray) -> None:
        w = self._watch
        if not w.size:
            return
        sel = w[w < est.shape[0]]
        vals = est[sel]
        for v, e in zip(sel.tolist(), vals.tolist()):
            tl = self._timelines[int(v)]
            # message-timeline semantics: an entry per (run, round) where
            # the client's estimate was observable, flagged when it moved
            changed = bool(tl) and tl[-1]["est"] != int(e)
            tl.append({"run": run["id"], "batch": run["batch"],
                       "round": rnd, "est": int(e), "changed": changed})
            if len(tl) > 4 * self.capacity:
                del tl[: 2 * self.capacity]

    def timelines(self) -> dict[int, list]:
        """Per-watched-vertex estimate/message timeline (replayable)."""
        with self._lock:
            return {v: list(tl) for v, tl in self._timelines.items()}

    def trajectory(self, vid: int) -> list:
        return self.timelines().get(int(vid), [])

    # -------------------------------------------------------------- #
    # observers (repro.obs.health subscribes here)
    # -------------------------------------------------------------- #
    def add_observer(self, fn) -> None:
        with self._lock:
            if fn not in self._observers:
                self._observers.append(fn)

    def remove_observer(self, fn) -> None:
        with self._lock:
            if fn in self._observers:
                self._observers.remove(fn)

    def _notify(self, event: dict) -> None:
        for fn in list(self._observers):
            fn(event)

    # -------------------------------------------------------------- #
    # export
    # -------------------------------------------------------------- #
    def records(self, last: int | None = None) -> list[FlightRecord]:
        """A snapshot of the retained records, oldest first."""
        with self._lock:
            recs = list(self._ring)
        return recs if last is None else recs[-int(last):]

    @property
    def runs(self) -> int:
        return self._runs

    def to_json(self, last: int | None = None) -> dict:
        with self._lock:
            return {
                "capacity": self.capacity,
                "runs": self._runs,
                "rounds_recorded": self.rounds_recorded,
                "dropped": max(self.rounds_recorded - len(self._ring), 0),
                "records": [r.to_json() for r in self.records(last)],
                "events": self.events(last),
                "watch": self.timelines(),
            }

    def dump(self, path: str, last: int | None = None) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(last), f)
        return path

    def reset(self) -> None:
        with self._lock:
            self._ring.clear()
            self._seq = 0
            self._runs = 0
            self._run = None
            self._context = {}
            self._timelines = {v: [] for v in self._timelines}
            self._events.clear()
            self.last_run_rounds = 0
            self.rounds_recorded = 0


# ------------------------------------------------------------------ #
# Process-wide default recorder — what the engines record against.
# ------------------------------------------------------------------ #

_DEFAULT = FlightRecorder()
_enabled = False


def recorder():
    """The hot-path accessor: the real recorder when enabled, the shared
    NULL_RECORDER otherwise. Engines call this once per run and branch on
    ``.active`` — the disabled path is one attribute read."""
    return _DEFAULT if _enabled else NULL_RECORDER


def get_recorder() -> FlightRecorder:
    """The default recorder itself (regardless of the enabled flag) —
    export/inspection paths (the HTTP endpoint, ``--flight`` dumps)."""
    return _DEFAULT


def enabled() -> bool:
    return _enabled


def enable(capacity: int | None = None) -> None:
    global _DEFAULT, _enabled
    if capacity is not None and capacity != _DEFAULT.capacity:
        _DEFAULT = FlightRecorder(capacity)
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def reset() -> None:
    _DEFAULT.reset()


def watch(ids) -> None:
    _DEFAULT.watch(ids)


def records(last: int | None = None) -> list[FlightRecord]:
    return _DEFAULT.records(last)


def to_json(last: int | None = None) -> dict:
    return _DEFAULT.to_json(last)


def dump(path: str, last: int | None = None) -> str:
    return _DEFAULT.dump(path, last)
