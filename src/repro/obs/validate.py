"""Chrome-trace JSON validator — the CI schema gate for exported traces.

Checks that a trace produced by ``repro.obs.trace`` (or any Chrome
``trace_event`` document of complete events) is well-formed:

  * the document is ``{"traceEvents": [...]}``; every event has a string
    ``name``, ``ph == "X"``, numeric non-negative ``ts``/``dur``, and
    integer ``pid``/``tid``; ``args``, when present, is an object;
  * spans on one thread properly NEST: sorted by start time, every pair
    of spans is either disjoint or one contains the other (a small float
    epsilon absorbs the ns->us conversion);
  * optionally (``--require-span`` / ``--min-coverage``): spans with a
    given name exist, and the fraction of their wall-clock covered by
    their direct child spans meets a floor — the "every batch is
    attributed to named phases" acceptance check, run against the real
    CLI artifacts in CI, not just unit-test traces.

Usage::

    python -m repro.obs.validate trace.json [more.json ...] \
        [--require-span NAME] [--min-coverage 0.95]
"""

from __future__ import annotations

import argparse
import json
import sys

_EPS_US = 0.01  # ns->us float conversion slack


class TraceValidationError(ValueError):
    pass


def _check_event(i: int, ev) -> None:
    if not isinstance(ev, dict):
        raise TraceValidationError(f"event {i}: not an object")
    if not isinstance(ev.get("name"), str) or not ev["name"]:
        raise TraceValidationError(f"event {i}: missing/empty name")
    if ev.get("ph") != "X":
        raise TraceValidationError(
            f"event {i} ({ev['name']}): ph must be 'X', got {ev.get('ph')!r}")
    for field in ("ts", "dur"):
        v = ev.get(field)
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            raise TraceValidationError(
                f"event {i} ({ev['name']}): {field} must be numeric")
        if v < 0:
            raise TraceValidationError(
                f"event {i} ({ev['name']}): negative {field} ({v})")
    for field in ("pid", "tid"):
        if not isinstance(ev.get(field), int):
            raise TraceValidationError(
                f"event {i} ({ev['name']}): {field} must be an int")
    if "args" in ev and not isinstance(ev["args"], dict):
        raise TraceValidationError(
            f"event {i} ({ev['name']}): args must be an object")


def _nesting_sweep(spans: list[dict]) -> dict[int, list[int]]:
    """Stack sweep of one thread's spans (sorted by start, longest first).

    Raises on partial overlap; returns ``{span_index: [child indices]}``
    with DIRECT children only (indices into the given list).
    """
    order = sorted(range(len(spans)),
                   key=lambda i: (spans[i]["ts"], -spans[i]["dur"]))
    children: dict[int, list[int]] = {i: [] for i in order}
    stack: list[int] = []  # indices of currently open spans
    for i in order:
        s, e = spans[i]["ts"], spans[i]["ts"] + spans[i]["dur"]
        while stack:
            top = spans[stack[-1]]
            top_end = top["ts"] + top["dur"]
            if s >= top_end - _EPS_US:
                stack.pop()          # previous span closed before we start
                continue
            if e > top_end + _EPS_US:
                raise TraceValidationError(
                    f"spans overlap without nesting: {spans[i]['name']!r} "
                    f"[{s:.3f}, {e:.3f}]us vs {top['name']!r} "
                    f"[{top['ts']:.3f}, {top_end:.3f}]us on tid "
                    f"{spans[i]['tid']}")
            break
        if stack:
            children[stack[-1]].append(i)
        stack.append(i)
    return children


def span_tree_coverage(events: list[dict], name: str) -> list[dict]:
    """Per-instance coverage of ``name`` spans by their direct children.

    Returns one ``{"dur_us", "child_us", "coverage", "children"}`` record
    per span named ``name``. Child intervals cannot overlap (nesting is
    validated first), so summing child durations is exact coverage.
    """
    out = []
    by_tid: dict[tuple, list[dict]] = {}
    for ev in events:
        by_tid.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for spans in by_tid.values():
        children = _nesting_sweep(spans)
        for i, kids in children.items():
            if spans[i]["name"] != name:
                continue
            dur = spans[i]["dur"]
            child_us = sum(spans[j]["dur"] for j in kids)
            out.append({
                "dur_us": dur,
                "child_us": child_us,
                "coverage": child_us / dur if dur > 0 else 1.0,
                "children": sorted({spans[j]["name"] for j in kids}),
            })
    return out


def validate_chrome_trace(doc) -> dict:
    """Validate one trace document; returns a summary dict or raises
    ``TraceValidationError``."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise TraceValidationError("document must be {'traceEvents': [...]}")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise TraceValidationError("traceEvents must be a list")
    for i, ev in enumerate(events):
        _check_event(i, ev)
    by_tid: dict[tuple, list[dict]] = {}
    for ev in events:
        by_tid.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    max_depth = 0
    for spans in by_tid.values():
        children = _nesting_sweep(spans)
        # depth via the child map (roots = spans that are nobody's child)
        child_ids = {j for kids in children.values() for j in kids}
        depth: dict[int, int] = {}

        def _depth(i: int) -> int:
            if i not in depth:
                depth[i] = 1 + max((_depth(j) for j in children[i]),
                                   default=0)
            return depth[i]

        for i in children:
            if i not in child_ids:
                max_depth = max(max_depth, _depth(i))
    names: dict[str, int] = {}
    for ev in events:
        names[ev["name"]] = names.get(ev["name"], 0) + 1
    return {"events": len(events), "threads": len(by_tid),
            "max_depth": max_depth, "names": names}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate Chrome trace_event JSON files")
    ap.add_argument("paths", nargs="+", help="trace JSON files to validate")
    ap.add_argument("--require-span", default=None, metavar="NAME",
                    help="fail unless >=1 span with this name exists")
    ap.add_argument("--min-coverage", type=float, default=None,
                    help="minimum fraction of each --require-span span's "
                         "wall covered by its direct child spans")
    args = ap.parse_args(argv)
    if args.min_coverage is not None and args.require_span is None:
        ap.error("--min-coverage requires --require-span")

    rc = 0
    for path in args.paths:
        try:
            with open(path) as f:
                doc = json.load(f)
            summary = validate_chrome_trace(doc)
            msg = (f"{path}: OK — {summary['events']} events, "
                   f"{summary['threads']} thread(s), "
                   f"max depth {summary['max_depth']}")
            if args.require_span is not None:
                cov = span_tree_coverage(doc["traceEvents"],
                                         args.require_span)
                if not cov:
                    raise TraceValidationError(
                        f"no span named {args.require_span!r}")
                worst = min(c["coverage"] for c in cov)
                msg += (f"; {len(cov)} {args.require_span!r} span(s), "
                        f"min child coverage {worst:.3f}")
                if args.min_coverage is not None and worst < args.min_coverage:
                    raise TraceValidationError(
                        f"{args.require_span!r} child coverage {worst:.3f} "
                        f"< required {args.min_coverage}")
            print(msg)
        except (OSError, json.JSONDecodeError, TraceValidationError) as e:
            print(f"{path}: INVALID — {e}", file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
