"""Unified observability layer: span tracing + metrics.

Dependency-free (stdlib-only) measurement substrate for the whole repo:

  * ``trace``    — nested-span tracer (context-manager API, monotonic
    clocks, thread-safe, per-span attributes, true no-op when disabled)
    with Chrome ``trace_event`` JSON export loadable in Perfetto /
    ``chrome://tracing``;
  * ``metrics``  — process-wide registry of counters, gauges, and
    reservoir histograms (p50/p95/p99), exportable as JSON and the
    Prometheus text format;
  * ``validate`` — Chrome-trace schema/nesting/coverage validator
    (``python -m repro.obs.validate``), the CI gate for exported traces;
  * ``flight``   — convergence flight recorder: a bounded ring of
    per-round records (frontier, messages, estimate-drop histogram,
    device/host wall) captured in every execution mode, with opt-in
    per-vertex trajectory watchlists;
  * ``health``   — online invariant monitor over the flight stream
    (monotone estimates, frontier progress, message-bill
    mode-invariance) feeding anomalies into the tracer and a health
    gauge into the metrics registry;
  * ``http``     — dependency-free threaded endpoint serving
    ``/metrics``, ``/healthz``, and ``/debug/flight`` live
    (``kcore_serve --listen``).

The hot paths are instrumented permanently (host round loop, fused
runtime, streaming batch phases, window advances, the serving loop, XLA
compile durations via repro.core.jit_telemetry); tracing costs nothing
until ``trace.enable()`` — surfaced as ``--trace out.json`` /
``--metrics`` on ``repro.launch.kcore_run`` and ``kcore_serve``.
"""

from repro.obs import flight, health, http, metrics, trace
from repro.obs.flight import FlightRecord, FlightRecorder, get_recorder
from repro.obs.health import InvariantMonitor, get_monitor
from repro.obs.http import ObsHTTPServer, start_server
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_registry)
from repro.obs.trace import Span, Tracer, get_tracer
from repro.obs.validate import (TraceValidationError, span_tree_coverage,
                                validate_chrome_trace)

__all__ = [
    "trace",
    "metrics",
    "flight",
    "health",
    "http",
    "Tracer",
    "Span",
    "get_tracer",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "get_registry",
    "FlightRecorder",
    "FlightRecord",
    "get_recorder",
    "InvariantMonitor",
    "get_monitor",
    "ObsHTTPServer",
    "start_server",
    "validate_chrome_trace",
    "span_tree_coverage",
    "TraceValidationError",
]
