"""Nested-span tracer with Chrome ``trace_event`` export.

The paper's whole contribution is *measurement* — running time and message
traffic of the distributed decomposition — so the repo's hot paths carry
spans: the host round loop (core/kcore.py), the fused convergence runtime
(core/runtime.py), the streaming engine's batch phases (patch / seed /
converge / host-reconstruct), window advances (temporal/window.py), and
the serving loop. XLA compile durations are attributed to the enclosing
span by repro.core.jit_telemetry (``xla.compile`` spans).

Design constraints, in order:

  1. **Zero cost when disabled.** Every engine keeps its spans in place
     permanently; the disabled path is one attribute check returning a
     shared no-op span. No timestamps are taken, nothing allocates per
     span, and CI's perf gates run with tracing off.
  2. **Dependency-free.** stdlib only (``time``, ``threading``, ``json``)
     — the tracer must be importable before jax, from the validator CLI,
     and from any future subprocess worker.
  3. **Thread-safe.** Spans nest per thread (a ``threading.local`` stack);
     the finished-event list is lock-protected. Concurrent serving threads
     each get a coherent span tree under their own ``tid``.

Export is the Chrome ``trace_event`` JSON array-of-complete-events format
(``ph: "X"``): load the file in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing`` to see the nested flame graph. Timestamps come from
``time.perf_counter_ns`` (monotonic), reported in microseconds.

API sketch (module-level functions drive one process-wide default tracer)::

    from repro.obs import trace

    trace.enable()
    with trace.span("batch", graph="EEN") as sp:
        with trace.span("patch"):
            ...
        sp.set(rounds=3, messages=1234)      # attach attrs any time
    trace.export("out.json")                 # open in Perfetto
"""

from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared no-op span returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One live span: a context manager that records a complete event."""

    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> "Span":
        """Attach attributes to this span (shows up under ``args``)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._tracer._stack().append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._emit(self.name, self._t0, t1 - self._t0, self.attrs)
        return False


class Tracer:
    """A span recorder. Most callers use the module-level default tracer."""

    def __init__(self):
        self._enabled = False
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._tls = threading.local()

    # ------------------------------------------------------------------ #
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    def reset(self) -> None:
        """Drop every recorded event (keeps the enabled flag)."""
        with self._lock:
            self._events = []

    # ------------------------------------------------------------------ #
    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _emit(self, name: str, t0_ns: int, dur_ns: int, attrs: dict) -> None:
        ev = {
            "name": name,
            "ph": "X",
            "ts": t0_ns / 1e3,          # Chrome wants microseconds
            "dur": max(dur_ns, 0) / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
        }
        if attrs:
            ev["args"] = dict(attrs)
        with self._lock:
            self._events.append(ev)

    # ------------------------------------------------------------------ #
    def span(self, name: str, **attrs):
        """Context manager for one nested span (no-op while disabled)."""
        if not self._enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    def current(self) -> Span | None:
        """The innermost open span on this thread, if any."""
        stack = self._stack()
        return stack[-1] if stack else None

    def annotate(self, **attrs) -> None:
        """Attach attributes to the innermost open span (no-op otherwise)."""
        if not self._enabled:
            return
        cur = self.current()
        if cur is not None:
            cur.set(**attrs)

    def record(self, name: str, dur_s: float, **attrs) -> None:
        """Record an already-elapsed duration as a span ending *now*.

        For externally measured work (XLA compile durations from
        jax.monitoring) where only the duration is known: the span is
        synthesized as ending at the current clock, so it lands inside
        whatever span was open while the work ran.
        """
        if not self._enabled:
            return
        dur_ns = max(int(dur_s * 1e9), 0)
        self._emit(name, time.perf_counter_ns() - dur_ns, dur_ns, attrs)

    # ------------------------------------------------------------------ #
    def events(self) -> list[dict]:
        """A snapshot copy of every finished event."""
        with self._lock:
            return [dict(e) for e in self._events]

    def chrome_trace(self) -> dict:
        """The Chrome ``trace_event`` document (Perfetto-loadable)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


# ---------------------------------------------------------------------- #
# Process-wide default tracer — what the engines instrument against.
# ---------------------------------------------------------------------- #

_DEFAULT = Tracer()


def get_tracer() -> Tracer:
    return _DEFAULT


def enabled() -> bool:
    return _DEFAULT.enabled


def enable() -> None:
    _DEFAULT.enable()


def disable() -> None:
    _DEFAULT.disable()


def reset() -> None:
    _DEFAULT.reset()


def span(name: str, **attrs):
    return _DEFAULT.span(name, **attrs)


def current() -> Span | None:
    return _DEFAULT.current()


def annotate(**attrs) -> None:
    _DEFAULT.annotate(**attrs)


def record(name: str, dur_s: float, **attrs) -> None:
    _DEFAULT.record(name, dur_s, **attrs)


def events() -> list[dict]:
    return _DEFAULT.events()


def chrome_trace() -> dict:
    return _DEFAULT.chrome_trace()


def export(path: str) -> str:
    return _DEFAULT.export(path)
