"""Dependency-free threaded HTTP endpoint for live observability.

``ObsHTTPServer`` is a stdlib ``ThreadingHTTPServer`` on a daemon thread
serving read-only routes:

* ``/metrics`` — Prometheus text exposition (the process-default metrics
  registry plus any registries added via ``add_registry``, e.g. a
  ``KCoreServer``'s per-server registry);
* ``/healthz`` — the invariant monitor's verdict as JSON; HTTP 200 while
  healthy, 503 once an anomaly has been observed;
* ``/debug/flight`` — the flight recorder's recent rounds (and watchlist
  timelines) as JSON; ``?n=50`` limits to the last n records;
* ``/query/<op>`` — live core-number reads, once a snapshot-isolated
  query backend has been attached via ``attach_query_backend`` (the
  ``ConcurrentKCoreServer`` in streaming/concurrent.py — duck-typed so
  the obs layer never imports streaming). Ops mirror the serving layer:
  ``/query/core?v=1,2,3``, ``/query/in_kcore?v=..&k=..``,
  ``/query/members?k=..``, ``/query/max_k``,
  ``/query/core_asof?t=..[&v=..]``, plus ``/query/stats``. Malformed
  requests come back HTTP 400 with a structured ``{"error": ...}`` body
  (the backend's contract: bad requests never touch serving state);
  a draining backend answers 503.

Mounted by ``kcore_serve --listen PORT``; ``port=0`` binds an ephemeral
port (tests). The server is intentionally started BEFORE heavy jax
initialization so external pollers can reach ``/healthz`` during startup.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from repro.obs import flight, health, metrics

_INDEX = b"repro obs: /metrics /healthz /debug/flight /query/<op>\n"


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-obs/1"

    # the owning ObsHTTPServer is attached to the socket server
    @property
    def obs(self) -> "ObsHTTPServer":
        return self.server.obs  # type: ignore[attr-defined]

    def log_message(self, fmt, *args):  # noqa: ARG002 - silence stderr
        pass

    def do_GET(self):  # noqa: N802 - BaseHTTPRequestHandler API
        url = urlparse(self.path)
        try:
            if url.path == "/metrics":
                body = self.obs.render_metrics().encode()
                self._reply(200, body,
                            "text/plain; version=0.0.4; charset=utf-8")
            elif url.path == "/healthz":
                v = health.verdict()
                self._reply(200 if v["status"] == "ok" else 503,
                            json.dumps(v).encode(), "application/json")
            elif url.path == "/debug/flight":
                qs = parse_qs(url.query)
                last = None
                if "n" in qs:
                    last = max(int(qs["n"][0]), 0)
                payload = flight.get_recorder().to_json(last)
                payload["enabled"] = flight.enabled()
                self._reply(200, json.dumps(payload).encode(),
                            "application/json")
            elif url.path.startswith("/query/"):
                self._query(url)
            elif url.path == "/":
                self._reply(200, _INDEX, "text/plain; charset=utf-8")
            else:
                self._reply(404, b"not found\n", "text/plain; charset=utf-8")
        except Exception as exc:  # never kill the serving thread
            self._reply(500, f"error: {exc}\n".encode(),
                        "text/plain; charset=utf-8")

    def _query(self, url) -> None:
        backend = self.obs.query_backend
        if backend is None:
            self._reply(404, b"no query backend attached\n",
                        "text/plain; charset=utf-8")
            return
        op = url.path[len("/query/"):]
        if op == "stats":
            self._reply(200, json.dumps(backend.stats()).encode(),
                        "application/json")
            return
        qs = parse_qs(url.query)
        try:
            vertices = ([int(x) for x in qs["v"][0].split(",") if x]
                        if "v" in qs else None)
            k = int(qs["k"][0]) if "k" in qs else None
            t = float(qs["t"][0]) if "t" in qs else None
        except ValueError as exc:
            self._reply(400, json.dumps({"op": op, "ok": False,
                                         "error": f"bad query arg: {exc}"}
                                        ).encode(), "application/json")
            return
        out = backend.handle_query(op, vertices=vertices, k=k, t=t)
        if out.get("ok"):
            code = 200
        elif "draining" in out.get("error", ""):
            code = 503
        else:
            code = 400
        self._reply(code, json.dumps(out).encode(), "application/json")

    def _reply(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)


class ObsHTTPServer:
    """Threaded HTTP server exposing metrics / health / flight state."""

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registries=()):
        self._host = host
        # guards the registry list and backend reference: scrapes run on
        # per-connection threads while the main thread mounts late (the
        # serve CLI starts the endpoint before jax init, then attaches)
        self._lock = threading.Lock()
        self._registries: list[metrics.MetricsRegistry] = list(registries)
        self._query_backend = None
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.obs = self  # type: ignore[attr-defined]
        self._thread: threading.Thread | None = None

    @property
    def host(self) -> str:
        return self._host

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def add_registry(self, registry: metrics.MetricsRegistry) -> None:
        """Also expose a non-default registry (e.g. KCoreServer.metrics)."""
        with self._lock:
            if registry not in self._registries:
                self._registries.append(registry)

    def attach_query_backend(self, backend) -> None:
        """Mount a live-read backend for the ``/query/*`` routes.

        Duck-typed: anything with ``handle_query(op, vertices, k, t) ->
        dict`` and ``stats() -> dict`` — in practice the
        ``ConcurrentKCoreServer`` from streaming/concurrent.py."""
        with self._lock:
            self._query_backend = backend

    @property
    def query_backend(self):
        with self._lock:
            return self._query_backend

    def render_metrics(self) -> str:
        with self._lock:
            registries = list(self._registries)
        parts = [metrics.to_prometheus()]
        parts.extend(r.to_prometheus() for r in registries)
        return "".join(p if p.endswith("\n") or not p else p + "\n"
                       for p in parts)

    def start(self) -> "ObsHTTPServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="repro-obs-http",
                daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=5)
            self._thread = None
        self._httpd.server_close()


def start_server(port: int = 0, host: str = "127.0.0.1",
                 registries=()) -> ObsHTTPServer:
    """Create and start an ObsHTTPServer (convenience for CLIs)."""
    return ObsHTTPServer(port=port, host=host, registries=registries).start()
