from repro.models.gnn import common, egnn, graphcast, mace, schnet, steps

__all__ = ["common", "egnn", "graphcast", "mace", "schnet", "steps"]
