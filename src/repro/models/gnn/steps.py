"""Train steps + dry-run input specs for the GNN family.

All 4 assigned shapes lower to the GraphBatch layout (see common.py):
  full_graph_sm / ogb_products — node CE over the whole graph;
  minibatch_lg — node CE over the seed prefix of the sampled block;
  molecule — per-graph energy MSE.

Distribution: node/edge arrays sharded over ALL mesh axes flattened
(P(("pod","data","model"))) — the graph engines are memory/collective bound,
not matmul bound, so every chip takes a slice of edges; cross-shard feature
gathers become all-gathers exactly like the k-core engine's estimate
broadcast."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import GNNConfig, ShapeSpec
from repro.models.gnn import egnn, graphcast, mace, schnet
from repro.optim import AdamWConfig, adamw_update

_MODELS = {"mace": mace, "schnet": schnet, "egnn": egnn,
           "graphcast": graphcast}


def model_module(cfg: GNNConfig):
    return _MODELS[cfg.kind]


def init_params(cfg: GNNConfig, key, d_in=None, n_classes: int = 0):
    mod = model_module(cfg)
    params = mod.init_params(cfg, key, d_in=d_in)
    if n_classes:
        k = jax.random.fold_in(key, 7)
        params["classify"] = jax.random.normal(
            k, (cfg.d_hidden, n_classes)) / math.sqrt(cfg.d_hidden)
    return params


def node_logits(params, cfg: GNNConfig, batch):
    h = model_module(cfg).node_embeddings(params, cfg, batch)
    return h @ params["classify"].astype(h.dtype)


def _ce_loss(params, cfg, batch, predict_mask):
    logits = node_logits(params, cfg, batch).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=1)[:, 0]
    m = predict_mask.astype(jnp.float32)
    return jnp.sum((lse - gold) * m) / jnp.maximum(m.sum(), 1)


def _energy_loss(params, cfg, batch, n_graphs):
    mod = model_module(cfg)
    if cfg.kind == "graphcast":           # no energy head: pool logits
        h = mod.node_embeddings(params, cfg, batch)
        e = jax.ops.segment_sum(
            h.mean(-1) * batch["node_mask"], batch["graph_id"],
            num_segments=n_graphs)
    else:
        e = mod.energy(params, cfg, batch, n_graphs)
    return jnp.mean((e.astype(jnp.float32) - batch["labels"]) ** 2)


def make_train_step(cfg: GNNConfig, shape: ShapeSpec,
                    opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or AdamWConfig(lr=1e-3, weight_decay=0.0)
    kind = shape.kind

    def loss_fn(params, batch):
        if kind == "molecule":
            return _energy_loss(params, cfg, batch, shape.params["batch"])
        if kind == "minibatch":
            n = batch["node_mask"].shape[0]
            pm = (jnp.arange(n) < shape.params["batch_nodes"]) & \
                batch["node_mask"]
            return _ce_loss(params, cfg, batch, pm)
        return _ce_loss(params, cfg, batch, batch["node_mask"])

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------- #
# Dry-run specs
# ---------------------------------------------------------------------- #

def _pad512(x: int) -> int:
    """Round up: node arrays to a 512 multiple (lcm of both production
    meshes), big edge arrays to 512*64 so MACE's power-of-two edge chunking
    keeps 512-divisible chunks; masks make padding semantically inert."""
    m = 512 * 64 if x > 4_000_000 else 512
    return ((x + m - 1) // m) * m


def batch_specs(cfg: GNNConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs of the GraphBatch for each assigned shape."""
    f32, i32 = jnp.float32, jnp.int32
    k = shape.kind
    if k == "molecule":
        B = shape.params["batch"]
        N = _pad512(B * shape.params["n_nodes"])
        E = _pad512(2 * B * shape.params["n_edges"])
        d_feat, labels = None, jax.ShapeDtypeStruct((B,), f32)
    elif k == "minibatch":
        seeds = shape.params["batch_nodes"]
        f = shape.params["fanout"]
        sizes = [seeds]
        for fo in f:
            sizes.append(sizes[-1] * fo)
        N = _pad512(sum(sizes))
        E = _pad512(sum(sizes[i + 1] for i in range(len(f))))
        d_feat = shape.params["d_feat"]
        labels = jax.ShapeDtypeStruct((N,), i32)
    else:
        N = _pad512(shape.params["n_nodes"])
        E = _pad512(2 * shape.params["n_edges"])
        d_feat = shape.params["d_feat"]
        labels = jax.ShapeDtypeStruct((N,), i32)
    specs = {
        "src": jax.ShapeDtypeStruct((E,), i32),
        "dst": jax.ShapeDtypeStruct((E,), i32),
        "edge_mask": jax.ShapeDtypeStruct((E,), jnp.bool_),
        "node_mask": jax.ShapeDtypeStruct((N,), jnp.bool_),
        "graph_id": jax.ShapeDtypeStruct((N,), i32),
        "positions": jax.ShapeDtypeStruct((N, 3), f32),
        "species": jax.ShapeDtypeStruct((N,), i32),
        "labels": labels,
    }
    if d_feat:
        specs["feats"] = jax.ShapeDtypeStruct((N, d_feat), f32)
    return specs


def n_classes_for(shape: ShapeSpec) -> int:
    return int(shape.params.get("n_classes", 0))


def build_train(cfg: GNNConfig, shape: ShapeSpec, mesh):
    from repro.models.gnn.common import set_flat_sharding
    set_flat_sharding(mesh, mesh.axis_names if mesh is not None else None)
    step = make_train_step(cfg, shape)
    bspecs = batch_specs(cfg, shape)
    d_in = bspecs["feats"].shape[1] if "feats" in bspecs else None
    pshapes = jax.eval_shape(
        functools.partial(init_params, cfg, d_in=d_in,
                          n_classes=n_classes_for(shape)),
        jax.random.key(0))
    specs = {"batch": bspecs, "_params": pshapes}
    if mesh is None:
        return step, specs, None, None
    flat = P(tuple(mesh.axis_names))
    def batch_spec_of(s):
        return NamedSharding(mesh, flat if s.shape and s.shape[0] > 1024
                             else P())
    batch_sh = jax.tree.map(batch_spec_of, bspecs)
    params_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), pshapes)
    opt_sh = {"m": params_sh, "v": params_sh,
              "count": NamedSharding(mesh, P())}
    in_sh = (params_sh, opt_sh, batch_sh)
    out_sh = (params_sh, opt_sh, NamedSharding(mesh, P()))
    return step, specs, in_sh, out_sh


# every assigned GNN shape lowers a train step
build_step = build_train
