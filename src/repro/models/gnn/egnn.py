"""EGNN [arXiv:2102.09844] — E(n)-equivariant GNN.

Per layer:  m_ij = phi_e(h_i, h_j, |x_i - x_j|^2)
            x_i' = x_i + C * sum_j (x_i - x_j) phi_x(m_ij)
            h_i' = phi_h(h_i, sum_j m_ij)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.util import scan_unroll
from repro.configs.base import GNNConfig
from repro.models.gnn.common import layernorm, mlp_apply, mlp_init, scatter_sum


def init_params(cfg: GNNConfig, key, d_in: int | None = None):
    d = cfg.d_hidden
    ks = jax.random.split(key, 3 + 3 * cfg.n_layers)
    params = {
        "embed_species": jax.random.normal(
            ks[0], (cfg.params["n_species"], d)) * 0.1,
        "proj_in": mlp_init(ks[1], (d_in, d)) if d_in else None,
        "readout": mlp_init(ks[2], (d, d, 1)),
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        params["blocks"].append({
            "phi_e": mlp_init(ks[2 + 3 * i], (2 * d + 1, d, d)),
            "phi_x": mlp_init(ks[3 + 3 * i], (d, d, 1)),
            "phi_h": mlp_init(ks[4 + 3 * i], (2 * d, d, d)),
        })
    params["blocks"] = jax.tree.map(lambda *x: jnp.stack(x),
                                    *params["blocks"]) \
        if cfg.n_layers > 1 else jax.tree.map(lambda x: x[None],
                                              params["blocks"][0])
    return params


def node_embeddings(params, cfg: GNNConfig, batch, return_pos=False):
    n = batch["species"].shape[0]
    h = jnp.take(params["embed_species"], batch["species"], axis=0)
    if params.get("proj_in") is not None and "feats" in batch:
        h = h + mlp_apply(params["proj_in"], batch["feats"].astype(h.dtype))
    x = batch["positions"].astype(h.dtype)
    src, dst = batch["src"], batch["dst"]
    emask = batch["edge_mask"].astype(h.dtype)

    def block(carry, bp):
        h, x = carry
        rel = x[dst] - x[src]
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        m = mlp_apply(bp["phi_e"], jnp.concatenate(
            [h[dst], h[src], d2], axis=-1), final_act=True)
        m = m * emask[:, None]
        # coordinate update (normalized rel for stability)
        wx = mlp_apply(bp["phi_x"], m)
        xagg = scatter_sum(rel / (jnp.sqrt(d2) + 1) * wx, dst, n)
        x = x + xagg / 8.0
        magg = scatter_sum(m, dst, n)
        h = h + mlp_apply(bp["phi_h"], jnp.concatenate([h, magg], axis=-1))
        h = layernorm(h)   # stabilizes high-degree (non-molecular) graphs
        return (h, x), None

    (h, x), _ = jax.lax.scan(block, (h, x), params["blocks"],
                             unroll=scan_unroll())
    return (h, x) if return_pos else h


def energy(params, cfg: GNNConfig, batch, n_graphs: int):
    h = node_embeddings(params, cfg, batch)
    e_atom = mlp_apply(params["readout"], h)[:, 0]
    e_atom = e_atom * batch["node_mask"].astype(e_atom.dtype)
    return scatter_sum(e_atom, batch["graph_id"], n_graphs)
