"""MACE [arXiv:2206.07697] — higher-order E(3)-equivariant message passing.

Faithful structure, TPU-native tensor algebra: instead of spherical-harmonic
irrep arrays + CG coefficient tables (pointer-heavy), l=0/1/2 features are
carried as (scalars, vectors, symmetric-traceless matrices) per channel and
all products use closed-form equivariant bilinear maps (dot, cross, outer-sym,
matvec, trace) — equivalent capacity for l_max=2, equivariant by
construction (verified by rotation tests), and every op is a dense einsum.

Per MACE layer:
  A-features (one-particle basis): A_l(u) = sum_edges R_l(r) Y_l(r_hat) (W h_v)
  B-features (correlation order 3): products A (x) A (x) A contracted back to
  l <= 2 via the bilinear maps; update = linear(B) + residual.
Documented simplifications (DESIGN.md): real-SH normalization absorbed into
learned radial weights; channel-diagonal tensor products with channel mixing
in the surrounding linears (MACE's own factorization).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.util import scan_unroll
from repro.configs.base import GNNConfig
from repro.models.gnn.common import (COMPUTE_DTYPE, bessel_rbf, mlp_apply,
                                     mlp_init, scatter_sum)

_EYE3 = jnp.eye(3)


def _sym_traceless(m):
    s = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    return s - tr * _EYE3 / 3.0


def init_params(cfg: GNNConfig, key, d_in: int | None = None):
    C = cfg.d_hidden
    p = cfg.params
    ks = jax.random.split(key, 4 + 4 * cfg.n_layers)
    params = {
        "embed_species": jax.random.normal(ks[0], (p["n_species"], C)) * 0.1,
        "proj_in": mlp_init(ks[1], (d_in, C)) if d_in else None,
        "blocks": [],
        "readout": mlp_init(ks[2], (C, C, 1)),
    }
    for i in range(cfg.n_layers):
        k = jax.random.split(ks[4 + i], 6)
        params["blocks"].append({
            # radial MLP: n_rbf -> weights for each of the 3 l-channels
            "radial": mlp_init(k[0], (p["n_rbf"], C, 3 * C)),
            "w_h": jax.random.normal(k[1], (C, C)) / jnp.sqrt(C),
            # linear mix of the 8C ACE invariants back into C channels
            "w_b": jax.random.normal(k[2], (8 * C, C)) / jnp.sqrt(8 * C),
            "update": mlp_init(k[5], (2 * C, C, C)),
        })
    params["blocks"] = jax.tree.map(lambda *x: jnp.stack(x),
                                    *params["blocks"]) \
        if cfg.n_layers > 1 else jax.tree.map(lambda x: x[None],
                                              params["blocks"][0])
    return params


def node_embeddings(params, cfg: GNNConfig, batch):
    C = cfg.d_hidden
    p = cfg.params
    n = batch["species"].shape[0]
    h = jnp.take(params["embed_species"], batch["species"], axis=0) \
        .astype(COMPUTE_DTYPE)
    if params.get("proj_in") is not None and "feats" in batch:
        h = h + mlp_apply(params["proj_in"], batch["feats"].astype(h.dtype))

    src, dst = batch["src"], batch["dst"]
    rel = batch["positions"][dst] - batch["positions"][src]
    dist = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
    rhat = rel / dist[:, None]
    # l=0,1,2 "spherical harmonics" in tensor form
    y1 = rhat.astype(COMPUTE_DTYPE)                  # (E, 3)
    y2 = _sym_traceless(rhat[:, :, None] * rhat[:, None, :]) \
        .astype(COMPUTE_DTYPE)                       # (E, 3, 3)
    rbf = bessel_rbf(dist, p["n_rbf"], p["cutoff"])
    emask = batch["edge_mask"].astype(h.dtype)

    E_total = src.shape[0]
    # Edge-chunked A-feature accumulation bounds the (E, C, 9) message
    # tensor on a SINGLE device. Under a mesh the sharded scatter_sum
    # already keeps the per-device slice at E/devices rows (and scan-of-
    # chunks would stack carries for backward), so chunking only kicks in
    # for huge single-device runs. Chunks stay 512-divisible for the
    # sharded scatter path.
    from repro.models.gnn.common import _FLAT_AXES_SHARDING
    single_dev = _FLAT_AXES_SHARDING["mesh"] is None
    n_chunks = 1
    while single_dev and E_total // n_chunks > 2_000_000:
        n_chunks *= 2
    while n_chunks > 1 and (E_total % n_chunks or
                            (E_total // n_chunks) % 512):
        n_chunks //= 2
    Ec = E_total // n_chunks

    def block(h, bp):
        hw = h @ bp["w_h"].astype(h.dtype)                     # (n, C)

        def chunk(carry, i):
            from repro.models.gnn.common import constrain_rows, gather_rows
            a0, a1, a2 = carry
            sl = lambda x: lax.dynamic_slice_in_dim(x, i * Ec, Ec)
            radial = mlp_apply(bp["radial"], sl(rbf).astype(h.dtype))
            r0, r1, r2 = jnp.split(radial * sl(emask)[:, None], 3, axis=-1)
            hsrc = gather_rows(hw, sl(src))                    # (Ec, C)
            dst_c = sl(dst)
            a0 += scatter_sum(r0 * hsrc, dst_c, n)
            a1 += scatter_sum((r1 * hsrc)[:, :, None] * sl(y1)[:, None, :],
                              dst_c, n)
            a2 += scatter_sum((r2 * hsrc)[:, :, None, None] *
                              sl(y2)[:, None, :, :], dst_c, n)
            return (constrain_rows(a0), constrain_rows(a1),
                    constrain_rows(a2)), None

        C_ = h.shape[1]
        init = (jnp.zeros((n, C_), h.dtype),
                jnp.zeros((n, C_, 3), h.dtype),
                jnp.zeros((n, C_, 3, 3), h.dtype))
        (a0, a1, a2), _ = lax.scan(jax.checkpoint(chunk), init,
                                   jnp.arange(n_chunks),
                                   unroll=scan_unroll())
        # B-features: channel-diagonal ACE invariants, correlation <= 3
        dot11 = jnp.sum(a1 * a1, axis=-1)                      # A1.A1
        tr22 = jnp.einsum("ncij,ncij->nc", a2, a2)             # tr(A2 A2)
        quad = jnp.einsum("nci,ncij,ncj->nc", a1, a2, a1)      # A1' A2 A1
        tr222 = jnp.einsum("ncij,ncjk,ncki->nc", a2, a2, a2)   # tr(A2^3)
        b = jnp.concatenate(
            [a0, a0 * a0, dot11, tr22,              # order 1-2
             quad, tr222, a0 * dot11, a0 * tr22],   # order 3
            axis=-1)                                           # (n, 8C)
        feats = b @ bp["w_b"].astype(h.dtype)
        h = h + mlp_apply(bp["update"],
                          jnp.concatenate([h, feats], axis=-1))
        return h, None

    h, _ = jax.lax.scan(block, h, params["blocks"], unroll=scan_unroll())
    return h


def energy(params, cfg: GNNConfig, batch, n_graphs: int):
    h = node_embeddings(params, cfg, batch)
    e_atom = mlp_apply(params["readout"], h)[:, 0]
    e_atom = e_atom * batch["node_mask"].astype(e_atom.dtype)
    return scatter_sum(e_atom, batch["graph_id"], n_graphs)
