"""Shared GNN substrate.

JAX has no native sparse message passing — per the assignment and
kernel_taxonomy §GNN, scatter/gather message passing is built on
``jax.ops.segment_sum`` over an edge-index list. This module provides that
substrate plus the uniform GraphBatch layout every assigned GNN consumes.

All four assigned shapes lower to the same layout:
  * full_graph_sm / ogb_products — the whole graph as one batch;
  * minibatch_lg — the sampled subgraph (union of sampler layers) with
    predictions on the seed prefix;
  * molecule — a disjoint union of B small graphs with ``graph_id`` pooling.

Geometric models (MACE/SchNet/EGNN) require positions + species; for the
citation-shaped cells these are synthesized inputs (DESIGN.md §6).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structs import Graph


# GraphBatch: a plain dict of arrays (pytree-friendly):
#   feats (N, d_feat) | species (N,) | positions (N, 3)
#   src, dst (E,) int32 | edge_mask (E,) bool | node_mask (N,) bool
#   graph_id (N,) int32 | labels (N,) int32 or (G,) f32


def scatter_sum(values, index, n):
    """Segment-sum messages ``values`` (E, ...) into ``n`` destinations.

    Single device: jax.ops.segment_sum. Under a mesh (flat-sharding context
    set): a shard_map with per-device local segment-sum + psum_scatter —
    GSPMD cannot partition scatter and falls back to full replication
    (measured 49GB/device on graphcast x ogb_products), while the explicit
    reduce-scatter is the k-core engine's own aggregation pattern."""
    mesh, axes = _FLAT_AXES_SHARDING["mesh"], _FLAT_AXES_SHARDING["axes"]
    if mesh is None or values.shape[0] < 4096 or n % _mesh_size(mesh):
        return jax.ops.segment_sum(values, index, num_segments=n)
    from jax.sharding import PartitionSpec as P

    def local(v, i):
        full = jax.ops.segment_sum(v, i, num_segments=n)
        return jax.lax.psum_scatter(full, axes, scatter_dimension=0,
                                    tiled=True)

    from repro.distribution.compat import shard_map

    rest = (None,) * (values.ndim - 1)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, *rest), P(axes)),
        out_specs=P(axes, *rest))(values, index)


def gather_rows(h, idx):
    """h[idx] with h row-sharded: explicit all-gather + local take (the
    estimate-broadcast pattern from core/kcore.py) instead of GSPMD's
    replicated gather."""
    return gather_rows_multi(h, (idx,))[0]


def gather_rows_multi(h, idxs: tuple):
    """Gather h rows for SEVERAL index vectors from ONE all-gather —
    a GraphNet block needs h[src] and h[dst]; sharing the broadcast halves
    the dominant collective (§Perf graphcast iteration 2)."""
    mesh, axes = _FLAT_AXES_SHARDING["mesh"], _FLAT_AXES_SHARDING["axes"]
    if mesh is None or h.shape[0] % _mesh_size(mesh) or \
            any(i.shape[0] % _mesh_size(mesh) for i in idxs):
        return tuple(jnp.take(h, i, axis=0) for i in idxs)
    from jax.sharding import PartitionSpec as P

    def local(h_l, *i_l):
        hg = jax.lax.all_gather(h_l, axes, axis=0, tiled=True)
        return tuple(jnp.take(hg, i, axis=0) for i in i_l)

    from repro.distribution.compat import shard_map

    rest = (None,) * (h.ndim - 1)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, *rest),) + (P(axes),) * len(idxs),
        out_specs=(P(axes, *rest),) * len(idxs))(h, *idxs)


def _mesh_size(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n


def scatter_mean(values, index, n, eps=1e-9):
    s = scatter_sum(values, index, n)
    cnt = scatter_sum(jnp.ones(values.shape[:1], values.dtype), index, n)
    return s / (cnt[:, None] + eps) if values.ndim > 1 else s / (cnt + eps)


COMPUTE_DTYPE = jnp.bfloat16   # GNN activation dtype (params stay fp32)

# Flat row-sharding context for full-batch graph work: node/edge arrays are
# sharded over every mesh axis (set by gnn.steps when a mesh is present;
# None on the single-device smoke path). GSPMD needs these constraints
# INSIDE the layer loop or it replicates the (n_nodes, d) carries — measured
# 167GB/device on graphcast x ogb_products without them.
_FLAT_AXES_SHARDING: dict = {"mesh": None, "axes": None}


def set_flat_sharding(mesh, axes) -> None:
    _FLAT_AXES_SHARDING["mesh"] = mesh
    _FLAT_AXES_SHARDING["axes"] = tuple(axes) if axes else None


def constrain_rows(x):
    """Shard dim 0 over all mesh axes (no-op without a mesh context)."""
    mesh = _FLAT_AXES_SHARDING["mesh"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = P(_FLAT_AXES_SHARDING["axes"], *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def mlp_init(key, sizes, dtype=jnp.float32):
    ks = jax.random.split(key, len(sizes) - 1)
    return [{
        "w": jax.random.normal(k, (a, b), dtype) * (1.0 / np.sqrt(a)),
        "b": jnp.zeros((b,), dtype),
    } for k, a, b in zip(ks, sizes[:-1], sizes[1:])]


def mlp_apply(layers, x, act=jax.nn.silu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"].astype(x.dtype) + l["b"].astype(x.dtype)
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def layernorm(x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------- #
# Radial bases
# ---------------------------------------------------------------------- #

def gaussian_rbf(dist, n_rbf: int, cutoff: float):
    """SchNet-style Gaussian radial basis."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * (dist[..., None] - centers) ** 2)


def bessel_rbf(dist, n_rbf: int, cutoff: float):
    """MACE/NequIP Bessel basis with smooth cutoff envelope."""
    d = jnp.maximum(dist, 1e-6)[..., None]
    n = jnp.arange(1, n_rbf + 1)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d
    x = jnp.clip(dist / cutoff, 0, 1)[..., None]
    envelope = 1 - 10 * x**3 + 15 * x**4 - 6 * x**5   # polynomial cutoff p=3
    return basis * envelope


# ---------------------------------------------------------------------- #
# Batch builders (host-side, numpy)
# ---------------------------------------------------------------------- #

def batch_from_graph(g: Graph, d_feat: int, n_classes: int, seed: int = 0,
                     with_positions: bool = True) -> dict:
    rng = np.random.default_rng(seed)
    batch = {
        "src": g.src.astype(np.int32),
        "dst": g.dst.astype(np.int32),
        "edge_mask": np.ones(g.num_arcs, bool),
        "node_mask": np.ones(g.n, bool),
        "graph_id": np.zeros(g.n, np.int32),
        "feats": rng.normal(size=(g.n, d_feat)).astype(np.float32),
        "labels": rng.integers(0, n_classes, g.n).astype(np.int32),
    }
    if with_positions:
        batch["positions"] = rng.normal(size=(g.n, 3)).astype(np.float32) * 3
        batch["species"] = rng.integers(0, 4, g.n).astype(np.int32)
    return batch


def batch_molecules(n_mols: int, n_nodes: int, n_edges: int, n_species: int,
                    seed: int = 0) -> dict:
    """Disjoint union of n_mols random molecules (fixed nodes/edges each)."""
    rng = np.random.default_rng(seed)
    N = n_mols * n_nodes
    offsets = np.repeat(np.arange(n_mols) * n_nodes, n_edges)
    e = rng.integers(0, n_nodes, size=(n_mols * n_edges, 2))
    # symmetric arcs: both directions
    src = np.concatenate([e[:, 0] + offsets, e[:, 1] + offsets]).astype(np.int32)
    dst = np.concatenate([e[:, 1] + offsets, e[:, 0] + offsets]).astype(np.int32)
    keep = src != dst
    return {
        "src": np.where(keep, src, 0),
        "dst": np.where(keep, dst, 0),
        "edge_mask": keep,
        "node_mask": np.ones(N, bool),
        "graph_id": np.repeat(np.arange(n_mols), n_nodes).astype(np.int32),
        "positions": rng.normal(size=(N, 3)).astype(np.float32) * 2,
        "species": rng.integers(0, n_species, N).astype(np.int32),
        "labels": rng.normal(size=(n_mols,)).astype(np.float32),  # energies
    }


def batch_from_sampled(g: Graph, sub, d_feat: int, n_classes: int,
                       feats: np.ndarray | None = None,
                       labels: np.ndarray | None = None,
                       seed: int = 0) -> dict:
    """Flatten a sampler.SampledSubgraph into one padded edge-list batch.

    Nodes = concatenation of all sampler layers (seeds first). Predictions
    read the seed prefix."""
    rng = np.random.default_rng(seed)
    layer_sizes = [ln.shape[0] for ln in sub.layer_nodes]
    starts = np.concatenate([[0], np.cumsum(layer_sizes)[:-1]])
    all_nodes = np.concatenate(sub.layer_nodes)
    node_mask = all_nodes >= 0
    safe = np.where(node_mask, all_nodes, 0)
    srcs, dsts, masks = [], [], []
    for h, blk in enumerate(sub.blocks):
        dsts.append(blk.dst_index + starts[h])
        srcs.append(blk.src_index + starts[h + 1])
        masks.append(blk.mask)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    emask = np.concatenate(masks)
    if feats is None:
        feats = rng.normal(size=(len(all_nodes), d_feat)).astype(np.float32)
    else:
        feats = feats[safe] * node_mask[:, None]
    if labels is None:
        labels = rng.integers(0, n_classes, len(all_nodes)).astype(np.int32)
    else:
        labels = labels[safe]
    return {
        # message direction: sampled neighbor (layer h+1) -> requester (h)
        "src": src, "dst": dst,
        "edge_mask": emask,
        "node_mask": node_mask,
        "graph_id": np.zeros(len(all_nodes), np.int32),
        "feats": feats.astype(np.float32),
        "labels": labels,
        "positions": rng.normal(size=(len(all_nodes), 3)).astype(np.float32),
        "species": rng.integers(0, 4, len(all_nodes)).astype(np.int32),
        "n_seeds": np.int32(layer_sizes[0]),
    }
