"""GraphCast [arXiv:2212.12794] — encoder-processor-decoder mesh GNN.

Two operating modes:

  * ``weather`` — the paper's own typed multigraph: grid nodes (lat x lon,
    n_vars channels) -> encoder (grid2mesh block) -> 16 processor blocks on
    the icosahedral multimesh -> decoder (mesh2grid block) -> per-grid-node
    prediction of the n_vars channels. Used by the weather example/benchmark.

  * ``generic`` — the assigned graph shapes (full_graph_sm / minibatch_lg /
    ogb_products / molecule) are single untyped graphs: the same
    InteractionBlock processor runs directly on the given edge list
    (encoder/decoder become node MLPs). Documented in DESIGN.md §6.

Every block is a GraphNet InteractionBlock (edge MLP -> segment-sum ->
node MLP, residual, LayerNorm), the paper's exact block type.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.util import scan_unroll
from repro.configs.base import GNNConfig
from repro.models.gnn.common import layernorm, mlp_apply, mlp_init, scatter_sum


def _block_init(key, d):
    k1, k2 = jax.random.split(key)
    return {
        "edge_mlp": mlp_init(k1, (3 * d, d, d)),
        "node_mlp": mlp_init(k2, (2 * d, d, d)),
    }


def _interaction(bp, h_src, h_dst, e, src, dst, n_dst, emask):
    """One GraphNet block. Returns (new_h_dst, new_e)."""
    from repro.models.gnn.common import (constrain_rows, gather_rows,
                                         gather_rows_multi)
    import os
    if h_src is h_dst and not os.environ.get("REPRO_NO_GATHER_DEDUP"):
        # generic mode: one broadcast serves both ends
        hs, hd = gather_rows_multi(h_src, (src, dst))
    else:
        hs, hd = gather_rows(h_src, src), gather_rows(h_dst, dst)
    eh = jnp.concatenate([e, hs, hd], axis=-1)
    e_new = constrain_rows((e + mlp_apply(bp["edge_mlp"], eh)) *
                           emask[:, None])
    agg = constrain_rows(scatter_sum(e_new, dst, n_dst))
    h_new = h_dst + mlp_apply(bp["node_mlp"],
                              jnp.concatenate([h_dst, agg], axis=-1))
    return constrain_rows(layernorm(h_new)), \
        constrain_rows(layernorm(e_new) * emask[:, None])


# ---------------------------------------------------------------------- #
# Generic mode (assigned shapes)
# ---------------------------------------------------------------------- #

def init_params(cfg: GNNConfig, key, d_in: int | None = None):
    d = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 3)
    blocks = [_block_init(ks[i], d) for i in range(cfg.n_layers)]
    return {
        "encode": mlp_init(ks[-3], (d_in or d, d, d)),
        "edge_embed": jnp.zeros((1, d)),           # learned constant edge init
        "blocks": jax.tree.map(lambda *x: jnp.stack(x), *blocks)
        if cfg.n_layers > 1 else jax.tree.map(lambda x: x[None], blocks[0]),
        "decode": mlp_init(ks[-2], (d, d, d)),
    }


def node_embeddings(params, cfg: GNNConfig, batch):
    from repro.models.gnn.common import COMPUTE_DTYPE
    n = batch["node_mask"].shape[0]
    feats = batch.get("feats")
    if feats is None:
        feats = jax.nn.one_hot(batch["species"], cfg.d_hidden)
    h = mlp_apply(params["encode"],
                  feats.astype(COMPUTE_DTYPE))
    src, dst = batch["src"], batch["dst"]
    e = jnp.broadcast_to(params["edge_embed"].astype(COMPUTE_DTYPE),
                         (src.shape[0], cfg.d_hidden))
    emask = batch["edge_mask"].astype(h.dtype)

    def block(carry, bp):
        # checkpoint: never save per-layer (E, d) edge intermediates — the
        # ogb_products cell has 124M edges (measured 167GB/dev without this).
        h, e = jax.checkpoint(
            lambda h_, e_, bp_: _interaction(bp_, h_, h_, e_, src, dst, n,
                                             emask))(carry[0], carry[1], bp)
        return (h, e), None

    (h, e), _ = jax.lax.scan(block, (h, e), params["blocks"],
                             unroll=scan_unroll())
    return mlp_apply(params["decode"], h)


# ---------------------------------------------------------------------- #
# Weather mode (the paper's own config)
# ---------------------------------------------------------------------- #

def make_weather_graph(cfg: GNNConfig, seed: int = 0) -> dict:
    """Host-side synthetic multimesh wiring with the configured sizes.

    Mesh connectivity is generated as a deterministic random regular-ish
    graph of the configured edge count (the real icosahedral multimesh is a
    constant that would ship as data; its sizes are what matter for
    performance work)."""
    p = cfg.params
    rng = np.random.default_rng(seed)
    n_grid = p["grid_lat"] * p["grid_lon"]
    n_mesh = p["mesh_nodes"]
    g2m = rng.integers(0, [[n_grid], [n_mesh]],
                       size=(2, p["grid2mesh_edges"]))
    mm = rng.integers(0, n_mesh, size=(2, p["mesh_edges"]))
    m2g = rng.integers(0, [[n_mesh], [n_grid]],
                       size=(2, p["mesh2grid_edges"]))
    return {
        "g2m_src": g2m[0].astype(np.int32), "g2m_dst": g2m[1].astype(np.int32),
        "mm_src": mm[0].astype(np.int32), "mm_dst": mm[1].astype(np.int32),
        "m2g_src": m2g[0].astype(np.int32), "m2g_dst": m2g[1].astype(np.int32),
    }


def init_weather_params(cfg: GNNConfig, key):
    d = cfg.d_hidden
    p = cfg.params
    ks = jax.random.split(key, cfg.n_layers + 6)
    blocks = [_block_init(ks[i], d) for i in range(cfg.n_layers)]
    return {
        "grid_encode": mlp_init(ks[-6], (p["n_vars"], d, d)),
        "mesh_embed": jnp.zeros((1, d)),
        "g2m": _block_init(ks[-5], d),
        "blocks": jax.tree.map(lambda *x: jnp.stack(x), *blocks)
        if cfg.n_layers > 1 else jax.tree.map(lambda x: x[None], blocks[0]),
        "m2g": _block_init(ks[-4], d),
        "grid_decode": mlp_init(ks[-3], (d, d, p["n_vars"])),
    }


def weather_forward(params, cfg: GNNConfig, grid_state, graph):
    """grid_state: (n_grid, n_vars) -> next-state prediction (residual)."""
    d = cfg.d_hidden
    n_grid = grid_state.shape[0]
    n_mesh = cfg.params["mesh_nodes"]
    hg = mlp_apply(params["grid_encode"], grid_state.astype(jnp.float32))
    hm = jnp.broadcast_to(params["mesh_embed"], (n_mesh, d))
    ones = lambda e: jnp.ones((e.shape[0],), hg.dtype)

    # encoder: grid -> mesh
    e0 = jnp.zeros((graph["g2m_src"].shape[0], d), hg.dtype)
    hm, _ = _interaction(params["g2m"], hg, hm, e0, graph["g2m_src"],
                         graph["g2m_dst"], n_mesh, ones(graph["g2m_src"]))

    # processor on the multimesh
    em = jnp.zeros((graph["mm_src"].shape[0], d), hg.dtype)

    def block(carry, bp):
        hm, em = carry
        hm, em = _interaction(bp, hm, hm, em, graph["mm_src"],
                              graph["mm_dst"], n_mesh, ones(graph["mm_src"]))
        return (hm, em), None

    (hm, em), _ = jax.lax.scan(block, (hm, em), params["blocks"],
                               unroll=scan_unroll())

    # decoder: mesh -> grid
    e1 = jnp.zeros((graph["m2g_src"].shape[0], d), hg.dtype)
    hg2, _ = _interaction(params["m2g"], hm, hg, e1, graph["m2g_src"],
                          graph["m2g_dst"], n_grid, ones(graph["m2g_src"]))
    delta = mlp_apply(params["grid_decode"], hg2)
    return grid_state + delta
