"""SchNet [arXiv:1706.08566] — continuous-filter convolutions.

Interaction block: h_j --(atomwise)--> x_j; filter W(r_ij) = MLP(rbf(r_ij));
message = x_j * W(r_ij); aggregate (segment_sum); atomwise MLP; residual.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.util import scan_unroll
from repro.configs.base import GNNConfig
from repro.models.gnn.common import (
    gaussian_rbf, mlp_apply, mlp_init, scatter_sum)


def init_params(cfg: GNNConfig, key, d_in: int | None = None):
    d = cfg.d_hidden
    p = cfg.params
    ks = jax.random.split(key, 3 + 3 * cfg.n_layers)
    params = {
        "embed_species": jax.random.normal(ks[0], (p["n_species"], d)) * 0.1,
        "proj_in": mlp_init(ks[1], (d_in, d)) if d_in else None,
        "blocks": [],
        "readout": mlp_init(ks[2], (d, d // 2, 1)),
    }
    for i in range(cfg.n_layers):
        params["blocks"].append({
            "filter": mlp_init(ks[3 + 3 * i], (p["n_rbf"], d, d)),
            "in2f": mlp_init(ks[4 + 3 * i], (d, d)),
            "out": mlp_init(ks[5 + 3 * i], (d, d, d)),
        })
    params["blocks"] = jax.tree.map(lambda *x: jnp.stack(x),
                                    *params["blocks"]) \
        if cfg.n_layers > 1 else jax.tree.map(lambda x: x[None],
                                              params["blocks"][0])
    return params


def node_embeddings(params, cfg: GNNConfig, batch):
    p = cfg.params
    n = batch["species"].shape[0]
    h = jnp.take(params["embed_species"], batch["species"], axis=0)
    if params.get("proj_in") is not None and "feats" in batch:
        h = h + mlp_apply(params["proj_in"], batch["feats"].astype(h.dtype))
    rel = batch["positions"][batch["dst"]] - batch["positions"][batch["src"]]
    dist = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
    rbf = gaussian_rbf(dist, p["n_rbf"], p["cutoff"]).astype(h.dtype)
    emask = batch["edge_mask"][:, None].astype(h.dtype)

    def block(h, bp):
        x = mlp_apply(bp["in2f"], h)
        w = mlp_apply(bp["filter"], rbf) * emask
        msg = x[batch["src"]] * w
        agg = scatter_sum(msg, batch["dst"], n)
        return h + mlp_apply(bp["out"], agg), None

    h, _ = jax.lax.scan(block, h, params["blocks"], unroll=scan_unroll())
    return h


def energy(params, cfg: GNNConfig, batch, n_graphs: int):
    h = node_embeddings(params, cfg, batch)
    e_atom = mlp_apply(params["readout"], h)[:, 0]
    e_atom = e_atom * batch["node_mask"].astype(e_atom.dtype)
    return scatter_sum(e_atom, batch["graph_id"], n_graphs)
