"""DIN [arXiv:1706.06978] — Deep Interest Network.

Target attention over the user behavior sequence: each history item is
scored against the candidate item by an MLP over [h, t, h-t, h*t], weights
(softmax-free, as in the paper: sigmoid-scaled) pool the history into a
user-interest vector; concat with candidate + context -> prediction MLP.

The embedding lookup (items 10^6 x 18, categories 10^4 x 18) is the hot
path; tables are row-sharded over "model" (see steps.py)."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig
from repro.models.gnn.common import mlp_apply, mlp_init
from repro.models.recsys.embedding_bag import embedding_bag


def init_params(cfg: RecSysConfig, key):
    D = cfg.embed_dim
    ks = jax.random.split(key, 5)
    feat_dim = 4 * 2 * D          # [h, t, h-t, h*t] over (item||cate) embeds
    user_dim = 2 * D              # attention-pooled history
    in_dim = user_dim + 2 * D + 2 * D   # user, target, context bag
    return {
        "item_emb": jax.random.normal(ks[0], (cfg.n_items, D)) * 0.01,
        "cate_emb": jax.random.normal(ks[1], (cfg.n_cates, D)) * 0.01,
        "attn": mlp_init(ks[2], (feat_dim, *cfg.attn_mlp, 1)),
        "mlp": mlp_init(ks[3], (in_dim, *cfg.mlp, 1)),
    }


def _hist_embed(params, hist_items, hist_cates):
    e_i = jnp.take(params["item_emb"], jnp.maximum(hist_items, 0), axis=0)
    e_c = jnp.take(params["cate_emb"], jnp.maximum(hist_cates, 0), axis=0)
    e = jnp.concatenate([e_i, e_c], axis=-1)            # (B, L, 2D)
    return e * (hist_items >= 0)[..., None].astype(e.dtype)


def user_vector(params, cfg: RecSysConfig, hist_items, hist_cates,
                target_items, target_cates):
    """Target attention pooling -> (B, 2D)."""
    h = _hist_embed(params, hist_items, hist_cates)     # (B, L, 2D)
    t_i = jnp.take(params["item_emb"], target_items, axis=0)
    t_c = jnp.take(params["cate_emb"], target_cates, axis=0)
    t = jnp.concatenate([t_i, t_c], axis=-1)[:, None, :]  # (B, 1, 2D)
    tb = jnp.broadcast_to(t, h.shape)
    feat = jnp.concatenate([h, tb, h - tb, h * tb], axis=-1)
    score = mlp_apply(params["attn"], feat)[..., 0]     # (B, L)
    score = jnp.where(hist_items >= 0, score, -1e30)
    w = jax.nn.softmax(score.astype(jnp.float32), axis=-1).astype(h.dtype)
    return jnp.einsum("bl,bld->bd", w, h), t[:, 0, :]


def logits(params, cfg: RecSysConfig, batch):
    """batch: hist_items/hist_cates (B, L), target_item/target_cate (B,),
    context_bag (B, L_ctx) multi-hot cate ids (EmbeddingBag path)."""
    u, t = user_vector(params, cfg, batch["hist_items"], batch["hist_cates"],
                       batch["target_item"], batch["target_cate"])
    ctx = embedding_bag(params["cate_emb"], batch["context_bag"], mode="sum")
    ctx = jnp.concatenate([ctx, embedding_bag(
        params["cate_emb"], batch["context_bag"], mode="mean")], axis=-1)
    x = jnp.concatenate([u, t, ctx], axis=-1)
    return mlp_apply(params["mlp"], x)[..., 0]


def retrieval_scores(params, cfg: RecSysConfig, batch):
    """Score ONE user against n_candidates items — batched dot + MLP over the
    candidate matrix, never a loop. batch: hist_* (1, L), cand_items (N,),
    cand_cates (N,)."""
    u, _ = user_vector(params, cfg, batch["hist_items"], batch["hist_cates"],
                       batch["cand_items"][:1], batch["cand_cates"][:1])
    e_i = jnp.take(params["item_emb"], batch["cand_items"], axis=0)
    e_c = jnp.take(params["cate_emb"], batch["cand_cates"], axis=0)
    cand = jnp.concatenate([e_i, e_c], axis=-1)           # (N, 2D)
    uN = jnp.broadcast_to(u, cand.shape)
    # MLP input layout matches logits(): [user(2D), target(2D), ctx(2D)];
    # retrieval has no context bag -> zeros.
    ctx = jnp.zeros((cand.shape[0], 2 * cfg.embed_dim), cand.dtype)
    x = jnp.concatenate([uN, cand, ctx], axis=-1)
    return mlp_apply(params["mlp"], x)[..., 0]
