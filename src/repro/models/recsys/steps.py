"""Train / serve / retrieval steps + dry-run specs for DIN.

Sharding: embedding tables row-sharded over "model" (the 10^6-row item table
is the dominant state); batch data-parallel over ("pod","data"); the lookup
becomes a GSPMD gather over the table shards — the recsys analogue of the
k-core estimate broadcast."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import RecSysConfig, ShapeSpec
from repro.models.recsys import din
from repro.optim import AdamWConfig, adamw_update


def param_specs(cfg: RecSysConfig) -> dict:
    mlp_spec = [{"w": P(None, None), "b": P(None)}] * 0  # filled below
    def mlp_of(sizes):
        return [{"w": P(None, None), "b": P(None)} for _ in sizes]
    import os
    item_spec = P(("model", "data"), None) if \
        os.environ.get("REPRO_DIN_FULLSHARD") else P("model", None)
    return {
        "item_emb": item_spec,
        "cate_emb": P("model", None),
        "attn": mlp_of(range(len(cfg.attn_mlp) + 1)),
        "mlp": mlp_of(range(len(cfg.mlp) + 1)),
    }


def make_train_step(cfg: RecSysConfig, opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or AdamWConfig(lr=1e-3, weight_decay=0.0)

    def loss_fn(params, batch):
        lg = din.logits(params, cfg, batch).astype(jnp.float32)
        y = batch["label"].astype(jnp.float32)
        return jnp.mean(jnp.maximum(lg, 0) - lg * y +
                        jnp.log1p(jnp.exp(-jnp.abs(lg))))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: RecSysConfig):
    def serve_step(params, batch):
        return jax.nn.sigmoid(din.logits(params, cfg, batch))
    return serve_step


def make_retrieval_step(cfg: RecSysConfig, top_k: int = 100):
    def retrieval_step(params, batch):
        scores = din.retrieval_scores(params, cfg, batch)
        vals, idx = jax.lax.top_k(scores, top_k)
        return vals, idx
    return retrieval_step


# ---------------------------------------------------------------------- #
# Specs + synthetic batches
# ---------------------------------------------------------------------- #

def batch_specs(cfg: RecSysConfig, shape: ShapeSpec) -> dict:
    i32 = jnp.int32
    if shape.kind == "retrieval":
        # pad to a 512 multiple so the candidate shard divides both meshes
        N = ((shape.params["n_candidates"] + 511) // 512) * 512
        return {
            "hist_items": jax.ShapeDtypeStruct((1, cfg.seq_len), i32),
            "hist_cates": jax.ShapeDtypeStruct((1, cfg.seq_len), i32),
            "cand_items": jax.ShapeDtypeStruct((N,), i32),
            "cand_cates": jax.ShapeDtypeStruct((N,), i32),
        }
    B = shape.params["batch"]
    specs = {
        "hist_items": jax.ShapeDtypeStruct((B, cfg.seq_len), i32),
        "hist_cates": jax.ShapeDtypeStruct((B, cfg.seq_len), i32),
        "target_item": jax.ShapeDtypeStruct((B,), i32),
        "target_cate": jax.ShapeDtypeStruct((B,), i32),
        "context_bag": jax.ShapeDtypeStruct((B, 16), i32),
    }
    if shape.kind == "train":
        specs["label"] = jax.ShapeDtypeStruct((B,), i32)
    return specs


def synth_batch(cfg: RecSysConfig, shape: ShapeSpec, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    sp = batch_specs(cfg, shape)
    out = {}
    for k, s in sp.items():
        if k == "label":
            out[k] = rng.integers(0, 2, s.shape).astype(np.int32)
        elif "cate" in k or k == "context_bag":
            out[k] = rng.integers(0, cfg.n_cates, s.shape).astype(np.int32)
        else:
            out[k] = rng.zipf(1.3, s.shape).clip(max=cfg.n_items - 1) \
                .astype(np.int32) if "item" in k else \
                rng.integers(0, cfg.n_items, s.shape).astype(np.int32)
    # mark some history padding (ragged behavior lengths)
    L = cfg.seq_len
    lens = rng.integers(L // 4, L + 1, out["hist_items"].shape[0])
    mask = np.arange(L)[None, :] < lens[:, None]
    out["hist_items"] = np.where(mask, out["hist_items"], -1)
    return out


def build_step(cfg: RecSysConfig, shape: ShapeSpec, mesh):
    specs = batch_specs(cfg, shape)
    if shape.kind == "train":
        step = make_train_step(cfg)
        pshapes = jax.eval_shape(lambda k: din.init_params(cfg, k),
                                 jax.random.key(0))
        if mesh is None:
            return step, specs, None, None
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           param_specs(cfg),
                           is_leaf=lambda x: isinstance(x, P))
        osh = {"m": psh, "v": psh, "count": NamedSharding(mesh, P())}
        dp = _dp_axes(mesh)
        bsh = jax.tree.map(
            lambda s: NamedSharding(mesh, P(dp, *([None] * (len(s.shape) - 1)))),
            specs)
        return step, specs, (psh, osh, bsh), \
            (psh, osh, NamedSharding(mesh, P()))
    if shape.kind == "serve":
        step = make_serve_step(cfg)
    else:
        step = make_retrieval_step(cfg)
    if mesh is None:
        return step, specs, None, None
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(cfg),
                       is_leaf=lambda x: isinstance(x, P))
    dp = _dp_axes(mesh)
    if shape.kind == "retrieval":
        # candidates sharded over every axis; user history replicated
        flat = tuple(mesh.axis_names)
        bsh = {
            "hist_items": NamedSharding(mesh, P(None, None)),
            "hist_cates": NamedSharding(mesh, P(None, None)),
            "cand_items": NamedSharding(mesh, P(flat)),
            "cand_cates": NamedSharding(mesh, P(flat)),
        }
        out_sh = (NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    else:
        bsh = jax.tree.map(
            lambda s: NamedSharding(mesh, P(dp, *([None] * (len(s.shape) - 1)))),
            specs)
        out_sh = NamedSharding(mesh, P(dp))
    return step, specs, (psh, bsh), out_sh


def _dp_axes(mesh):
    d = tuple(a for a in mesh.axis_names if a != "model")
    return d if len(d) > 1 else d[0]
