"""EmbeddingBag — JAX has no native nn.EmbeddingBag; per the assignment this
is built from ``jnp.take`` + ``jax.ops.segment_sum`` as a first-class part of
the system. The Pallas kernel (kernels/embedding_bag) is the fused TPU hot
path; this module is the composable API + XLA reference path."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table, indices, weights=None, mode: str = "sum"):
    """Dense-batch bag: indices (B, L) -> (B, D). Padding = index < 0."""
    mask = (indices >= 0)
    safe = jnp.where(mask, indices, 0)
    emb = jnp.take(table, safe, axis=0)           # (B, L, D)
    m = mask[..., None].astype(emb.dtype)
    if weights is not None:
        m = m * weights[..., None].astype(emb.dtype)
    emb = emb * m
    if mode == "sum":
        return emb.sum(axis=1)
    if mode == "mean":
        return emb.sum(axis=1) / jnp.maximum(m.sum(axis=1), 1e-9)
    if mode == "max":
        neg = jnp.where(mask[..., None], emb, -jnp.inf)
        return jnp.max(neg, axis=1)
    raise ValueError(mode)


def ragged_embedding_bag(table, flat_indices, segment_ids, n_bags: int,
                         mode: str = "sum"):
    """CSR-style ragged bag: flat indices + segment ids -> (n_bags, D)."""
    emb = jnp.take(table, flat_indices, axis=0)
    if mode == "sum":
        return jax.ops.segment_sum(emb, segment_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(emb, segment_ids, num_segments=n_bags)
        c = jax.ops.segment_sum(jnp.ones_like(flat_indices, emb.dtype),
                                segment_ids, num_segments=n_bags)
        return s / jnp.maximum(c[:, None], 1e-9)
    if mode == "max":
        return jax.ops.segment_max(emb, segment_ids, num_segments=n_bags)
    raise ValueError(mode)
