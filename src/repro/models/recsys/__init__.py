from repro.models.recsys import din, embedding_bag, steps

__all__ = ["din", "embedding_bag", "steps"]
