"""Model-layer utilities."""

from __future__ import annotations

import os


def scan_unroll():
    """lax.scan ``unroll=`` argument for model loops.

    The dry-run (launch/dryrun.py) sets REPRO_UNROLL_SCANS=1 so the lowered
    module contains no while loops: XLA's HloCostAnalysis counts loop bodies
    ONCE (trip counts ignored), which under-counts FLOPs/bytes/collectives by
    the trip count; with full unroll the compiled-artifact analysis is exact.
    Training/serving runs keep scans (unroll=1) for compile time and memory.
    """
    return True if os.environ.get("REPRO_UNROLL_SCANS", "0") == "1" else 1
