"""Train / prefill / decode steps for the LM family, plus dry-run specs.

``build_*`` functions return (step_fn, input_specs, in_shardings,
out_shardings) so launch/dryrun.py and launch/train.py share one code path.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig, ShapeSpec
from repro.distribution.sharding import lm_param_specs, lm_rules
from repro.models.transformer import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_warmup


def _named(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def param_shapes(cfg: LMConfig) -> Any:
    """ShapeDtypeStructs of the parameter pytree (no allocation)."""
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.random.key(0))


def opt_shapes(cfg: LMConfig) -> Any:
    return jax.eval_shape(lambda k: adamw_init(M.init_params(cfg, k)),
                          jax.random.key(0))


def opt_specs(cfg: LMConfig) -> Any:
    ps = lm_param_specs(cfg)
    return {"m": ps, "v": ps, "count": P()}


# ---------------------------------------------------------------------- #
# Train
# ---------------------------------------------------------------------- #

def make_train_step(cfg: LMConfig, rules, opt_cfg: AdamWConfig | None = None,
                    total_steps: int = 10_000):
    opt_cfg = opt_cfg or AdamWConfig()
    M_ub = max(cfg.train_microbatches, 1)

    def grads_of(params, tokens, labels):
        return jax.value_and_grad(
            lambda p: M.lm_loss(p, cfg, tokens, labels, rules))(params)

    def train_step(params, opt_state, tokens, labels):
        if M_ub == 1:
            loss, grads = grads_of(params, tokens, labels)
        else:
            # gradient accumulation: activations scale with B/M_ub; the
            # accumulator is f32 and inherits the (FSDP) param sharding.
            B, S = tokens.shape
            tok = tokens.reshape(M_ub, B // M_ub, S)
            lab = labels.reshape(M_ub, B // M_ub, S)

            def mb(carry, inp):
                g_acc, l_acc = carry
                loss_i, g_i = grads_of(params, *inp)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, g_i)
                return (g_acc, l_acc + loss_i), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                mb, (zeros, jnp.float32(0)), (tok, lab))
            grads = jax.tree.map(lambda g: g / M_ub, grads)
            loss = loss / M_ub
        lr_scale = cosine_warmup(opt_state["count"], warmup=100,
                                 total=total_steps)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg, lr_scale)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def build_train(cfg: LMConfig, shape: ShapeSpec, mesh):
    rules = lm_rules(mesh, cfg) if mesh is not None else None
    step = make_train_step(cfg, rules)
    B, S = shape.params["global_batch"], shape.params["seq_len"]
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if mesh is None:
        return step, specs, None, None
    pspecs = lm_param_specs(cfg)
    in_sh = (_named(mesh, pspecs), _named(mesh, opt_specs(cfg)),
             rules.tokens, rules.tokens)
    out_sh = (_named(mesh, pspecs), _named(mesh, opt_specs(cfg)),
              NamedSharding(mesh, P()))
    return step, specs, in_sh, out_sh


# ---------------------------------------------------------------------- #
# Serve: prefill + decode
# ---------------------------------------------------------------------- #

def build_prefill(cfg: LMConfig, shape: ShapeSpec, mesh):
    rules = lm_rules(mesh, cfg) if mesh is not None else None

    def prefill_step(params, tokens):
        return M.prefill(params, cfg, tokens, rules)

    B, S = shape.params["global_batch"], shape.params["seq_len"]
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if mesh is None:
        return prefill_step, specs, None, None
    pspecs = lm_param_specs(cfg)
    cache_sh = _stacked_cache_sharding(mesh, rules)
    in_sh = (_named(mesh, pspecs), rules.tokens)
    out_sh = (NamedSharding(mesh, P(_dp(rules), None)),
              {"k": cache_sh, "v": cache_sh})
    return prefill_step, specs, in_sh, out_sh


def build_decode(cfg: LMConfig, shape: ShapeSpec, mesh):
    rules = lm_rules(mesh, cfg) if mesh is not None else None

    def decode_step(params, token, cache, pos):
        return M.decode_step(params, cfg, token, cache, pos, rules)

    B, S = shape.params["global_batch"], shape.params["seq_len"]
    cache_shapes = jax.eval_shape(
        lambda: M.init_kv_cache(cfg, B, S))
    specs = {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": cache_shapes,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if mesh is None:
        return decode_step, specs, None, None
    pspecs = lm_param_specs(cfg)
    # batch 1 (long-context decode) cannot shard over the data axes —
    # replicate the batch dim and rely on head/time sharding.
    dp_size = int(np.prod([mesh.shape[a] for a in rules.data_axes]))
    dp = _dp(rules) if B % dp_size == 0 else None
    kv_spec = rules.kv_cache.spec
    kv = NamedSharding(mesh, P(None, dp, *kv_spec[1:]))
    cache_sh = {"k": kv, "v": kv}
    in_sh = (_named(mesh, pspecs),
             NamedSharding(mesh, P(dp, None)),
             cache_sh, NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, P(dp, None)), cache_sh)
    return decode_step, specs, in_sh, out_sh


def _dp(rules):
    d = rules.data_axes
    return d if len(d) > 1 else d[0]


def _stacked_cache_sharding(mesh, rules) -> NamedSharding:
    """Cache is stacked (L, B, Hkv, T, Dh): prepend None to the per-layer
    kv spec."""
    return NamedSharding(mesh, P(None, *rules.kv_cache.spec))


def build_step(cfg: LMConfig, shape: ShapeSpec, mesh):
    kind = shape.kind
    if kind == "train":
        return build_train(cfg, shape, mesh)
    if kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    if kind == "decode":
        return build_decode(cfg, shape, mesh)
    raise ValueError(f"unknown LM shape kind {kind}")
