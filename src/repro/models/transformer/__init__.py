from repro.models.transformer import model, steps

__all__ = ["model", "steps"]
