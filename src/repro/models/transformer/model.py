"""Decoder-only transformer stack (dense + MoE), pure JAX.

Covers the five assigned LM architectures: GQA/MQA attention with RoPE and
optional QKV bias, RMSNorm, SwiGLU or GELU MLP, Mixtral-style top-k MoE with
capacity dispatch + optional shared experts, sliding-window attention, tied
embeddings, KV-cache decode with rolling SWA buffer.

Design notes
  * Layers are stacked (L, ...) and iterated with lax.scan + jax.checkpoint
    — keeps HLO size O(1) in depth and gives per-layer activation remat.
  * Attention is evaluated in query chunks (scan) so the score matrix never
    exceeds (B, H, q_chunk, S) — the XLA analogue of flash attention; the
    Pallas flash kernel (kernels/flash_attention) is a drop-in for the TPU
    runtime and is validated against the same reference in tests.
  * All activation sharding is injected via distribution.ShardingRules; the
    module is mesh-agnostic.
  * Params are stored fp32 and cast to ``compute_dtype`` at use (bf16 on
    TPU); RMSNorm/softmax/router run in fp32.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.util import scan_unroll
from repro.configs.base import LMConfig
from repro.distribution.sharding import ShardingRules, constrain


COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------- #
# Initialization
# ---------------------------------------------------------------------- #

def init_params(cfg: LMConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    """Parameter pytree; stacked (L, ...) leaves for scan."""
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    d, L = cfg.d_model, cfg.n_layers
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    std = 0.02

    def init(key, shape, scale=std):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)

    ks = jax.random.split(k_layers, 16)
    attn = {
        "wq": init(ks[0], (L, d, hq * dh)),
        "wk": init(ks[1], (L, d, hkv * dh)),
        "wv": init(ks[2], (L, d, hkv * dh)),
        "wo": init(ks[3], (L, hq * dh, d), scale=std / math.sqrt(2 * L)),
    }
    if cfg.qkv_bias:
        attn["bq"] = jnp.zeros((L, hq * dh), dtype)
        attn["bk"] = jnp.zeros((L, hkv * dh), dtype)
        attn["bv"] = jnp.zeros((L, hkv * dh), dtype)
    layers: dict[str, Any] = {
        "attn": attn,
        "norm1": jnp.ones((L, d), dtype),
        "norm2": jnp.ones((L, d), dtype),
    }
    if cfg.moe:
        # storage layout: E_eff = pad(E) * virtual_split experts of width
        # f_eff = d_ff_expert / virtual_split (exact-math mesh divisibility;
        # see configs.base.MoEConfig)
        E, fe = cfg.moe.e_eff, cfg.moe.f_eff
        moe = {
            "router": init(ks[4], (L, d, cfg.moe.e_pad)),
            "w_up": init(ks[5], (L, E, d, fe)),
            "w_down": init(ks[6], (L, E, fe, d), scale=std / math.sqrt(2 * L)),
        }
        if cfg.mlp_type == "swiglu":
            moe["w_gate"] = init(ks[7], (L, E, d, fe))
        if cfg.moe.n_shared:
            fs = cfg.moe.n_shared * fe
            shared = {
                "w_up": init(ks[8], (L, d, fs)),
                "w_down": init(ks[9], (L, fs, d), scale=std / math.sqrt(2 * L)),
            }
            if cfg.mlp_type == "swiglu":
                shared["w_gate"] = init(ks[10], (L, d, fs))
            moe["shared"] = shared
        layers["moe"] = moe
    else:
        f = cfg.d_ff
        mlp = {
            "w_up": init(ks[4], (L, d, f)),
            "w_down": init(ks[5], (L, f, d), scale=std / math.sqrt(2 * L)),
        }
        if cfg.mlp_type == "swiglu":
            mlp["w_gate"] = init(ks[6], (L, d, f))
        layers["mlp"] = mlp
    params = {
        "embed": init(k_emb, (cfg.vocab, d)),
        "layers": layers,
        "norm_f": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init(k_head, (cfg.vocab, d))
    return params


# ---------------------------------------------------------------------- #
# Building blocks
# ---------------------------------------------------------------------- #

def rmsnorm(x, w, eps):
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def _rope(x, pos, theta):
    """x: (B, S, H, Dh), pos: (S,) — positions are shared across the batch
    (continuous batching keeps ragged offsets outside the kernel), so all
    position-derived tensors stay 1-D/2-D and never replicate a
    (B, S, ...)-sized buffer on every device."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freq          # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]                   # (1, S, 1, half)
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _attention_scores(q, k, v, q_pos, k_pos, window):
    """q: (B, Q, Hkv, rep, Dh), k/v: (B, T, Hkv, Dh); q_pos (Q,), k_pos (T,)
    absolute positions (shared across batch). Returns (B, Q, Hkv, rep, Dh).
    (Grouped layout — used by the decode path.)"""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", q, k) / math.sqrt(dh)
    mask = k_pos[None, :] <= q_pos[:, None]                # (Q, T)
    if window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    mask = mask[None, None, None]                          # (1,1,1,Q,T)
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhrqk,bkhd->bqhrd", p, v)


def _attention_scores_mha(q, k, v, q_pos, k_pos, window):
    """Flat-head layout: q (B, Q, H, Dh), k/v (B, T, H, Dh) — KV expanded to
    the full query-head count so the head dim shards cleanly over "model"
    (kv-head counts like 8 do not divide a 16-way axis; GSPMD then falls
    back to involuntary replication). Train/prefill path."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
    mask = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def attention(x, p, cfg: LMConfig, pos, rules: ShardingRules | None,
              kv_cache=None, cache_pos=None, q_chunk: int = 512):
    """Full-sequence (train/prefill) or single-token (decode) attention.

    x: (B, S, d). pos: (S,) absolute positions (shared across batch).
    kv_cache: None → self-attention over x (chunked over queries);
    else dict {k, v} → decode against the cache (S == 1).
    Returns (out, new_cache_or_None).
    """
    B, S, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    rep = hq // hkv
    cd = x.dtype

    def proj(w, b=None):
        y = jnp.einsum("bsd,df->bsf", x, w.astype(cd))
        if b is not None:
            y = y + b.astype(cd)
        return y

    q = proj(p["wq"], p.get("bq")).reshape(B, S, hkv, rep, dh)
    k = proj(p["wk"], p.get("bk")).reshape(B, S, hkv, dh)
    v = proj(p["wv"], p.get("bv")).reshape(B, S, hkv, dh)
    q = _rope(q.reshape(B, S, hkv * rep, dh), pos, cfg.rope_theta) \
        .reshape(B, S, hkv, rep, dh)
    k = _rope(k, pos, cfg.rope_theta)

    if kv_cache is not None:
        # ---- decode: S == 1, write into rolling cache ------------------- #
        T = kv_cache["k"].shape[2]           # cache capacity
        wpos = cache_pos if cfg.swa_window is None else cache_pos % T
        ck = lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype).transpose(0, 2, 1, 3),
            (0, 0, wpos, 0))
        cv = lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype).transpose(0, 2, 1, 3),
            (0, 0, wpos, 0))
        if rules:
            ck = constrain(ck, rules.kv_cache)
            cv = constrain(cv, rules.kv_cache)
        # absolute positions of cache slots (1-D — shared across batch)
        slot = jnp.arange(T)
        if cfg.swa_window is None:
            k_pos_row = slot
            valid = slot <= cache_pos
        else:
            # rolling buffer: slot holds absolute position p with
            # p % T == slot, the largest such p <= cache_pos
            k_pos_row = cache_pos - ((cache_pos - slot) % T)
            valid = k_pos_row >= 0
        k_pos = jnp.where(valid, k_pos_row, -1)
        out = _attention_scores(
            q, ck.transpose(0, 2, 1, 3).astype(cd),
            cv.transpose(0, 2, 1, 3).astype(cd),
            pos, k_pos, cfg.swa_window)
        new_cache = {"k": ck, "v": cv}
    else:
        # ---- train/prefill: chunked self-attention ---------------------- #
        qc = min(q_chunk, S)
        n_chunks = S // qc if S % qc == 0 else 1
        if S % qc != 0:
            qc = S
        # Expand KV to the full query-head count (identity when rep == 1) so
        # the head dim shards evenly over "model" — see _attention_scores_mha.
        kf = jnp.repeat(k, rep, axis=2) if rep > 1 else k   # (B, S, hq, dh)
        vf = jnp.repeat(v, rep, axis=2) if rep > 1 else v
        qf = q.reshape(B, S, hq, dh)
        if rules:
            qf = constrain(qf, rules.attn_q)
            kf = constrain(kf, rules.attn_q)
            vf = constrain(vf, rules.attn_q)
        q_r = qf.reshape(B, n_chunks, qc, hq, dh)
        pos_r = pos.reshape(n_chunks, qc)
        # SWA: each q chunk only sees keys in [chunk_start - window, chunk
        # end) — slice that window out instead of masking the full S
        # (sub-quadratic compute and memory; exact because positions
        # outside the window are masked anyway).
        win = cfg.swa_window
        use_slice = win is not None and S > 2 * win and qc + win < S

        def chunk_body(carry, inp):
            q_c, pos_c, idx = inp                  # (B, qc, hq, dh), (qc,)
            if use_slice:
                kv_len = qc + win
                start = jnp.maximum(idx * qc - win, 0)
                start = jnp.minimum(start, S - kv_len)
                k_c = lax.dynamic_slice_in_dim(kf, start, kv_len, axis=1)
                v_c = lax.dynamic_slice_in_dim(vf, start, kv_len, axis=1)
                kpos_c = start + jnp.arange(kv_len)
            else:
                k_c, v_c, kpos_c = kf, vf, pos
            # checkpoint: never save the (B, H, qc, S) probs for backward —
            # recompute per chunk (flash-attention-style grad).
            o = jax.checkpoint(_attention_scores_mha, static_argnums=(5,))(
                q_c, k_c, v_c, pos_c, kpos_c, win)
            return carry, o

        _, outs = lax.scan(chunk_body, 0,
                           (q_r.transpose(1, 0, 2, 3, 4), pos_r,
                            jnp.arange(n_chunks)),
                           unroll=scan_unroll())
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, hkv, rep, dh)
        new_cache = None

    out = out.reshape(B, S, hq * dh)
    out = jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(cd))
    return out, new_cache


def mlp(x, p, cfg: LMConfig, rules):
    cd = x.dtype
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cd))
    if cfg.mlp_type == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cd))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    if rules:
        h = constrain(h, rules.ffn_hidden)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cd))


def moe_block(x, p, cfg: LMConfig, rules):
    """Top-k capacity-dispatch MoE — GShard-style einsum dispatch.

    x: (B, S, d). Per-group capacity C = ceil(S*k/E * cf). Dispatch and
    combine are one-hot EINSUMS (not scatter/gather: GSPMD reliably shards
    dot_general, while batched scatter/gather fall back to replicated
    64GB temporaries — measured, see EXPERIMENTS.md §Perf).

    Expert parallelism: the E dim of the dispatch buffer and the expert
    weights is sharded over "model" (GSPMD pads non-divisible E: 60 -> 64
    is 7% waste; 8 -> 16 is 2x — the virtual-expert split below removes
    it). Expert weights additionally FSDP-shard d over "data".
    """
    moe = cfg.moe
    B, S, d = x.shape
    E, K = moe.e_pad, moe.top_k                 # E includes dummy pad experts
    C = max(int(math.ceil(S * K / moe.n_experts * moe.capacity_factor)), 1)
    cd = x.dtype

    router_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32))
    if E > moe.n_experts:                       # dummy experts never selected
        pad_mask = jnp.arange(E) >= moe.n_experts
        router_logits = jnp.where(pad_mask, -1e30, router_logits)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_v, gate_i = lax.top_k(probs, K)                   # (B, S, K)
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    # slot assignment: position of each (token, k) selection within expert
    sel = jax.nn.one_hot(gate_i.reshape(B, S * K), E, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(sel, axis=1) - sel               # (B, S*K, E)
    pos_sel = jnp.take_along_axis(
        pos_in_e, gate_i.reshape(B, S * K, 1), axis=2)[..., 0]
    keep = pos_sel < C                                     # (B, S*K)

    # one-hot dispatch/combine tensors (B, S, E_eff, C); virtual_split
    # repeats each expert's slots across its half-width virtual experts —
    # the combine sum over e then adds the halves (exact SwiGLU split).
    oh_e = jax.nn.one_hot(gate_i, E, dtype=cd)             # (B, S, K, E)
    if moe.virtual_split > 1:
        oh_e = jnp.repeat(oh_e, moe.virtual_split, axis=-1)
    oh_c = jax.nn.one_hot(
        jnp.where(keep, pos_sel, C).reshape(B, S, K), C, dtype=cd)
    dispatch = jnp.einsum("bske,bskc->bsec", oh_e, oh_c)
    combine = jnp.einsum("bske,bskc,bsk->bsec", oh_e, oh_c,
                         gate_v.astype(cd))
    if rules:
        dispatch = constrain(dispatch, rules.moe_dispatch)
        x = constrain(x, rules.residual_decode if S == 1 else
                      rules.moe_x)

    buf = jnp.einsum("bsec,bsd->becd", dispatch, x)        # (B, E, C, d)
    if rules:
        buf = constrain(buf, rules.moe_buf)

    up = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(cd))
    if cfg.mlp_type == "swiglu":
        gate = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(cd))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    if rules:
        h = constrain(h, rules.moe_hidden)
    y = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cd))

    out = jnp.einsum("bsec,becd->bsd", combine, y)

    if moe.n_shared:
        out = out + mlp(x, p["shared"], cfg, rules)

    # load-balancing auxiliary loss (Switch-style), returned via aux
    me = probs.mean(axis=(0, 1))                           # mean router prob
    ce = sel.reshape(B, S, K, E).sum(2).mean(axis=(0, 1)) / K  # token fraction
    aux = E * jnp.sum(me * ce)
    return out, aux


def layer_fn(x, lp, cfg: LMConfig, pos, rules, kv_cache=None, cache_pos=None):
    h, new_cache = attention(rmsnorm(x, lp["norm1"], cfg.norm_eps),
                             lp["attn"], cfg, pos, rules,
                             kv_cache=kv_cache, cache_pos=cache_pos)
    x = x + h
    h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
    if cfg.moe:
        h2, aux = moe_block(h2, lp["moe"], cfg, rules)
    else:
        h2, aux = mlp(h2, lp["mlp"], cfg, rules), jnp.float32(0)
    x = x + h2
    if rules:
        spec = rules.residual if x.shape[1] > 1 else rules.residual_decode
        x = constrain(x, spec)
    return x, new_cache, aux


# ---------------------------------------------------------------------- #
# Full passes
# ---------------------------------------------------------------------- #

def forward_hidden(params, cfg: LMConfig, tokens, rules=None):
    """tokens (B, S) → final hidden states (B, S, d) bf16; aux loss."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
    pos = jnp.arange(S)
    if rules:
        x = constrain(x, rules.residual)
    policy = {"full": None,
              "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
              "all_dots": jax.checkpoint_policies.dots_saveable,
              }[cfg.remat_policy]

    def body(x, lp):
        x, _, aux = jax.checkpoint(
            lambda x_, lp_: layer_fn(x_, lp_, cfg, pos, rules),
            policy=policy)(x, lp)
        return x, aux

    x, auxs = lax.scan(body, x, params["layers"], unroll=scan_unroll())
    x = rmsnorm(x, params["norm_f"], cfg.norm_eps)
    return x, auxs.sum()


def logits_from_hidden(params, cfg: LMConfig, h):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", h, head.astype(h.dtype))


def lm_loss(params, cfg: LMConfig, tokens, labels, rules=None,
            vocab_chunk: int = 8):
    """Chunked cross-entropy: logits are materialized per sequence chunk so
    the (tokens, vocab) matrix never exists in full. Returns mean CE."""
    h, aux = forward_hidden(params, cfg, tokens, rules)
    B, S, d = h.shape
    n_chunks = min(vocab_chunk, S)
    while S % n_chunks:
        n_chunks -= 1
    hc = h.reshape(B, n_chunks, S // n_chunks, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        h_c, l_c = inp
        logits = logits_from_hidden(params, cfg, h_c).astype(jnp.float32)
        if rules:
            logits = constrain(logits, rules.logits_chunk)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        return carry + (lse - gold).sum(), None

    total, _ = lax.scan(jax.checkpoint(chunk_loss), jnp.float32(0), (hc, lc),
                        unroll=scan_unroll())
    return total / (B * S) + 0.01 * aux


# ---------------------------------------------------------------------- #
# Serving passes
# ---------------------------------------------------------------------- #

def init_kv_cache(cfg: LMConfig, batch: int, max_len: int,
                  dtype=COMPUTE_DTYPE) -> dict:
    """Stacked (L, B, Hkv, T, Dh) cache; SWA archs cap T at the window."""
    T = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, T, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, cfg: LMConfig, token, cache, pos, rules=None):
    """One decode step. token (B, 1) int32, pos scalar int32 (same position
    for the whole batch — continuous batching handles ragged externally).
    Returns (logits (B, vocab), new_cache)."""
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(COMPUTE_DTYPE)
    posb = pos[None].astype(jnp.int32)          # (1,) — shared position

    def body(x, lp_and_cache):
        lp, ck, cv = lp_and_cache
        x, new_cache, _ = layer_fn(x, lp, cfg, posb, rules,
                                   kv_cache={"k": ck, "v": cv},
                                   cache_pos=pos)
        return x, (new_cache["k"], new_cache["v"])

    x, (nk, nv) = lax.scan(body, x, (params["layers"], cache["k"],
                                     cache["v"]), unroll=scan_unroll())
    h = rmsnorm(x, params["norm_f"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, h)[:, 0, :]
    return logits.astype(jnp.float32), {"k": nk, "v": nv}


def prefill(params, cfg: LMConfig, tokens, rules=None):
    """Full-sequence prefill building the KV cache; returns
    (last-token logits (B, vocab), cache)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
    pos = jnp.arange(S)
    if rules:
        x = constrain(x, rules.residual)

    def body(x, lp):
        def inner(x_, lp_):
            h = rmsnorm(x_, lp_["norm1"], cfg.norm_eps)
            # recompute k/v for the cache outside attention to keep the
            # chunked attention path shared
            hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
            cd = x_.dtype
            k = jnp.einsum("bsd,df->bsf", h, lp_["attn"]["wk"].astype(cd))
            if cfg.qkv_bias:
                k = k + lp_["attn"]["bk"].astype(cd)
            k = _rope(k.reshape(B, S, hkv, dh), pos, cfg.rope_theta)
            v = jnp.einsum("bsd,df->bsf", h, lp_["attn"]["wv"].astype(cd))
            if cfg.qkv_bias:
                v = v + lp_["attn"]["bv"].astype(cd)
            v = v.reshape(B, S, hkv, dh)
            x_, _, _ = layer_fn(x_, lp_, cfg, pos, rules)
            if cfg.swa_window and S > cfg.swa_window:
                k = k[:, -cfg.swa_window:]
                v = v[:, -cfg.swa_window:]
            return x_, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

        x, kv = jax.checkpoint(inner)(x, lp)
        return x, kv

    x, (ks, vs) = lax.scan(body, x, params["layers"], unroll=scan_unroll())
    h = rmsnorm(x[:, -1:, :], params["norm_f"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, h)[:, 0, :]
    cache = {"k": ks.astype(COMPUTE_DTYPE), "v": vs.astype(COMPUTE_DTYPE)}
    return logits.astype(jnp.float32), cache
