"""Process-wide jit-recompile telemetry.

The streaming/temporal shape-stability story (pow2 padding, capacity
floors, fused while_loops) claims a whole replay compiles O(log) distinct
jit signatures. This module makes that claim measurable instead of
asserted: jax's monitoring stream emits one ``backend_compile`` duration
event per program XLA actually compiles, so the delta of
``compile_count()`` across a batch/step/replay IS the number of fresh
compiled signatures it minted (0 = every program was a cache hit).

The listener registers lazily on first use and is a no-op counter bump,
so leaving it installed costs nothing. On a jax that stops emitting the
event (none known across 0.4.x..current), counts degrade to 0 rather
than erroring — telemetry must never take down the engine.
"""

from __future__ import annotations

_count = 0
_installed = False


def _on_duration(event: str, *args, **kwargs) -> None:
    global _count
    if "backend_compile" in event:
        _count += 1


def install() -> None:
    """Register the compile listener once (idempotent)."""
    global _installed
    if _installed:
        return
    _installed = True
    try:
        import jax

        jax.monitoring.register_event_duration_secs_listener(_on_duration)
    except Exception:
        pass  # no monitoring API: compile_count() stays 0 forever


def compile_count() -> int:
    """Monotone count of XLA compilations since the listener installed.

    Diff two snapshots to count the recompiles a region of code caused.
    """
    install()
    return _count
