"""Process-wide jit-recompile telemetry.

The streaming/temporal shape-stability story (pow2 padding, capacity
floors, fused while_loops) claims a whole replay compiles O(log) distinct
jit signatures. This module makes that claim measurable instead of
asserted: jax's monitoring stream emits one ``backend_compile`` duration
event per program XLA actually compiles, so the delta of
``compile_count()`` across a batch/step/replay IS the number of fresh
compiled signatures it minted (0 = every program was a cache hit).

The same event carries the compile DURATION (jax.monitoring calls the
listener as ``listener(event, duration_secs)``), so the listener also
accumulates ``compile_seconds()`` — the wall-clock XLA spent compiling —
and, when span tracing is live (repro.obs.trace), records each compile as
an ``xla.compile`` span ending at the current clock, which lands it inside
whatever engine span was open while the compile ran. That is how a trace
attributes "this batch was slow because it minted a fresh program" to the
exact batch/phase that paid for it.

The listener registers lazily on first use and is a no-op counter bump,
so leaving it installed costs nothing. On a jax that stops emitting the
event (none known across 0.4.x..current), counts degrade to 0 rather
than erroring — telemetry must never take down the engine.
"""

from __future__ import annotations

_count = 0
_seconds = 0.0
_installed = False


def _on_duration(event: str, *args, **kwargs) -> None:
    global _count, _seconds
    if "backend_compile" in event:
        _count += 1
        dur = 0.0
        if args:
            try:
                dur = float(args[0])
            except (TypeError, ValueError):
                pass
        _seconds += dur
        try:
            from repro.obs import trace

            if trace.enabled():
                trace.record("xla.compile", dur, event=event)
        except Exception:
            pass  # tracing must never take down a compile


def install() -> None:
    """Register the compile listener once (idempotent)."""
    global _installed
    if _installed:
        return
    _installed = True
    try:
        import jax

        jax.monitoring.register_event_duration_secs_listener(_on_duration)
    except Exception:
        pass  # no monitoring API: compile_count() stays 0 forever


def compile_count() -> int:
    """Monotone count of XLA compilations since the listener installed.

    Diff two snapshots to count the recompiles a region of code caused.
    """
    install()
    return _count


def compile_seconds() -> float:
    """Monotone wall-clock seconds XLA spent compiling since install.

    Diff two snapshots to attribute compile time to a region of code —
    the duration-valued sibling of ``compile_count()`` (the listener
    always received the durations; it used to discard them).
    """
    install()
    return _seconds
