"""Backend-aware superstep dispatch: XLA segment ops vs Pallas kernels.

The seed shipped two Pallas kernels aimed exactly at the h-index superstep —
``kernels/kcore_hindex`` (rowwise clipped h-index over the degree-bucketed
ELL layout) and ``kernels/segment_sum`` (blocked one-hot-matmul segment sum
over sorted COO) — that the convergence path never called: the masked
superstep and the fused ``lax.while_loop`` body always lowered to generic
``jax.ops.segment_sum`` programs, which PR 5 measured as the 10k-vertex
CPU bottleneck. This module is the routing layer between them:

* ``resolve_plan()`` turns the platform dispatch switch
  (``repro.platform.dispatch_mode()`` — ``REPRO_PALLAS`` env var or a CLI
  flag) into a concrete ``DispatchPlan``: ``auto`` picks the Pallas kernels
  only where they compile natively (TPU), ``on`` forces them everywhere
  (interpret mode off-TPU — bit-exact, slow; the parity/CI path), ``off``
  keeps the XLA segment ops. Unavailable kernels (a jax build without
  Pallas) always fall back to XLA.
* ``masked_round_program`` / ``fused_convergence_program`` build (and
  cache) jitted superstep programs with the SAME contract as
  ``core.kcore.masked_round_segment`` / ``core.kcore.fused_convergence``,
  but with the per-round reductions routed through the kernels: the
  binary-search hit counts and the receiver computation go through the
  blocked Pallas segment sum, and — when the caller provides the static
  degree-bucketed ``EllGraph`` — the whole per-vertex h-index goes through
  the Pallas ``hindex_rows`` kernel instead of the log2(maxdeg)
  segment-sum binary search.

Dispatch is an execution-placement choice, never an accounting one: cores
and per-round MessageStats are bit-equal across every (plan, mode) pair —
the kernels do exact int32 arithmetic, ``ref.py`` stays the independent
oracle, and tests/test_dispatch.py asserts the equality across host, fused,
and sharded modes. The sharded (shard_map) paths intentionally keep the XLA
segment ops — per-shard Pallas dispatch is a later step once a real
accelerator lane exists.

Arc arrays enter the programs as jit CONSTANTS here (the blocked layout and
ELL tables are host-precomputed from them), so programs are cached by an
arc-content key: static graphs and the streaming engine's high-water padded
slots reuse one compiled program; forcing Pallas dispatch on a stream whose
slot contents churn re-stages per batch — that cost is the documented price
of ``REPRO_PALLAS=on`` off-TPU today.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import platform as _platform
from repro.graph.structs import EllGraph


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Resolved kernel-dispatch decision for superstep programs.

    ``kind`` is ``"xla"`` (generic segment ops — the default everywhere
    until a native accelerator is present) or ``"pallas"`` (route through
    the kernels package); ``interpret`` records whether Pallas kernels run
    interpreted (any backend but real TPU) — informational for reports,
    the kernels' ops wrappers decide it themselves.
    """

    kind: str = "xla"
    interpret: bool = True


@functools.lru_cache(maxsize=1)
def pallas_supported() -> bool:
    """Can this jax build stage Pallas kernels at all? (cached probe)"""
    try:
        # the kernel modules are exactly the surface the ops wrappers defer
        # (jax.experimental.pallas + pallas.tpu); probing them probes what
        # trace time will actually import
        from repro.kernels.kcore_hindex import kernel as _hk  # noqa: F401
        from repro.kernels.segment_sum import kernel as _sk  # noqa: F401
    except Exception:
        return False
    return True


def resolve_plan(mode: str | None = None) -> DispatchPlan:
    """Resolve auto/pallas/xla (default: the platform layer's switch)."""
    mode = _platform.normalize_dispatch(mode) if mode else "auto"
    if mode == "auto":
        # "auto" (incl. the KCoreConfig default) defers to the platform
        # switch, so REPRO_PALLAS / --dispatch reach every call site that
        # didn't pin a mode explicitly
        mode = _platform.dispatch_mode()
    interpret = _platform.interpret_kernels()
    if mode == "auto":
        mode = "pallas" if (not interpret and pallas_supported()) else "xla"
    if mode == "pallas" and not pallas_supported():
        warnings.warn(
            "Pallas dispatch requested but jax.experimental.pallas is "
            "unavailable; falling back to XLA segment ops",
            RuntimeWarning,
            stacklevel=2,
        )
        mode = "xla"
    return DispatchPlan(kind=mode, interpret=interpret)


# ---------------------------------------------------------------------- #
# Program cache — arc arrays are jit constants in dispatched programs
# ---------------------------------------------------------------------- #

_PROGRAMS: dict[tuple, object] = {}
_LAYOUTS: dict[tuple, object] = {}
_CACHE_CAP = 64


def _arc_key(src: np.ndarray, dst: np.ndarray, n: int) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(n).tobytes())
    h.update(np.ascontiguousarray(src, np.int32).tobytes())
    h.update(np.ascontiguousarray(dst, np.int32).tobytes())
    return h.hexdigest()


def _evict(cache: dict) -> None:
    while len(cache) > _CACHE_CAP:
        cache.pop(next(iter(cache)))


def _blocked_segment_layout(src: np.ndarray, n: int, key: str):
    """Blocked Pallas segment-sum layout over the (static) arc sources."""
    from repro.kernels.segment_sum.ops import blocked_layout

    cache_key = (key, n)
    if cache_key not in _LAYOUTS:
        _LAYOUTS[cache_key] = blocked_layout(np.asarray(src, np.int64), n)
        _evict(_LAYOUTS)
    return _LAYOUTS[cache_key]


def _make_segment_sum(plan: DispatchPlan, src: np.ndarray, n: int, key: str):
    """Traceable ``seg(vals_i32) -> (n,) i32`` for per-source reductions."""
    if plan.kind == "pallas":
        from repro.kernels.segment_sum.ops import segment_sum_blocked

        layout = _blocked_segment_layout(src, n, key)

        def seg(vals):
            return segment_sum_blocked(vals, layout, n)[:, 0]

        return seg

    src_j = jnp.asarray(src, jnp.int32)

    def seg(vals):
        return jax.ops.segment_sum(vals, src_j, num_segments=n)

    return seg


def _ell_sig(ell: EllGraph | None) -> tuple:
    if ell is None:
        return ()
    return tuple((b.width, b.ids.shape[0], b.rows_real) for b in ell.buckets)


# ---------------------------------------------------------------------- #
# Round body — the dispatched superstep
# ---------------------------------------------------------------------- #


def _make_round_body(
    n: int,
    n_iters: int,
    plan: DispatchPlan,
    src: np.ndarray,
    dst: np.ndarray,
    ell: EllGraph | None,
    key: str,
):
    """Build the traceable masked-superstep body with dispatched reductions.

    Same math as ``core.kcore._masked_round``; ``src``/``dst`` are closed
    over as constants. With ``ell`` (static fully-live adjacency only — the
    from-scratch decomposition) the h-index runs through the Pallas
    ``hindex_rows`` kernel per degree bucket; otherwise it is the binary
    search with the hit counts routed through the dispatched segment sum.
    """
    src_j = jnp.asarray(src, jnp.int32)
    dst_j = jnp.asarray(dst, jnp.int32)
    seg = _make_segment_sum(plan, src, n, key)

    if ell is not None and plan.kind == "pallas":
        from repro.kernels.kcore_hindex.ops import hindex_rows

        bucket_ids = [jnp.asarray(b.ids) for b in ell.buckets]
        bucket_nbrs = [jnp.asarray(b.nbrs) for b in ell.buckets]

        def hindex(est, est_dst_masked):
            # est_ext[n] = 0: padded neighbor slots never count for k >= 1.
            # Requires est == 0 on degree-0 vertices (true from the degree
            # seed: they are in no bucket, so their estimate passes through)
            est_ext = jnp.concatenate([est, jnp.zeros(1, jnp.int32)])
            new_ext = est_ext
            for ids, nbrs in zip(bucket_ids, bucket_nbrs):
                h = hindex_rows(est_ext[nbrs], est_ext[ids], n_iters=n_iters)
                new_ext = new_ext.at[ids].set(h)
            return new_ext[:n]

    else:

        def hindex(est, est_dst_masked):
            lo = jnp.zeros_like(est)
            hi = est

            def body(lohi, _):
                lo, hi = lohi
                mid = (lo + hi + 1) // 2
                hit = (est_dst_masked >= mid[src_j]) & (mid[src_j] > 0)
                cnt = seg(hit.astype(jnp.int32))
                ok = cnt >= mid
                return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)), None

            # lax.scan (not fori_loop) like core.kcore._hindex_by_bsearch:
            # the trip count stays visible to jaxpr-walk cost analyses
            (lo, hi), _ = lax.scan(body, (lo, hi), None, length=n_iters)
            return lo

    def round_body(est, arc_mask, active):
        est_dst = jnp.where(arc_mask, est[dst_j], 0)
        h = hindex(est, est_dst)
        new_est = jnp.where(active, h, est)
        changed = new_est < est
        recv = seg(jnp.where(arc_mask, changed[dst_j], False).astype(jnp.int32)) > 0
        return new_est, changed, recv

    return round_body


def masked_round_program(
    n: int,
    n_iters: int,
    plan: DispatchPlan,
    src: np.ndarray,
    dst: np.ndarray,
    ell: EllGraph | None = None,
):
    """Cached jitted dispatched superstep: ``(est, arc_mask, active) ->
    (new_est, changed, recv)`` — ``core.kcore.masked_round_segment`` with
    the reductions routed per ``plan`` (arc arrays are baked-in constants).
    """
    key = _arc_key(src, dst, n)
    cache_key = ("round", n, n_iters, plan, key, _ell_sig(ell))
    if cache_key not in _PROGRAMS:
        body = _make_round_body(n, n_iters, plan, src, dst, ell, key)
        _PROGRAMS[cache_key] = jax.jit(body)
        _evict(_PROGRAMS)
    return _PROGRAMS[cache_key]


def fused_convergence_program(
    n: int,
    n_iters: int,
    max_rounds: int,
    plan: DispatchPlan,
    src: np.ndarray,
    dst: np.ndarray,
    ell: EllGraph | None = None,
):
    """Cached jitted dispatched fused convergence loop.

    Same carry, cond, stat buffers, and output contract as
    ``core.kcore.fused_convergence`` — ``prog(est, arc_mask, active, deg)
    -> (est', rounds, stopped, final_active, msgs_buf, changed_buf,
    recv_buf)`` — with the while_loop BODY routed through the Pallas
    kernels per ``plan``. Accounting is reconstructed by the shared
    ``fused_round_stats``, so the bill is bit-equal to every other mode.
    """
    key = _arc_key(src, dst, n)
    cache_key = ("fused", n, n_iters, max_rounds, plan, key, _ell_sig(ell))
    if cache_key in _PROGRAMS:
        return _PROGRAMS[cache_key]

    round_body = _make_round_body(n, n_iters, plan, src, dst, ell, key)

    def prog(est, arc_mask, active, deg):
        def cond(carry):
            _est, act, r, stop = carry[:4]
            return (~stop) & (r < max_rounds) & act.any()

        def body(carry):
            est, act, r, _stop, mb, cb, rb = carry
            new_est, changed, recv = round_body(est, arc_mask, act)
            any_ch = changed.any()
            mb = mb.at[r].set(jnp.sum(jnp.where(changed, deg, 0), dtype=jnp.int32))
            cb = cb.at[r].set(jnp.sum(changed, dtype=jnp.int32))
            rb = rb.at[r].set(jnp.sum(recv, dtype=jnp.int32))
            return new_est, recv, r + 1, ~any_ch, mb, cb, rb

        zeros = jnp.zeros(max_rounds, jnp.int32)
        carry = (est, active, jnp.int32(0), jnp.bool_(False), zeros, zeros, zeros)
        est, act, r, stop, mb, cb, rb = lax.while_loop(cond, body, carry)
        return est, r, stop, jnp.sum(act, dtype=jnp.int32), mb, cb, rb

    _PROGRAMS[cache_key] = jax.jit(prog)
    _evict(_PROGRAMS)
    return _PROGRAMS[cache_key]


def clear_caches() -> None:
    """Drop cached layouts/programs (tests; after massive graph churn)."""
    _PROGRAMS.clear()
    _LAYOUTS.clear()
