"""Distributed k-core decomposition — the paper's algorithm, TPU-native.

Montresor-style locality iteration: every vertex keeps a monotonically
decreasing estimate, initialized to its degree; each round it recomputes

    est'(u) = H( { min(est(v), est(u)) : v in adj(u) } )

where H is the h-index operator, and "sends" its new value to all neighbors
when it decreased. The fixpoint equals the exact core numbers (locality
theorem, §II.B of the paper).

Execution modes
  * ``jacobi``    — paper-faithful synchronous rounds (every vertex updates
                    from last round's estimates).
  * ``block_gs``  — beyond-paper block-Gauss-Seidel: vertex blocks are swept
                    sequentially within a round using freshest estimates;
                    converges in fewer rounds / messages (mimics the Go
                    version's asynchrony).

Backends
  * ``segment``     — sorted-COO + jax.ops.segment_sum; the general, shardable
                      path. The per-round h-index is a vectorized binary
                      search (log2(maxdeg) segment_sums per round).
  * ``ell``         — degree-bucketed dense tiles, pure-jnp rowwise h-index.
  * ``ell_pallas``  — same layout, Pallas kernel (kernels/kcore_hindex).

Distribution: `make_sharded_superstep` builds a shard_map superstep over a
device mesh — vertex state sharded by contiguous range, arcs co-located with
their source, one `all_gather` of the estimate vector per round (this IS the
paper's message broadcast), counts purely local, termination = 1-bit psum.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import dispatch as _dispatch
from repro.core.jit_telemetry import compile_count, compile_seconds
from repro.core.messages import MessageStats
from repro.obs import flight as _flight
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.graph.partition import ShardedGraph
from repro.graph.structs import EllGraph, Graph


# ---------------------------------------------------------------------- #
# Config / result
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class KCoreConfig:
    mode: str = "jacobi"            # "jacobi" | "block_gs"
    backend: str = "segment"        # "segment" | "ell" | "ell_pallas"
    n_blocks: int = 8               # block_gs sweep granularity
    max_rounds: int | None = None   # None → n (the worst-case depth)
    widths: tuple[int, ...] = (8, 32, 128, 512, 2048)
    # run the whole round loop as ONE device-resident lax.while_loop via the
    # shared fused runtime (core/runtime.py) instead of one jitted superstep
    # per Python-loop round. jacobi only; accounting is bit-equal either way.
    fused: bool = False
    # superstep kernel dispatch (repro.core.dispatch): "auto" consults the
    # platform layer (REPRO_PALLAS env; Pallas only where it compiles
    # natively), "pallas"/"xla" force it. Segment-backend jacobi paths
    # (host loop and fused) only; execution placement, never accounting.
    dispatch: str = "auto"


@dataclasses.dataclass
class KCoreResult:
    core: np.ndarray
    rounds: int
    converged: bool
    stats: MessageStats
    # fresh XLA compilations this decomposition caused (process-wide delta
    # of repro.core.jit_telemetry.compile_count; 0 = every jitted program
    # was a cache hit) — makes the fused path's O(log)-compiles claim
    # measurable in benchmarks/static_decomposition.py
    recompiles: int = 0
    # ... and the wall-clock XLA spent on those compiles (the duration-
    # valued twin: jit_telemetry.compile_seconds delta)
    compile_s: float = 0.0
    # per-phase wall breakdown (seconds). Fused runs report the runtime's
    # split: "device-converge" (the while_loop, blocked to completion) and
    # "host-reconstruct" (stats recovery); host-loop runs report "converge"
    # (the whole round loop). Always measured — two perf_counter pairs per
    # DECOMPOSITION, not per round.
    phase_s: dict = dataclasses.field(default_factory=dict)
    # resolved superstep dispatch this run executed with ("xla" | "pallas");
    # see repro.core.dispatch — bills are bit-equal across choices
    dispatch: str = "xla"


def _bs_iters(max_deg: int) -> int:
    """Static binary-search iteration count covering estimates in [0, maxdeg]."""
    return max(int(np.ceil(np.log2(max_deg + 1))) + 1, 1)


# ---------------------------------------------------------------------- #
# Single-host rounds — segment backend
# ---------------------------------------------------------------------- #

def _hindex_by_bsearch(est, est_dst_masked, src, n, n_iters):
    """Vectorized per-vertex h-index via binary search.

    For every vertex u, finds max k in [0, est_u] with
    |{arcs (u,v): est_v >= k}| >= k. est_dst_masked must be 0 on padding arcs
    (so they never count for k >= 1).
    """
    lo = jnp.zeros_like(est)
    hi = est

    def body(lohi, _):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        hit = (est_dst_masked >= mid[src]) & (mid[src] > 0)
        cnt = jax.ops.segment_sum(hit.astype(jnp.int32), src, num_segments=n)
        ok = cnt >= mid
        return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)), None

    # lax.scan (not fori_loop): scan records the trip count in the jaxpr,
    # which the roofline's jaxpr-walk cost analysis needs to be exact.
    (lo, hi), _ = lax.scan(body, (lo, hi), None, length=n_iters)
    return lo


def _masked_round(est, src, dst, arc_mask, active, n, n_iters):
    """Traceable body of the masked Jacobi superstep (shared by the jitted
    per-round entry point and the fused while_loop)."""
    est_dst = jnp.where(arc_mask, est[dst], 0)
    h = _hindex_by_bsearch(est, est_dst, src, n, n_iters)
    new_est = jnp.where(active, h, est)
    changed = new_est < est
    # who receives a message next round: u s.t. some neighbor v changed
    recv = jax.ops.segment_sum(
        (jnp.where(arc_mask, changed[dst], False)).astype(jnp.int32),
        src, num_segments=n) > 0
    return new_est, changed, recv


@functools.partial(jax.jit, static_argnames=("n", "n_iters"))
def masked_round_segment(est, src, dst, arc_mask, active, n, n_iters):
    """One frontier-masked Jacobi superstep. Returns (new_est, changed, recv).

    Only vertices with ``active`` True recompute their h-index; everyone else
    keeps their estimate. With ``active`` all-True this is the paper's plain
    synchronous superstep. The masked form is the primitive the streaming
    engine (repro.streaming.engine) iterates: after an edge-churn batch only
    the frontier — vertices whose estimate may still drop — recomputes, which
    is exact for the monotone locality operator (an inactive vertex's inputs
    are unchanged, so recomputing it would be a no-op).
    """
    return _masked_round(est, src, dst, arc_mask, active, n, n_iters)


def _round_segment(est, src, dst, arc_mask, n, n_iters):
    """One (unmasked) Jacobi superstep. Returns (new_est, changed, received)."""
    active = jnp.ones(est.shape, bool)
    return masked_round_segment(est, src, dst, arc_mask, active, n, n_iters)


# ---------------------------------------------------------------------- #
# Fused convergence — one device-resident while_loop per batch
# ---------------------------------------------------------------------- #

@functools.partial(jax.jit, static_argnames=("n", "n_iters", "max_rounds"))
def fused_convergence(est, src, dst, arc_mask, active, deg,
                      n, n_iters, max_rounds):
    """Run masked Jacobi supersteps to the fixpoint in ONE ``lax.while_loop``.

    The host round loop (kcore_decompose / the streaming engine's per-round
    ``step``) pays a device round-trip of est/changed/recv per superstep —
    at streaming batch sizes that host traffic, not the h-index math,
    dominates wall-clock. Montresor et al. bound the number of rounds, so a
    whole batch re-convergence is a bounded iteration that can live on
    device: carry = (est, active, round_idx, stop, per-round stat buffers),
    body = the same ``_masked_round`` superstep the host loop runs, cond =
    frontier non-empty (and round cap not hit, and last round productive).

    Per executed round r the body fills three ``(max_rounds,)`` int32
    buffers — messages (Σ deg over changed vertices; < 2m < 2^31 per round
    for every graph we target, accumulated to int64 on host), changed
    count, and receiver count — from which the host reconstructs per-round
    ``MessageStats`` EXACTLY equal to the host-loop modes' accounting
    (see ``fused_round_stats``).

    Returns ``(est', rounds, stopped, final_active, msgs_buf, changed_buf,
    recv_buf)``: ``rounds`` counts every executed superstep including a
    final unproductive one (host-loop convention), ``stopped`` is True iff
    the loop exited on an unproductive round, ``final_active`` is the exit
    frontier size (0 and/or ``stopped`` ⇒ converged).
    """
    def cond(carry):
        _est, act, r, stop = carry[:4]
        return (~stop) & (r < max_rounds) & act.any()

    def body(carry):
        est, act, r, _stop, mb, cb, rb = carry
        new_est, changed, recv = _masked_round(est, src, dst, arc_mask,
                                               act, n, n_iters)
        any_ch = changed.any()
        mb = mb.at[r].set(jnp.sum(jnp.where(changed, deg, 0),
                                  dtype=jnp.int32))
        cb = cb.at[r].set(jnp.sum(changed, dtype=jnp.int32))
        rb = rb.at[r].set(jnp.sum(recv, dtype=jnp.int32))
        return new_est, recv, r + 1, ~any_ch, mb, cb, rb

    zeros = jnp.zeros(max_rounds, jnp.int32)
    carry = (est, active, jnp.int32(0), jnp.bool_(False),
             zeros, zeros, zeros)
    est, act, r, stop, mb, cb, rb = lax.while_loop(cond, body, carry)
    return est, r, stop, jnp.sum(act, dtype=jnp.int32), mb, cb, rb


def fused_round_stats(rounds, stopped, final_active,
                      msgs_buf, changed_buf, recv_buf):
    """Host-side reconstruction of per-round accounting from fused buffers.

    Returns ``(k, msgs, changed, recv, converged)``: ``k`` is the number of
    PRODUCTIVE rounds (the prefix whose changed count is non-zero — once a
    round changes nothing the loop stops, so productive rounds are always a
    prefix) and the three ``(k,)`` int64 arrays are exactly what the
    host-loop modes would have appended round by round.
    """
    rounds = int(rounds)
    cb = np.asarray(changed_buf[:rounds], np.int64)
    k = int((cb > 0).sum())
    converged = bool(stopped) or int(final_active) == 0
    return (k, np.asarray(msgs_buf[:k], np.int64), cb[:k],
            np.asarray(recv_buf[:k], np.int64), converged)


@functools.lru_cache(maxsize=64)
def _fused_sharded_convergence(mesh: jax.sharding.Mesh, axes: tuple,
                               V: int, n_iters: int, max_rounds: int):
    """Cached jitted fused convergence over a device mesh (streaming path).

    The masked shard_map superstep of ``_masked_sharded_superstep`` nested
    INSIDE the while_loop: the whole batch re-convergence is one shard_map
    program, with per-round cross-device traffic only (one est all_gather,
    one 1-bit changed all_gather, three scalar psums) — the host sees the
    final estimate plus the filled stat buffers, same contract and same
    exact accounting as ``fused_convergence``. Keyed on (mesh, axes, V,
    n_iters, max_rounds) like its per-round sibling so stable shard shapes
    reuse one compiled program across batches.

    Returns ``prog(est, src, dst, arc_mask, deg, active) -> (est', rounds,
    stopped, final_active, msgs_buf, changed_buf, recv_buf)`` with est'
    sharded like the state and everything else replicated.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distribution.compat import shard_map

    def prog(est, src, dst, arc_mask, deg, active):
        # shapes inside shard_map (per device): est (1, V), src (1, A), ...
        src_l, dst_l, am_l, deg_l = src[0], dst[0], arc_mask[0], deg[0]

        def cond(carry):
            _est, act, r, stop = carry[:4]
            return ((~stop) & (r < max_rounds)
                    & (lax.psum(jnp.sum(act, dtype=jnp.int32), axes) > 0))

        def body(carry):
            est_c, act_c, r, _stop, mb, cb, rb = carry
            est_glob = lax.all_gather(est_c, axes, axis=0,
                                      tiled=True).reshape(-1)
            est_dst = jnp.where(am_l, est_glob[dst_l], 0)
            h = _hindex_by_bsearch(est_c[0], est_dst, src_l, V, n_iters)
            new_l = jnp.where(act_c[0], h, est_c[0])
            changed_l = new_l < est_c[0]
            msgs = lax.psum(jnp.sum(jnp.where(changed_l, deg_l, 0),
                                    dtype=jnp.int32), axes)
            ch_cnt = lax.psum(jnp.sum(changed_l, dtype=jnp.int32), axes)
            ch_glob = lax.all_gather(changed_l[None], axes, axis=0,
                                     tiled=True).reshape(-1)
            recv_l = jax.ops.segment_sum(
                jnp.where(am_l, ch_glob[dst_l], False).astype(jnp.int32),
                src_l, num_segments=V) > 0
            rb = rb.at[r].set(lax.psum(jnp.sum(recv_l, dtype=jnp.int32),
                                       axes))
            return (new_l[None], recv_l[None], r + 1, ch_cnt == 0,
                    mb.at[r].set(msgs), cb.at[r].set(ch_cnt), rb)

        zeros = jnp.zeros(max_rounds, jnp.int32)
        carry = (est, active, jnp.int32(0), jnp.bool_(False),
                 zeros, zeros, zeros)
        est, act, r, stop, mb, cb, rb = lax.while_loop(cond, body, carry)
        final = lax.psum(jnp.sum(act, dtype=jnp.int32), axes)
        return est, r, stop, final, mb, cb, rb

    spec_state = P(axes)
    sharded = shard_map(prog, mesh=mesh, in_specs=(spec_state,) * 6,
                        out_specs=(spec_state,) + (P(),) * 6)
    return jax.jit(sharded)


# ---------------------------------------------------------------------- #
# Single-host rounds — ELL backend
# ---------------------------------------------------------------------- #

def hindex_rows_ref(nbr_est, est_u, n_iters):
    """Rowwise h-index of clip(nbr_est, 0, est_u) — jnp reference.

    nbr_est: (rows, w) int32 (sentinel slots hold 0), est_u: (rows,) int32.
    """
    vals = jnp.minimum(nbr_est, est_u[:, None])
    lo = jnp.zeros_like(est_u)
    hi = est_u

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        cnt = jnp.sum(vals >= jnp.maximum(mid[:, None], 1), axis=1)
        ok = cnt >= mid
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo, hi = lax.fori_loop(0, n_iters, body, (lo, hi))
    return lo


def _make_round_ell(ell: EllGraph, n_iters: int, use_pallas: bool):
    if use_pallas:
        from repro.kernels.kcore_hindex.ops import hindex_rows as _hindex
    else:
        _hindex = hindex_rows_ref

    bucket_ids = [jnp.asarray(b.ids) for b in ell.buckets]
    bucket_nbrs = [jnp.asarray(b.nbrs) for b in ell.buckets]
    n = ell.n

    @jax.jit
    def round_ell(est_ext):
        """est_ext: (n+1,) int32, est_ext[n] == 0 (sentinel)."""
        new_ext = est_ext
        for ids, nbrs in zip(bucket_ids, bucket_nbrs):
            nbr_est = est_ext[nbrs]
            est_u = est_ext[ids]
            h = _hindex(nbr_est, est_u, n_iters)
            new_ext = new_ext.at[ids].set(h)
        new_ext = new_ext.at[n].set(0)          # keep sentinel pinned
        changed = new_ext[:n] < est_ext[:n]
        return new_ext, changed

    return round_ell


# ---------------------------------------------------------------------- #
# Single-host rounds — block-Gauss-Seidel (beyond-paper)
# ---------------------------------------------------------------------- #

def _make_round_block_gs(sg: ShardedGraph, n_iters: int):
    src = jnp.asarray(sg.src)          # (B, A) local indices
    dst = jnp.asarray(sg.dst)          # (B, A) global indices
    amask = jnp.asarray(sg.arc_mask)
    B, V = sg.n_shards, sg.verts_per_shard
    n_pad = sg.n_pad

    @jax.jit
    def round_gs(est):
        """est: (n_pad,) int32. Sweeps blocks 0..B-1 with fresh estimates."""
        def block_body(b, carry):
            est, changed = carry
            est_dst = jnp.where(amask[b], est[dst[b]], 0)
            est_u = lax.dynamic_slice(est, (b * V,), (V,))
            new_u = _hindex_by_bsearch(est_u, est_dst, src[b], V, n_iters)
            ch_u = new_u < est_u
            est = lax.dynamic_update_slice(est, new_u, (b * V,))
            changed = lax.dynamic_update_slice(changed, ch_u, (b * V,))
            return est, changed

        changed0 = jnp.zeros(n_pad, bool)
        est, changed = lax.fori_loop(0, B, block_body, (est, changed0))
        return est, changed

    return round_gs


# ---------------------------------------------------------------------- #
# Driver
# ---------------------------------------------------------------------- #

def kcore_decompose(g: Graph, config: KCoreConfig = KCoreConfig(), *,
                    fused: bool | None = None) -> KCoreResult:
    """Run distributed k-core decomposition to the fixpoint on one host.

    Per-round message/active accounting follows the paper exactly (see
    core/messages.py). By default the Python loop is over rounds only; each
    round is one jitted superstep. With ``fused=True`` (keyword override of
    ``config.fused``) the ENTIRE round loop runs as one device-resident
    ``lax.while_loop`` through the shared fused runtime (core/runtime.py) —
    no per-round host round-trips — and the per-round stats are
    reconstructed from device buffers, bit-equal to the host loop
    (hypothesis-tested, BZ-verified). Fused is jacobi-only; the backend is
    ignored there (every backend computes the identical h-index, and the
    fused program always stages the segment arrays).
    """
    use_fused = config.fused if fused is None else fused
    if use_fused and config.mode != "jacobi":
        raise ValueError("fused=True requires mode='jacobi' "
                         f"(got {config.mode!r})")
    with _trace.span("kcore.decompose", n=g.n, m=g.m, mode=config.mode,
                     backend=config.backend, fused=bool(use_fused)) as _sp:
        res = _decompose_body(g, config, use_fused)
        _sp.set(rounds=res.rounds, messages=res.stats.total_messages,
                converged=res.converged, recompiles=res.recompiles,
                compile_s=round(res.compile_s, 6), dispatch=res.dispatch)
    return res


def _decompose_body(g: Graph, config: KCoreConfig,
                    use_fused: bool) -> KCoreResult:
    compiles0, csecs0 = compile_count(), compile_seconds()
    phase_s: dict = {}
    dispatch_kind = "xla"
    n = g.n
    if n == 0:
        return KCoreResult(core=np.zeros(0, np.int32), rounds=0,
                           converged=True,
                           stats=MessageStats(*(np.zeros(0, np.int64),) * 3))
    n_iters = _bs_iters(g.max_deg)
    max_rounds = config.max_rounds if config.max_rounds is not None else n + 1
    deg64 = g.deg.astype(np.int64)

    msgs = [int(deg64.sum())]             # round 0: degree broadcast = 2m
    # active[r] = vertices recomputing in round r. Round 0: all (they all
    # broadcast); round 1: every vertex that received the degree broadcast.
    active = [n, int((g.deg > 0).sum())]
    changed_counts = [n]

    # flight recorder: one run per decomposition, round 0 = the degree
    # broadcast. Disabled path = one attribute read; every est host-copy
    # and per-round clock below is guarded by rec.active.
    rec = _flight.recorder()
    if rec.active:
        rec.start_run(
            "static",
            "fused" if use_fused else f"{config.mode}/{config.backend}",
            n=n)
        rec.record_round(active[0], msgs[0], changed_counts[0], est=g.deg)

    if use_fused:
        from repro.core.runtime import fused_converge_dense

        plan = _dispatch.resolve_plan(config.dispatch)
        ell = None
        if plan.kind == "pallas":
            from repro.graph.structs import build_ell

            # static fully-live adjacency + degree seed: the ELL h-index
            # route is exact here (see dispatch._make_round_body)
            ell = build_ell(g, widths=config.widths)
        # from-scratch seeding: est = degrees, frontier = every vertex —
        # round 1 of the fused loop IS round 1 of the host loop, and the
        # recv-masked rounds after it are exact for the monotone locality
        # operator (an inactive vertex's inputs are unchanged)
        # frontier1: the while_loop activates everyone but the accounting
        # bills only (deg>0) receivers in round 1 — pass the accounting
        # value so flight records match the host loop bit-for-bit
        outcome = fused_converge_dense(
            g.deg, np.ones(n, bool), g.src, g.dst,
            np.ones(g.num_arcs, bool), g.deg,
            n=n, n_iters=n_iters, max_rounds=max_rounds,
            dispatch=plan.kind, ell=ell, frontier1=active[1])
        rounds, converged = outcome.rounds, outcome.converged
        dispatch_kind = outcome.dispatch
        msgs.extend(outcome.msgs.tolist())
        changed_counts.extend(outcome.changed.tolist())
        active.extend(outcome.recv.tolist())
        core = outcome.est
        phase_s["device-converge"] = outcome.device_s
        phase_s["host-reconstruct"] = outcome.reconstruct_s

    elif config.backend == "segment" and config.mode == "jacobi":
        plan = _dispatch.resolve_plan(config.dispatch)
        dispatch_kind = plan.kind
        est = jnp.asarray(g.deg, jnp.int32)
        src = jnp.asarray(g.src, jnp.int32)
        dst = jnp.asarray(g.dst, jnp.int32)
        amask = jnp.ones(g.num_arcs, bool)
        if plan.kind == "pallas":
            from repro.graph.structs import build_ell

            ell = build_ell(g, widths=config.widths)
            prog = _dispatch.masked_round_program(
                n, n_iters, plan, g.src, g.dst, ell=ell)
            ones = jnp.ones(n, bool)

            def step(est):
                return prog(est, amask, ones)
        else:

            def step(est):
                return _round_segment(est, src, dst, amask, n, n_iters)
        rounds, converged = 0, False
        t_conv = time.perf_counter()
        while rounds < max_rounds:
            t_r = time.perf_counter() if rec.active else 0.0
            with _trace.span("kcore.round", round=rounds) as rsp:
                new_est, changed, recv = step(est)
                rounds += 1
                ch_np = np.asarray(changed)
                if not ch_np.any():
                    converged = True
                    break
                msgs.append(int(deg64[ch_np].sum()))
                changed_counts.append(int(ch_np.sum()))
                active.append(int(np.asarray(recv).sum()))
                rsp.set(messages=msgs[-1], changed=changed_counts[-1])
                if rec.active:
                    rec.record_round(
                        active[rounds], msgs[-1], changed_counts[-1],
                        est=np.asarray(new_est), prev_est=np.asarray(est),
                        host_s=time.perf_counter() - t_r,
                        dispatch=dispatch_kind)
                est = new_est
        phase_s["converge"] = time.perf_counter() - t_conv
        core = np.asarray(est, np.int32)

    elif config.backend in ("ell", "ell_pallas") and config.mode == "jacobi":
        from repro.graph.structs import build_ell
        if config.backend == "ell_pallas":
            dispatch_kind = "pallas"
        ell = build_ell(g, widths=config.widths)
        round_fn = _make_round_ell(ell, n_iters,
                                   use_pallas=config.backend == "ell_pallas")
        est_ext = jnp.concatenate(
            [jnp.asarray(g.deg, jnp.int32), jnp.zeros(1, jnp.int32)])
        rounds, converged = 0, False
        t_conv = time.perf_counter()
        while rounds < max_rounds:
            t_r = time.perf_counter() if rec.active else 0.0
            with _trace.span("kcore.round", round=rounds):
                new_ext, changed = round_fn(est_ext)
                rounds += 1
                ch_np = np.asarray(changed)
                if not ch_np.any():
                    converged = True
                    break
                msgs.append(int(deg64[ch_np].sum()))
                changed_counts.append(int(ch_np.sum()))
                # receivers: any vertex adjacent to a changed vertex
                recv = _receivers_np(g, ch_np)
                active.append(int(recv.sum()))
                if rec.active:
                    rec.record_round(
                        active[rounds], msgs[-1], changed_counts[-1],
                        est=np.asarray(new_ext)[:n],
                        prev_est=np.asarray(est_ext)[:n],
                        host_s=time.perf_counter() - t_r,
                        dispatch=dispatch_kind)
                est_ext = new_ext
        phase_s["converge"] = time.perf_counter() - t_conv
        core = np.asarray(est_ext[:n], np.int32)

    elif config.mode == "block_gs":
        from repro.graph.partition import shard_graph
        sg = shard_graph(g, max(1, config.n_blocks))
        round_fn = _make_round_block_gs(sg, n_iters)
        est = jnp.asarray(sg.deg.reshape(-1), jnp.int32)
        rounds, converged = 0, False
        t_conv = time.perf_counter()
        while rounds < max_rounds:
            t_r = time.perf_counter() if rec.active else 0.0
            with _trace.span("kcore.round", round=rounds):
                new_est, changed = round_fn(est)
                rounds += 1
                ch_real = np.asarray(changed)[: g.n]
                if not ch_real.any():
                    converged = True
                    break
                msgs.append(int(deg64[ch_real].sum()))
                changed_counts.append(int(ch_real.sum()))
                active.append(int(_receivers_np(g, ch_real).sum()))
                if rec.active:
                    rec.record_round(
                        active[rounds], msgs[-1], changed_counts[-1],
                        est=np.asarray(new_est)[: g.n],
                        prev_est=np.asarray(est)[: g.n],
                        host_s=time.perf_counter() - t_r,
                        dispatch=dispatch_kind)
                est = new_est
        phase_s["converge"] = time.perf_counter() - t_conv
        core = np.asarray(est)[: g.n].astype(np.int32)

    else:
        raise ValueError(f"unsupported combo mode={config.mode} "
                         f"backend={config.backend}")

    stats = MessageStats(
        messages_per_round=np.asarray(msgs, np.int64),
        active_per_round=np.asarray(active[: len(msgs)], np.int64),
        changed_per_round=np.asarray(changed_counts[: len(msgs)], np.int64),
    )
    if rec.active:
        rec.end_run(converged=converged, messages=int(stats.total_messages))
    return KCoreResult(core=core, rounds=rounds, converged=converged,
                       stats=stats,
                       recompiles=compile_count() - compiles0,
                       compile_s=compile_seconds() - csecs0,
                       phase_s=phase_s, dispatch=dispatch_kind)


def _receivers_arrays(n: int, src: np.ndarray, dst: np.ndarray,
                      live: np.ndarray | None, changed: np.ndarray
                      ) -> np.ndarray:
    """Vertices with a (live) arc to a changed vertex — the next frontier.

    ``live`` is an optional arc mask (the streaming engine's slack-padded
    CSR has dead slots); None means every arc is real.
    """
    recv = np.zeros(n, bool)
    if changed.any():
        sel = changed[dst] if live is None else live & changed[dst]
        np.logical_or.at(recv, src[sel], True)
    return recv


def _receivers_np(g: Graph, changed: np.ndarray) -> np.ndarray:
    return _receivers_arrays(g.n, g.src, g.dst, None, changed)


# ---------------------------------------------------------------------- #
# Sharded superstep (shard_map) — the multi-pod path
# ---------------------------------------------------------------------- #

@functools.lru_cache(maxsize=128)
def _masked_sharded_superstep(mesh: jax.sharding.Mesh,
                              axes: tuple, V: int, n_iters: int):
    """Cached jitted frontier-masked sharded superstep (streaming path).

    Keyed on (mesh, axes, verts_per_shard, n_iters) so a churn stream whose
    shard shapes are stable (the engine pads them to powers of two) reuses
    one compiled program across batches. Same layout contract as
    ``make_sharded_superstep``; on top of the est all_gather a second 1-bit
    all_gather of the changed mask computes next round's receivers locally.

    Returns ``superstep(est, src, dst, arc_mask, deg, active) ->
    (est', changed, recv, msgs)`` with est'/changed/recv sharded like the
    state and msgs a replicated scalar.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distribution.compat import shard_map

    def superstep(est, src, dst, arc_mask, deg, active):
        est_l, act_l = est[0], active[0]
        est_glob = lax.all_gather(est, axes, axis=0, tiled=True).reshape(-1)
        est_dst = jnp.where(arc_mask[0], est_glob[dst[0]], 0)
        h = _hindex_by_bsearch(est_l, est_dst, src[0], V, n_iters)
        new_l = jnp.where(act_l, h, est_l)
        changed_l = new_l < est_l
        msgs = lax.psum(jnp.sum(jnp.where(changed_l, deg[0], 0)), axes)
        ch_glob = lax.all_gather(changed_l[None], axes, axis=0,
                                 tiled=True).reshape(-1)
        recv_l = jax.ops.segment_sum(
            jnp.where(arc_mask[0], ch_glob[dst[0]], False).astype(jnp.int32),
            src[0], num_segments=V) > 0
        return new_l[None], changed_l[None], recv_l[None], msgs

    spec_state = P(axes)
    sharded = shard_map(superstep, mesh=mesh,
                        in_specs=(spec_state,) * 6,
                        out_specs=(spec_state, spec_state, spec_state, P()))
    return jax.jit(sharded)


def make_sharded_superstep(sg: ShardedGraph, mesh: jax.sharding.Mesh,
                           axis_names: Sequence[str], n_iters: int,
                           masked: bool = False):
    """Build a jit-able superstep over a device mesh.

    State layout: est (n_shards, V) with the leading dim sharded over the
    flattened ``axis_names``. Per round:
      1. all_gather est over the mesh axes  — the paper's message broadcast;
      2. gather est[dst] for local arcs     — local memory traffic;
      3. log2(maxdeg) local segment_sums    — the binary-search h-index;
      4. psum of (messages, changed-any)    — the paper's heartbeat/termination.

    Returns ``superstep(est, src, dst, arc_mask, deg) -> (est', msgs, any)``
    plus the in/out shardings for jit. With ``masked=True`` the superstep
    additionally takes an ``active`` (n_shards, V) bool mask — only active
    vertices recompute — and returns ``(est', changed, recv, msgs)`` (see
    ``_masked_sharded_superstep``); this is the primitive the streaming
    engine iterates on a mesh.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(axis_names)
    V = sg.verts_per_shard

    if masked:
        shardings = {
            "state": NamedSharding(mesh, P(axes)),
            "scalar": NamedSharding(mesh, P()),
        }
        return _masked_sharded_superstep(mesh, axes, V, n_iters), shardings

    def superstep(est, src, dst, arc_mask, deg):
        # shapes inside shard_map (per device): est (1, V), src (1, A), ...
        est_l = est[0]
        est_glob = lax.all_gather(est, axes, axis=0, tiled=True).reshape(-1)
        est_dst = jnp.where(arc_mask[0], est_glob[dst[0]], 0)
        new_l = _hindex_by_bsearch(est_l, est_dst, src[0], V, n_iters)
        changed = new_l < est_l
        # int32 is safe per round: messages/round <= 2m < 2^31 for all graphs
        # we target; host-side totals accumulate in int64.
        msgs = lax.psum(jnp.sum(jnp.where(changed, deg[0], 0)), axes)
        any_changed = lax.psum(changed.any().astype(jnp.int32), axes) > 0
        return new_l[None], msgs, any_changed

    from repro.distribution.compat import shard_map

    spec_state = P(axes)  # leading shard dim over all mesh axes
    in_specs = (spec_state, spec_state, spec_state, spec_state, spec_state)
    out_specs = (spec_state, P(), P())
    sharded = shard_map(superstep, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs)
    shardings = {
        "state": NamedSharding(mesh, spec_state),
        "scalar": NamedSharding(mesh, P()),
    }
    return sharded, shardings


def kcore_decompose_sharded(g: Graph, mesh: jax.sharding.Mesh,
                            axis_names: Sequence[str],
                            max_rounds: int | None = None,
                            fused: bool = False) -> KCoreResult:
    """Run the sharded engine to convergence (works on any mesh incl. 1 dev).

    With ``fused=True`` the whole round loop nests the masked shard_map
    superstep inside one device-resident ``lax.while_loop`` (the shared
    fused runtime, core/runtime.py): per-round cross-device traffic only,
    no host round-trips, accounting bit-equal to the host loop.
    """
    from repro.distribution.compat import is_multiprocess_mesh
    from repro.graph.partition import shard_graph

    if is_multiprocess_mesh(mesh) and not fused:
        # the per-round host loop reads sharded device state every round
        # with process-local conversions; only the fused runtime stages
        # global arrays (runtime.fused_converge_sharded via compat)
        raise ValueError("multi-process meshes require fused=True")

    compiles0, csecs0 = compile_count(), compile_seconds()
    phase_s: dict = {}
    n_dev = int(np.prod([mesh.shape[a] for a in axis_names]))
    sg = shard_graph(g, n_dev)
    # straggler visibility: a round's wall is the slowest shard's, so skew
    # should be observable BEFORE it costs wall-clock (same metric the
    # out-of-core driver publishes per block store)
    from repro.graph.partition import balance_report
    _metrics.gauge("kcore_shard_imbalance").set(
        balance_report(sg)["imbalance"])
    n_iters = _bs_iters(g.max_deg)

    deg64 = g.deg.astype(np.int64)
    msgs = [int(deg64.sum())]
    active = [g.n, int((g.deg > 0).sum())]
    changed_counts = [g.n]
    cap = max_rounds if max_rounds is not None else g.n + 1

    rec = _flight.recorder()
    if rec.active:
        rec.start_run("static", "fused_sharded" if fused else "sharded",
                      n=g.n)
        rec.record_round(active[0], msgs[0], changed_counts[0], est=g.deg)

    with _trace.span("kcore.decompose", n=g.n, m=g.m, mode="sharded",
                     mesh_devices=n_dev, fused=bool(fused)) as _sp:
        if fused:
            from repro.core.runtime import fused_converge_sharded

            outcome = fused_converge_sharded(
                g.deg, np.ones(g.n, bool), sg, mesh, tuple(axis_names),
                n=g.n, n_iters=n_iters, max_rounds=cap,
                frontier1=active[1])
            rounds, converged = outcome.rounds, outcome.converged
            msgs.extend(outcome.msgs.tolist())
            changed_counts.extend(outcome.changed.tolist())
            active.extend(outcome.recv.tolist())
            core = outcome.est
            phase_s["device-converge"] = outcome.device_s
            phase_s["host-reconstruct"] = outcome.reconstruct_s
        else:
            superstep, _ = make_sharded_superstep(sg, mesh, axis_names, n_iters)
            superstep = jax.jit(superstep)

            est = jnp.asarray(sg.deg, jnp.int32)
            src = jnp.asarray(sg.src)
            dst = jnp.asarray(sg.dst)
            amask = jnp.asarray(sg.arc_mask)
            deg = jnp.asarray(sg.deg)

            rounds, converged = 0, False
            t_conv = time.perf_counter()
            while rounds < cap:
                t_r = time.perf_counter() if rec.active else 0.0
                with _trace.span("kcore.round", round=rounds) as rsp:
                    new_est, m, any_ch = superstep(est, src, dst, amask, deg)
                    rounds += 1
                    if not bool(any_ch):
                        converged = True
                        break
                    ch_real = np.asarray(new_est < est).reshape(-1)[: g.n]
                    msgs.append(int(m))
                    changed_counts.append(int(ch_real.sum()))
                    active.append(int(_receivers_np(g, ch_real).sum()))
                    rsp.set(messages=msgs[-1], changed=changed_counts[-1])
                    if rec.active:
                        rec.record_round(
                            active[rounds], msgs[-1], changed_counts[-1],
                            est=np.asarray(new_est).reshape(-1)[: g.n],
                            prev_est=np.asarray(est).reshape(-1)[: g.n],
                            host_s=time.perf_counter() - t_r)
                    est = new_est
            phase_s["converge"] = time.perf_counter() - t_conv
            core = np.asarray(est).reshape(-1)[: g.n].astype(np.int32)
        _sp.set(rounds=rounds, converged=converged,
                messages=int(np.asarray(msgs, np.int64).sum()))
    stats = MessageStats(np.asarray(msgs, np.int64),
                         np.asarray(active[: len(msgs)], np.int64),
                         np.asarray(changed_counts[: len(msgs)], np.int64))
    if rec.active:
        rec.end_run(converged=converged, messages=int(stats.total_messages))
    return KCoreResult(core=core, rounds=rounds, converged=converged,
                       stats=stats,
                       recompiles=compile_count() - compiles0,
                       compile_s=compile_seconds() - csecs0,
                       phase_s=phase_s)
