"""Simulated-network cost model (the paper's future-work item: "a specific
framework ... which supports the simulation of accurate latency").

The paper stresses (§IV.F) that Go-channel wall-clock is NOT a valid proxy
for a real deployment — message complexity is. We therefore model run time
from the measured per-round message counts under explicit network regimes,
and separately under the TPU-pod regime used by the dry-run roofline.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.messages import MessageStats


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    name: str
    latency_s: float            # per-round critical-path latency
    bandwidth_Bps: float        # aggregate bisection bandwidth
    bytes_per_message: int = 16  # {sender id, core value} + framing


INTERNET = NetworkModel("internet-p2p", latency_s=50e-3, bandwidth_Bps=1e9)
DATACENTER = NetworkModel("datacenter", latency_s=10e-6, bandwidth_Bps=100e9)
TPU_POD = NetworkModel("tpu-pod-ici", latency_s=1e-6,
                       bandwidth_Bps=256 * 50e9)   # 256 chips × ~50 GB/s link


def simulate_runtime(stats: MessageStats, model: NetworkModel) -> dict:
    per_round_bytes = stats.messages_per_round.astype(np.float64) * \
        model.bytes_per_message
    per_round_s = model.latency_s + per_round_bytes / model.bandwidth_Bps
    return {
        "model": model.name,
        "rounds": stats.rounds,
        "total_s": float(per_round_s.sum()),
        "latency_bound_fraction":
            float(stats.rounds * model.latency_s / max(per_round_s.sum(),
                                                       1e-30)),
        "per_round_s": per_round_s,
    }
