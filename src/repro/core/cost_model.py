"""Cost models: simulated-network runtime and warm-start seed selection.

Two related models live here:

* ``simulate_runtime`` — the paper's future-work item ("a specific framework
  ... which supports the simulation of accurate latency"). The paper stresses
  (§IV.F) that Go-channel wall-clock is NOT a valid proxy for a real
  deployment — message complexity is. We therefore model run time from the
  measured per-round message counts under explicit network regimes, and
  separately under the TPU-pod regime used by the dry-run roofline.

* ``choose_seed`` — the streaming engine's per-batch seeding-strategy choice
  (ISSUE 5, in the spirit of Gao et al.'s limited-resource k-core cost
  modeling). It replaces the old ``bulk_seed_frac`` step function (degree
  seed iff inserts >= 25% of post-batch edges) with an explicit wall-cost
  comparison: the tight subcore upper bound costs one +1 device pass per
  unit of core raise, a degree seed costs extra fused re-convergence rounds
  instead. Both seeds are SOUND (correctness never depends on the choice) —
  the model only decides where the wall time goes, keeping the low-message
  tight bound on mid-churn batches whose cores barely move even when their
  insert fraction is large, and the degree seed on bulk loads (e.g. a
  sliding window filling from empty) whose pass count would grow with the
  core raise.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.messages import MessageStats


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    name: str
    latency_s: float  # per-round critical-path latency
    bandwidth_Bps: float  # aggregate bisection bandwidth
    bytes_per_message: int = 16  # {sender id, core value} + framing


INTERNET = NetworkModel("internet-p2p", latency_s=50e-3, bandwidth_Bps=1e9)
DATACENTER = NetworkModel("datacenter", latency_s=10e-6, bandwidth_Bps=100e9)
# 256 chips × ~50 GB/s link
TPU_POD = NetworkModel("tpu-pod-ici", latency_s=1e-6, bandwidth_Bps=256 * 50e9)


def simulate_runtime(stats: MessageStats, model: NetworkModel) -> dict:
    per_round_bytes = stats.messages_per_round.astype(np.float64) * model.bytes_per_message
    per_round_s = model.latency_s + per_round_bytes / model.bandwidth_Bps
    return {
        "model": model.name,
        "rounds": stats.rounds,
        "total_s": float(per_round_s.sum()),
        "latency_bound_fraction": float(
            stats.rounds * model.latency_s / max(per_round_s.sum(), 1e-30)
        ),
        "per_round_s": per_round_s,
    }


# ---------------------------------------------------------------------- #
# Warm-start seed selection (streaming engine, ISSUE 5)
# ---------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class SeedCostModel:
    """Relative wall costs, in units of one fused superstep round.

    The tight insertion upper bound (engine ``_ub_converge``) runs one +1
    pass per unit of the largest true core raise; each pass is a nested
    propagation + peel over the same arc arrays as a superstep, so it costs
    a small constant number of rounds (``pass_cost_rounds``, measured ~2).
    From the tight seed the fused loop then re-converges in a handful of
    rounds (``tight_seed_rounds``); from a plain degree seed it needs the
    from-scratch round regime instead (``degree_seed_rounds``, 10-30
    measured on the Table-I analogues — we charge the low end so the model
    errs toward the low-message tight bound). Degree seeding wins exactly
    when the estimated pass count makes the tight bound the slower path:

        est_passes * pass_cost_rounds + tight_seed_rounds > degree_seed_rounds

    i.e. with the defaults, when the cores are estimated to rise by more
    than (16 - 4) / 2 = 6 levels.
    """

    pass_cost_rounds: float = 2.0
    tight_seed_rounds: float = 4.0
    degree_seed_rounds: float = 16.0


@dataclasses.dataclass(frozen=True)
class SeedChoice:
    """Outcome of ``choose_seed`` — kept for telemetry (BatchResult)."""

    strategy: str  # "tight" | "degree"
    est_passes: int  # estimated +1 passes the tight bound would run
    tight_cost: float  # modeled cost of the tight-bound path, in rounds
    degree_cost: float  # modeled cost of the degree-seed path, in rounds


def estimate_ub_passes(inserted: np.ndarray, deg: np.ndarray, old_core: np.ndarray) -> int:
    """Estimate of the +1 passes ``_ub_converge`` would run for this batch.

    The true pass count equals the largest core raise the batch causes.
    Cheap per-vertex proxy: a vertex can rise by at most its headroom
    ``new_deg - old_core`` (a core never exceeds the degree), and churn
    raises are driven by incident insertions, so we take
    ``min(inserted_degree, headroom)`` per vertex and the max over
    vertices, capped by the sequential single-edge bound (a batch of b
    insertions raises no core by more than b). A heuristic, not a bound —
    both seeds are sound, so an estimate error costs wall time only.
    """
    b = int(inserted.shape[0]) if inserted.size else 0
    if b == 0:
        return 0
    n = int(deg.shape[0])
    ins_deg = np.bincount(inserted[:, 0], minlength=n) + np.bincount(inserted[:, 1], minlength=n)
    headroom = np.maximum(deg.astype(np.int64) - old_core.astype(np.int64), 0)
    per_vertex = np.minimum(ins_deg.astype(np.int64), headroom)
    return int(min(per_vertex.max(initial=0), b))


def choose_seed(
    inserted: np.ndarray,
    deg: np.ndarray,
    old_core: np.ndarray,
    model: SeedCostModel = SeedCostModel(),
) -> SeedChoice:
    """Pick the warm-start seeding strategy for one churn batch.

    ``inserted`` is the batch's effective (b, 2) inserted-edge array,
    ``deg`` the POST-batch degrees, ``old_core`` the pre-batch exact cores
    (0 for new vertices). Returns the modeled costs alongside the choice so
    the engine can surface them as telemetry.
    """
    est_passes = estimate_ub_passes(inserted, deg, old_core)
    tight_cost = est_passes * model.pass_cost_rounds + model.tight_seed_rounds
    degree_cost = model.degree_seed_rounds
    strategy = "degree" if est_passes and degree_cost < tight_cost else "tight"
    return SeedChoice(
        strategy=strategy,
        est_passes=est_passes,
        tight_cost=tight_cost,
        degree_cost=degree_cost,
    )
