"""k-truss decomposition — the paper's §V future-work extension.

The k-truss of G is the maximal subgraph whose every edge lies in at least
k−2 triangles within the subgraph; the truss number of an edge is the
largest such k. Like k-core, it admits a vertex/edge-local fixpoint
iteration: an edge's support only depends on its triangles, so the same
BSP engine pattern applies (edge states instead of vertex states).

Here: a sequential peeling oracle (numpy) and a synchronous
"h-index-style" BSP iteration with the paper-style message accounting —
each support decrease notifies the edge's triangle partners.
"""

from __future__ import annotations

import numpy as np

from repro.core.messages import MessageStats
from repro.graph.structs import Graph


def _undirected_edges(g: Graph) -> np.ndarray:
    e = np.stack([g.src, g.dst], axis=1)
    return e[e[:, 0] < e[:, 1]]


def _adj_sets(g: Graph):
    return [set(g.neighbors(u).tolist()) for u in range(g.n)]


def ktruss_peeling(g: Graph) -> dict[tuple[int, int], int]:
    """Sequential truss numbers via support peeling (the BZ analogue)."""
    edges = [tuple(e) for e in _undirected_edges(g)]
    adj = _adj_sets(g)
    support = {e: len(adj[e[0]] & adj[e[1]]) for e in edges}
    truss: dict[tuple[int, int], int] = {}
    alive = set(edges)
    k = 2
    while alive:
        peel = [e for e in alive if support[e] <= k - 2]
        if not peel:
            k += 1
            continue
        while peel:
            e = peel.pop()
            if e not in alive:
                continue
            alive.discard(e)
            truss[e] = k
            u, v = e
            for w in adj[u] & adj[v]:
                for f in ((min(u, w), max(u, w)), (min(v, w), max(v, w))):
                    if f in alive:
                        support[f] -= 1
                        if support[f] <= k - 2:
                            peel.append(f)
            adj[u].discard(v)
            adj[v].discard(u)
    return truss


def ktruss_bsp(g: Graph, max_rounds: int | None = None):
    """Synchronous edge-local iteration: every round each edge recomputes
    its support against CURRENT alive edges at its own threshold; edges
    whose support k-converges stop. Message accounting: an edge that drops
    out notifies its (pre-drop) triangle partners.

    Returns (truss dict, MessageStats)."""
    edges = [tuple(e) for e in _undirected_edges(g)]
    adj = _adj_sets(g)
    support = {e: len(adj[e[0]] & adj[e[1]]) for e in edges}
    # truss estimate init: support + 2 (analogue of est=degree)
    est = {e: support[e] + 2 for e in edges}
    msgs = [2 * 3 * sum(support.values()) // 3 or len(edges)]
    active = [len(edges)]
    changed_per_round = [len(edges)]
    rounds = 0
    cap = max_rounds or (len(edges) + 1)
    while rounds < cap:
        rounds += 1
        new_est = {}
        for (u, v) in edges:
            # h-index over triangle partners: largest k such that at least
            # k-2 triangles have both partner edges with est >= k
            tri = []
            for w in adj[u] & adj[v]:
                e1 = (min(u, w), max(u, w))
                e2 = (min(v, w), max(v, w))
                tri.append(min(est[e1], est[e2]))
            k = est[(u, v)]
            while k > 2 and sum(t >= k for t in tri) < k - 2:
                k -= 1
            new_est[(u, v)] = min(k, est[(u, v)])
        changed = [e for e in edges if new_est[e] < est[e]]
        est = new_est
        if not changed:
            break
        msgs.append(sum(len(adj[e[0]] & adj[e[1]]) * 2 for e in changed))
        changed_per_round.append(len(changed))
        active.append(len({f for e in changed
                           for w in adj[e[0]] & adj[e[1]]
                           for f in ((min(e[0], w), max(e[0], w)),
                                     (min(e[1], w), max(e[1], w)))}))
    stats = MessageStats(np.asarray(msgs, np.int64),
                         np.asarray(active[: len(msgs)], np.int64),
                         np.asarray(changed_per_round[: len(msgs)],
                                    np.int64))
    return est, stats
