"""Exact message / active-node accounting — the paper's §II.B metrics.

The paper counts a message every time a vertex sends its (new) estimate to a
neighbor. Rules (§III):
  * round 0: every vertex broadcasts its degree to all neighbors
    → Σ deg(u) = 2m messages; all n vertices Active;
  * round r ≥ 1: a vertex whose estimate *decreased* broadcasts to all
    neighbors → deg(u) messages; a vertex is Active in round r iff it
    received ≥1 message in round r-1 (it must recompute).

Work bound (§II.B):  W = O( Σ_u deg(u) · (deg(u) − core(u)) )  — each unit
decrease of u's estimate costs deg(u) messages, and the estimate travels from
deg(u) down to core(u).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structs import Graph


@dataclasses.dataclass
class MessageStats:
    """Per-round accounting collected by the engine."""
    messages_per_round: np.ndarray   # (R,) int64; [0] = 2m initial broadcast
    active_per_round: np.ndarray     # (R,) int64; receivers that recompute
    changed_per_round: np.ndarray    # (R,) int64; senders (estimate decreased)

    @property
    def total_messages(self) -> int:
        return int(self.messages_per_round.sum())

    @property
    def rounds(self) -> int:
        return int(len(self.messages_per_round))


def work_bound(g: Graph, core: np.ndarray) -> int:
    """Paper's W = Σ deg·(deg − core) + 2m (including the initial broadcast)."""
    d = g.deg.astype(np.int64)
    return int((d * (d - core.astype(np.int64))).sum() + d.sum())


def heartbeat_overhead(stats: MessageStats, *, heartbeat_every_rounds: int = 1
                       ) -> dict:
    """Model of the paper's centralized termination detection (§III.C).

    In the Go simulation every *activation* triggers an immediate heartbeat,
    plus periodic 10 s heartbeats while active. At round granularity we charge
    one heartbeat per active vertex per ``heartbeat_every_rounds`` rounds —
    the paper's event-driven lower bound — and compare with the BSP
    termination cost (one scalar all-reduce per round).
    """
    hb = int(stats.active_per_round[::heartbeat_every_rounds].sum())
    return {
        "heartbeat_messages": hb,
        "bsp_allreduce_rounds": stats.rounds,
        "heartbeat_fraction_of_traffic": hb / max(stats.total_messages, 1),
    }
