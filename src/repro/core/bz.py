"""Batagelj–Zaversnik sequential k-core decomposition — the paper's baseline.

O(n + m) bucket-sort peeling, exactly as reviewed in the paper's §I: the
sequential algorithm the distributed one is compared against, and our oracle
for every correctness test. Pure numpy, no JAX.
"""

from __future__ import annotations

import numpy as np

from repro.graph.structs import Graph


def bz_core_numbers(g: Graph) -> np.ndarray:
    """Exact core numbers via BZ bucket peeling."""
    n = g.n
    if n == 0:
        return np.zeros(0, np.int32)
    deg = g.deg.astype(np.int64).copy()
    md = int(deg.max()) if n else 0

    # bucket sort vertices by degree
    bin_count = np.bincount(deg, minlength=md + 1)
    bin_start = np.zeros(md + 2, np.int64)
    np.cumsum(bin_count, out=bin_start[1:])
    pos = np.zeros(n, np.int64)          # position of vertex in vert[]
    vert = np.zeros(n, np.int64)         # vertices sorted by current degree
    fill = bin_start[:-1].copy()
    for v in range(n):
        d = deg[v]
        pos[v] = fill[d]
        vert[fill[d]] = v
        fill[d] += 1
    bin_ptr = bin_start[:-1].copy()      # start index of each degree bucket

    core = deg.copy()
    dst, offsets = g.dst, g.offsets
    for i in range(n):
        v = vert[i]
        core[v] = deg[v]
        for u in dst[offsets[v]:offsets[v + 1]]:
            if deg[u] > deg[v]:
                du = deg[u]
                pu = pos[u]
                pw = bin_ptr[du]
                w = vert[pw]
                if u != w:               # swap u to the front of its bucket
                    pos[u], pos[w] = pw, pu
                    vert[pu], vert[pw] = w, u
                bin_ptr[du] += 1
                deg[u] -= 1
    return core.astype(np.int32)


def max_core(g: Graph) -> int:
    c = bz_core_numbers(g)
    return int(c.max()) if len(c) else 0
