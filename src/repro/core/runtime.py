"""Shared fused convergence runtime — one layer, both engines.

The device-resident ``lax.while_loop`` programs (``core.kcore.fused_convergence``
and its nested-shard_map sibling) were born in the streaming engine (ISSUE 4);
this module lifts their host-side orchestration — staging/padding inputs,
dispatching the right fused program, reconstructing exact per-round
``MessageStats`` arrays from the device stat buffers — into a runtime that
BOTH engines call:

* ``kcore_decompose(..., fused=True)`` / ``kcore_decompose_sharded(...,
  fused=True)`` run the paper's from-scratch decomposition as one jitted
  while_loop (seed = degrees, frontier = everyone);
* ``StreamingKCoreEngine`` (frontier ``fused`` / ``fused_sharded``) runs each
  churn-batch re-convergence the same way (seed = warm-start bound, frontier
  = the batch's touched set).

The contract either way: the returned accounting is bit-equal to what the
host-loop modes would have appended round by round (BZ-verified and
hypothesis-tested), so fusing is purely an execution-placement choice —
never an accounting one.

Every fused run is observable (repro.obs): a ``fused-converge`` span wraps
the whole dispatch with ``device-converge`` (the while_loop itself, blocked
to completion so the span owns the real device wall) and
``stats-reconstruct`` (host-side MessageStats recovery) children, plus
attributes for rounds, messages, and the compile count/seconds delta this
run caused (repro.core.jit_telemetry — fresh XLA compiles land inside the
``device-converge`` span as ``xla.compile`` events). The phase walls are
also measured unconditionally into ``FusedOutcome.device_s`` /
``reconstruct_s`` (two ``perf_counter`` pairs per BATCH — nanoseconds
against a convergence that runs for milliseconds) so benchmark rows get
the breakdown without tracing on.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as _dispatch
from repro.core.jit_telemetry import compile_count, compile_seconds
from repro.core.kcore import (
    _fused_sharded_convergence,
    fused_convergence,
    fused_round_stats,
)
from repro.obs import flight, trace


@dataclasses.dataclass
class FusedOutcome:
    """Host-side result of one fused convergence run.

    ``msgs`` / ``changed`` / ``recv`` cover exactly the PRODUCTIVE rounds —
    the arrays a host round loop would have appended — while ``rounds``
    counts every executed superstep including the final unproductive one
    (the host-loop convention).
    """

    est: np.ndarray  # (n,) int32 final estimates (exact cores on convergence)
    rounds: int
    converged: bool
    msgs: np.ndarray  # (k,) int64 messages per productive round
    changed: np.ndarray  # (k,) int64 senders per productive round
    recv: np.ndarray  # (k,) int64 receivers per productive round
    # phase walls (always measured; see module docstring):
    device_s: float = 0.0  # fused while_loop dispatch + device completion
    reconstruct_s: float = 0.0  # host-side stats/est reconstruction
    compile_delta: int = 0  # fresh XLA compiles this run caused
    compile_s: float = 0.0  # ... and the wall XLA spent on them
    # which superstep implementation ran (repro.core.dispatch): "xla" =
    # generic segment ops, "pallas" = the kernels package. Execution
    # placement only — the accounting above is bit-equal either way.
    dispatch: str = "xla"


def _finish(
    span, raw, rounds_raw, t_dev, compiles0, csecs0, est_of, dispatch="xla", frontier1=None, seed=None
):
    """Shared tail of both fused paths: block, time phases, reconstruct."""
    t0 = time.perf_counter()
    r, stop, final_act, mb, cb, rb = raw
    _k, m_r, c_r, r_r, converged = fused_round_stats(rounds_raw, stop, final_act, mb, cb, rb)
    est = est_of()
    reconstruct_s = time.perf_counter() - t0
    outcome = FusedOutcome(
        est=est,
        rounds=int(rounds_raw),
        converged=converged,
        msgs=m_r,
        changed=c_r,
        recv=r_r,
        device_s=t_dev,
        reconstruct_s=reconstruct_s,
        compile_delta=compile_count() - compiles0,
        compile_s=compile_seconds() - csecs0,
        dispatch=dispatch,
    )
    span.set(
        rounds=outcome.rounds,
        messages=int(outcome.msgs.sum()),
        converged=outcome.converged,
        compile_delta=outcome.compile_delta,
        compile_s=round(outcome.compile_s, 6),
    )
    # flight capture, reconstructed post-hoc from the while_loop stat
    # buffers: exactly the rounds a host loop would have recorded, same
    # accounting arrays. No-op (single attribute read) when disabled.
    rec = flight.recorder()
    if rec.active:
        rec.record_fused_rounds(
            outcome.msgs,
            outcome.changed,
            outcome.recv,
            frontier1=int(frontier1) if frontier1 is not None else (
                int(outcome.recv[0]) if len(outcome.recv) else 0
            ),
            device_s=t_dev,
            compiles=outcome.compile_delta,
            dispatch=dispatch,
            seed=seed,
            final=est,
        )
    return outcome


def fused_converge_dense(
    seed, active, src, dst, arc_mask, deg, *, n, n_iters, max_rounds, dispatch=None, ell=None, frontier1=None
):
    """Single-device fused convergence over (padded) arc arrays.

    ``src``/``dst``/``arc_mask`` may be numpy or already-device arrays; the
    streaming engine passes its pow2 high-water padded CSR slots, the static
    engine the plain sorted-COO arrays (every arc live).

    ``dispatch`` picks the superstep implementation inside the while_loop
    (``repro.core.dispatch``): None/"auto" consults the platform layer
    (``REPRO_PALLAS``), "pallas"/"xla" force it. With the Pallas plan the
    per-round reductions run through the kernels package — and through the
    ``kcore_hindex`` ELL kernel when the caller passes the static
    degree-bucketed ``ell`` layout (from-scratch decompositions only; the
    streaming engine's masked slot arrays stay on the segment-sum route).
    Accounting is bit-equal across every dispatch choice.
    """
    compiles0, csecs0 = compile_count(), compile_seconds()
    plan = _dispatch.resolve_plan(dispatch)
    # flight bookkeeping resolved up front, BEFORE device work: the
    # accounting round-1 frontier (callers override when their while_loop
    # activation differs from the accounting convention) and a host copy
    # of the seed for the aggregate drop histogram. Zero work when the
    # recorder is disabled.
    rec = flight.recorder()
    seed_np = None
    if rec.active:
        if frontier1 is None:
            frontier1 = int(np.asarray(active).sum())
        seed_np = np.asarray(seed, np.int64).copy()
    with trace.span("fused-converge", n=n, max_rounds=max_rounds, dispatch=plan.kind) as span:
        with trace.span("device-converge"):
            t0 = time.perf_counter()
            if plan.kind == "pallas":
                prog = _dispatch.fused_convergence_program(
                    n,
                    n_iters,
                    max_rounds,
                    plan,
                    np.asarray(src, np.int32),
                    np.asarray(dst, np.int32),
                    ell=ell,
                )
                est_j, r, stop, final_act, mb, cb, rb = prog(
                    jnp.asarray(seed, jnp.int32),
                    jnp.asarray(arc_mask),
                    jnp.asarray(active),
                    jnp.asarray(deg, jnp.int32),
                )
            else:
                est_j, r, stop, final_act, mb, cb, rb = fused_convergence(
                    jnp.asarray(seed, jnp.int32),
                    jnp.asarray(src, jnp.int32),
                    jnp.asarray(dst, jnp.int32),
                    jnp.asarray(arc_mask),
                    jnp.asarray(active),
                    jnp.asarray(deg, jnp.int32),
                    n=n,
                    n_iters=n_iters,
                    max_rounds=max_rounds,
                )
            # block INSIDE the span: the async dispatch returns immediately,
            # and without the sync the device wall would be misattributed to
            # whichever np.asarray happens to touch a result first
            est_j = jax.block_until_ready(est_j)
            t_dev = time.perf_counter() - t0
        with trace.span("stats-reconstruct"):
            return _finish(
                span,
                (r, stop, final_act, mb, cb, rb),
                r,
                t_dev,
                compiles0,
                csecs0,
                lambda: np.asarray(est_j, np.int32),
                dispatch=plan.kind,
                frontier1=frontier1,
                seed=seed_np,
            )


def fused_converge_sharded(seed, active, sg, mesh, axis_names, *, n, n_iters, max_rounds, frontier1=None):
    """Fused convergence with the masked shard_map superstep nested inside.

    ``sg`` is a ``repro.graph.partition.ShardedGraph`` (from ``shard_graph``
    for the static engine, ``shard_arc_arrays`` over live CSR slots for the
    streaming engine); ``seed``/``active`` are plain (n,) host vectors and
    are padded/reshaped to the shard layout here.

    The mesh may span PROCESSES (``compat.init_multiprocess`` +
    ``compat.global_mesh``): every rank calls this with the same graph and
    the same host vectors (SPMD — the graph is cheap to hold per host, the
    device arrays are what's sharded), inputs are staged as global arrays
    through ``compat.stage_to_mesh``, and the sharded estimate output comes
    back through ``compat.fetch_replicated``. The stat buffers are
    replicated outputs, so their host reads stay process-local. Accounting
    is bit-equal to every single-process mode either way (asserted rank-side
    in tests/test_multihost.py).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distribution import compat

    compiles0, csecs0 = compile_count(), compile_seconds()
    rec = flight.recorder()
    seed_np = None
    if rec.active:
        if frontier1 is None:
            frontier1 = int(np.asarray(active).sum())
        seed_np = np.asarray(seed, np.int64).copy()
    multiproc = compat.is_multiprocess_mesh(mesh)
    axes = tuple(axis_names)
    if multiproc:
        def stage(a):
            return compat.stage_to_mesh(np.asarray(a), mesh, P(axes))
    else:
        stage = jnp.asarray
    with trace.span("fused-converge", n=n, max_rounds=max_rounds,
                    mesh_devices=sg.n_shards, multiprocess=multiproc) as span:
        prog = _fused_sharded_convergence(
            mesh, axes, sg.verts_per_shard, n_iters, max_rounds
        )
        n_dev, V = sg.n_shards, sg.verts_per_shard
        est_p = np.zeros(sg.n_pad, np.int32)
        est_p[:n] = seed
        act_p = np.zeros(sg.n_pad, bool)
        act_p[:n] = active
        with trace.span("device-converge"):
            t0 = time.perf_counter()
            est_j, r, stop, final_act, mb, cb, rb = prog(
                stage(est_p.reshape(n_dev, V)),
                stage(sg.src),
                stage(sg.dst),
                stage(sg.arc_mask),
                stage(sg.deg),
                stage(act_p.reshape(n_dev, V)),
            )
            est_j = jax.block_until_ready(est_j)
            t_dev = time.perf_counter() - t0
        with trace.span("stats-reconstruct"):
            return _finish(
                span,
                (r, stop, final_act, mb, cb, rb),
                r,
                t_dev,
                compiles0,
                csecs0,
                lambda: compat.fetch_replicated(est_j, mesh)
                .reshape(-1)[:n].astype(np.int32),
                frontier1=frontier1,
                seed=seed_np,
            )
