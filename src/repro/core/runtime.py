"""Shared fused convergence runtime — one layer, both engines.

The device-resident ``lax.while_loop`` programs (``core.kcore.fused_convergence``
and its nested-shard_map sibling) were born in the streaming engine (ISSUE 4);
this module lifts their host-side orchestration — staging/padding inputs,
dispatching the right fused program, reconstructing exact per-round
``MessageStats`` arrays from the device stat buffers — into a runtime that
BOTH engines call:

* ``kcore_decompose(..., fused=True)`` / ``kcore_decompose_sharded(...,
  fused=True)`` run the paper's from-scratch decomposition as one jitted
  while_loop (seed = degrees, frontier = everyone);
* ``StreamingKCoreEngine`` (frontier ``fused`` / ``fused_sharded``) runs each
  churn-batch re-convergence the same way (seed = warm-start bound, frontier
  = the batch's touched set).

The contract either way: the returned accounting is bit-equal to what the
host-loop modes would have appended round by round (BZ-verified and
hypothesis-tested), so fusing is purely an execution-placement choice —
never an accounting one.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.kcore import (
    _fused_sharded_convergence,
    fused_convergence,
    fused_round_stats,
)


@dataclasses.dataclass
class FusedOutcome:
    """Host-side result of one fused convergence run.

    ``msgs`` / ``changed`` / ``recv`` cover exactly the PRODUCTIVE rounds —
    the arrays a host round loop would have appended — while ``rounds``
    counts every executed superstep including the final unproductive one
    (the host-loop convention).
    """

    est: np.ndarray  # (n,) int32 final estimates (exact cores on convergence)
    rounds: int
    converged: bool
    msgs: np.ndarray  # (k,) int64 messages per productive round
    changed: np.ndarray  # (k,) int64 senders per productive round
    recv: np.ndarray  # (k,) int64 receivers per productive round


def fused_converge_dense(seed, active, src, dst, arc_mask, deg, *, n, n_iters, max_rounds):
    """Single-device fused convergence over (padded) arc arrays.

    ``src``/``dst``/``arc_mask`` may be numpy or already-device arrays; the
    streaming engine passes its pow2 high-water padded CSR slots, the static
    engine the plain sorted-COO arrays (every arc live).
    """
    est_j, r, stop, final_act, mb, cb, rb = fused_convergence(
        jnp.asarray(seed, jnp.int32),
        jnp.asarray(src, jnp.int32),
        jnp.asarray(dst, jnp.int32),
        jnp.asarray(arc_mask),
        jnp.asarray(active),
        jnp.asarray(deg, jnp.int32),
        n=n,
        n_iters=n_iters,
        max_rounds=max_rounds,
    )
    _k, m_r, c_r, r_r, converged = fused_round_stats(r, stop, final_act, mb, cb, rb)
    return FusedOutcome(
        est=np.asarray(est_j, np.int32),
        rounds=int(r),
        converged=converged,
        msgs=m_r,
        changed=c_r,
        recv=r_r,
    )


def fused_converge_sharded(seed, active, sg, mesh, axis_names, *, n, n_iters, max_rounds):
    """Fused convergence with the masked shard_map superstep nested inside.

    ``sg`` is a ``repro.graph.partition.ShardedGraph`` (from ``shard_graph``
    for the static engine, ``shard_arc_arrays`` over live CSR slots for the
    streaming engine); ``seed``/``active`` are plain (n,) host vectors and
    are padded/reshaped to the shard layout here.
    """
    prog = _fused_sharded_convergence(
        mesh, tuple(axis_names), sg.verts_per_shard, n_iters, max_rounds
    )
    n_dev, V = sg.n_shards, sg.verts_per_shard
    est_p = np.zeros(sg.n_pad, np.int32)
    est_p[:n] = seed
    act_p = np.zeros(sg.n_pad, bool)
    act_p[:n] = active
    est_j, r, stop, final_act, mb, cb, rb = prog(
        jnp.asarray(est_p.reshape(n_dev, V)),
        jnp.asarray(sg.src),
        jnp.asarray(sg.dst),
        jnp.asarray(sg.arc_mask),
        jnp.asarray(sg.deg),
        jnp.asarray(act_p.reshape(n_dev, V)),
    )
    _k, m_r, c_r, r_r, converged = fused_round_stats(r, stop, final_act, mb, cb, rb)
    return FusedOutcome(
        est=np.asarray(est_j).reshape(-1)[:n].astype(np.int32),
        rounds=int(r),
        converged=converged,
        msgs=m_r,
        changed=c_r,
        recv=r_r,
    )
