"""The paper's primary contribution: distributed k-core decomposition as a
composable JAX module, with exact message accounting, termination-detection
models, and a simulated-network cost model."""

from repro.core.bz import bz_core_numbers, max_core
from repro.core.dispatch import DispatchPlan, pallas_supported, resolve_plan
from repro.core.jit_telemetry import compile_count, compile_seconds
from repro.core.kcore import (
    KCoreConfig,
    KCoreResult,
    fused_convergence,
    fused_round_stats,
    kcore_decompose,
    kcore_decompose_sharded,
    make_sharded_superstep,
    masked_round_segment,
)
from repro.core.cost_model import SeedCostModel, choose_seed, estimate_ub_passes
from repro.core.messages import MessageStats, heartbeat_overhead, work_bound
from repro.core.outofcore import (
    OutOfCoreResult,
    OutOfCoreStats,
    outofcore_decompose,
)
from repro.core.runtime import (
    FusedOutcome,
    fused_converge_dense,
    fused_converge_sharded,
)

__all__ = [
    "SeedCostModel",
    "choose_seed",
    "estimate_ub_passes",
    "FusedOutcome",
    "fused_converge_dense",
    "fused_converge_sharded",
    "bz_core_numbers",
    "max_core",
    "DispatchPlan",
    "pallas_supported",
    "resolve_plan",
    "compile_count",
    "compile_seconds",
    "KCoreConfig",
    "KCoreResult",
    "fused_convergence",
    "fused_round_stats",
    "kcore_decompose",
    "kcore_decompose_sharded",
    "make_sharded_superstep",
    "masked_round_segment",
    "MessageStats",
    "heartbeat_overhead",
    "work_bound",
    "OutOfCoreResult",
    "OutOfCoreStats",
    "outofcore_decompose",
]
