"""Termination detection — the paper's §II.C/§III.C, adapted to BSP.

The Go simulation uses a centralized heartbeat server (10 s heartbeats,
30 s check window, 5 min silence → terminate). In a bulk-synchronous TPU
execution the same *information* — "is any node still active?" — is a single
1-bit all-reduce per round, with zero false-termination risk and no timers.

This module keeps both models so the paper's overhead trade-off remains
reproducible, and adds a Dijkstra–Scholten-style tree estimate for
comparison (the paper lists it as an alternative)."""

from __future__ import annotations

import dataclasses
import math

from repro.core.messages import MessageStats


@dataclasses.dataclass(frozen=True)
class HeartbeatModel:
    """Paper's centralized server (§III.C)."""
    heartbeat_interval_s: float = 10.0
    check_interval_s: float = 30.0
    silence_timeout_s: float = 300.0

    def overhead(self, stats: MessageStats, round_time_s: float) -> dict:
        """Heartbeat traffic + termination delay for a run whose rounds each
        take ``round_time_s`` (the paper's simulation-clock analogue)."""
        total_time = stats.rounds * round_time_s
        # event heartbeats: one per activation
        event_hb = int(stats.active_per_round.sum())
        # periodic heartbeats: active nodes re-send every interval
        periods = max(int(total_time / self.heartbeat_interval_s), 0)
        per_round_active = float(stats.active_per_round.mean()) if \
            stats.rounds else 0.0
        periodic_hb = int(periods * per_round_active)
        return {
            "event_heartbeats": event_hb,
            "periodic_heartbeats": periodic_hb,
            "total_heartbeats": event_hb + periodic_hb,
            "termination_delay_s": self.silence_timeout_s,
        }


def bsp_termination_cost(stats: MessageStats, n_devices: int) -> dict:
    """Our replacement: one scalar all-reduce per round."""
    hops = max(int(math.ceil(math.log2(max(n_devices, 2)))), 1)
    return {
        "allreduces": stats.rounds,
        "latency_hops_total": stats.rounds * hops,
        "termination_delay_rounds": 1,
    }


def dijkstra_scholten_estimate(stats: MessageStats) -> dict:
    """Tree-based detection: every basic message eventually triggers one
    signal message back up the tree → overhead ≈ total basic messages."""
    return {"signal_messages": stats.total_messages}
