"""Out-of-core block-cycling k-core decomposition — bounded device memory.

The in-memory modes (host loop, fused while_loop, sharded) materialize the
FULL arc arrays on device, so the largest decomposable graph is capped by
device memory. This driver removes that cap by cycling
``repro.graph.blockstore`` blocks through the device one at a time, exactly
as Gao et al. cycle disk blocks through a small compute tier (PAPERS.md):

  * vertex-indexed state (estimates, frontier, degrees) stays dense on the
    host — O(n) int32/bool, two orders of magnitude below the arc arrays;
  * per round, each block with ≥1 active vertex is materialized (through a
    byte-budgeted LRU ``BlockCache``) and runs ONE masked Jacobi superstep
    on device: the same ``_hindex_by_bsearch`` program as every other mode,
    over the block's (V,) vertices and (A,) arcs only;
  * the *halo buffer* is the per-block gather ``est_prev[dst]`` — the
    neighbor estimates a block needs, shipped as one (A,) vector instead of
    the whole estimate array;
  * blocks whose vertex range has NO active vertices are skipped without
    loading — the frontier masks the engines already maintain double as a
    block-level I/O filter, so the load rate collapses with the frontier.

Exactness: every block superstep reads the ROUND-START estimates
(``est_prev``), so a full sweep is one synchronous Jacobi round — the same
operator the host loop and the fused while_loop iterate. Cores AND the
per-round message bill are therefore bit-equal to every in-memory mode
(BZ-oracle-verified, asserted in tests/test_outofcore.py). Receivers are
accumulated from the *loaded* blocks only: ``recv[dst] |= changed[src]``
over each processed block's arcs equals the host loop's
``segment_sum(changed[dst])`` because the arc set is symmetric (both
directions of every undirected edge are stored, dead slots die in pairs)
and a vertex can only change inside a processed block.
"""

from __future__ import annotations

import dataclasses
import functools
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jit_telemetry import compile_count, compile_seconds
from repro.core.kcore import KCoreResult, _bs_iters
from repro.core.messages import MessageStats
from repro.graph.blockstore import (ARC_SLOT_BYTES, BlockCache, BlockStore,
                                    plan_blocks)
from repro.graph.structs import Graph
from repro.obs import flight as _flight
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


def peak_rss_bytes() -> int:
    """Process peak resident set size (ru_maxrss is KiB on Linux)."""
    import resource
    import sys

    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return int(ru) if sys.platform == "darwin" else int(ru) * 1024


@dataclasses.dataclass
class OutOfCoreStats:
    """Block-cycling telemetry for one decomposition."""

    n_blocks: int
    V: int
    A: int
    rounds: int
    blocks_loaded: int  # cache misses — blocks actually read from disk
    blocks_skipped: int  # block-rounds skipped via the frontier mask
    block_rounds: int  # block supersteps executed (loads + cache hits)
    cache_hits: int
    evictions: int
    cache_peak_bytes: int
    mem_budget: int | None
    device_block_bytes: int  # largest block shipped, in arc bytes (device peak)
    total_arc_bytes: int  # full arc arrays (the in-memory footprint)
    imbalance: float  # max/mean live arcs per block (straggler factor)
    peak_rss_bytes: int
    ms_per_round: float

    @property
    def skip_rate(self) -> float:
        total = self.block_rounds + self.blocks_skipped
        return self.blocks_skipped / max(total, 1)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["skip_rate"] = round(self.skip_rate, 4)
        return d


@dataclasses.dataclass
class OutOfCoreResult(KCoreResult):
    """KCoreResult plus the block-cycling telemetry."""

    block_stats: OutOfCoreStats | None = None


@functools.partial(jax.jit, static_argnames=("n_iters",))
def _block_superstep(est_u, est_dst_masked, src_local, row_off, active,
                     n_iters):
    """One masked Jacobi superstep over a single resident block.

    Identical math to ``kcore._masked_round`` restricted to the block: the
    caller pre-gathers the halo ``est_dst_masked = where(mask, est_prev[dst],
    0)`` on the host, so the device only ever sees (V,) vertex state and
    (A,) arc state. Because a block's arcs are src-sorted the per-vertex
    hit counts inside the h-index binary search come from a cumsum +
    row-offset difference instead of ``segment_sum`` — an exact integer
    rewrite that sidesteps XLA's serialized scatter-add on CPU (~8x per
    superstep). Arc inputs arrive sliced to the block's pow2 LENGTH BUCKET
    (not the store-wide max A), so the straggler block no longer inflates
    every other block's arc slots; the bucket count bounds the number of
    compiled shapes at ~log2(A).
    """
    lo = jnp.zeros_like(est_u)
    hi = est_u

    def body(lohi, _):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        hit = (est_dst_masked >= mid[src_local]) & (mid[src_local] > 0)
        c = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum(hit.astype(jnp.int32))])
        cnt = c[row_off[1:]] - c[row_off[:-1]]
        ok = cnt >= mid
        return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)), None

    (h, _), _ = jax.lax.scan(body, (lo, hi), None, length=n_iters)
    new = jnp.where(active, h, est_u)
    return new, new < est_u


def _bucket(length: int, cap: int) -> int:
    """Smallest pow2 >= ``length`` (min 8), clamped to the store-wide A."""
    b = 8
    while b < length:
        b <<= 1
    return min(b, cap)


def _publish_metrics(stats: OutOfCoreStats) -> None:
    """Fold the block-cycling telemetry into the process metrics registry."""
    _metrics.counter("kcore_ooc_blocks_loaded_total").inc(stats.blocks_loaded)
    _metrics.counter("kcore_ooc_blocks_skipped_total").inc(
        stats.blocks_skipped)
    _metrics.counter("kcore_ooc_evictions_total").inc(stats.evictions)
    _metrics.gauge("kcore_ooc_device_block_bytes").set(
        stats.device_block_bytes)
    _metrics.gauge("kcore_ooc_total_arc_bytes").set(stats.total_arc_bytes)
    _metrics.gauge("kcore_ooc_cache_peak_bytes").set(stats.cache_peak_bytes)
    _metrics.gauge("kcore_ooc_peak_rss_bytes").set(stats.peak_rss_bytes)
    _metrics.gauge("kcore_block_imbalance").set(stats.imbalance)


def outofcore_decompose(source, *, mem_budget: int | None = None,
                        n_blocks: int | None = None,
                        max_rounds: int | None = None,
                        store_dir: str | None = None,
                        deg: np.ndarray | None = None,
                        keep_store: bool = False) -> OutOfCoreResult:
    """Decompose to the exact fixpoint while keeping ≤ one block on device.

    ``source`` is a ``Graph`` (a temporary ``BlockStore`` is written under
    ``store_dir`` / the system tmpdir and deleted afterwards unless
    ``keep_store``), an opened ``BlockStore``, or a store directory path.
    ``mem_budget`` bounds the LRU block cache in bytes — ``plan_blocks``
    picks the block count from it when ``n_blocks`` is not forced.
    ``deg`` must be passed (full (n,) int32) when ``source`` is a store
    built from masked arrays whose degrees are not ``mask``-weighted
    bincounts of the stored arcs; for stores written from a ``Graph`` it is
    reconstructed from the blocks on a single streaming pass.

    The accounting contract matches every in-memory mode bit for bit:
    round 0 bills the degree broadcast (2m messages, n senders, all-vertex
    frontier), round r ≥ 1 bills Σ deg over vertices whose estimate
    dropped, and the active series is the receiver counts.
    """
    tmp = None
    if isinstance(source, Graph):
        g: Graph = source
        if n_blocks is None:
            n_blocks = plan_blocks(g.n, g.src, mem_budget)
        tmp = tempfile.mkdtemp(prefix="kcore_blocks_", dir=store_dir)
        store = BlockStore.create(f"{tmp}/store", g, n_blocks=n_blocks)
        deg = g.deg
    elif isinstance(source, BlockStore):
        store = source
    else:
        store = BlockStore.open(source)
    try:
        return _decompose_store(store, deg=deg, mem_budget=mem_budget,
                                max_rounds=max_rounds)
    finally:
        if tmp is not None and not keep_store:
            import shutil

            shutil.rmtree(tmp, ignore_errors=True)


def _store_degrees(store: BlockStore) -> np.ndarray:
    """(n_pad,) mask-weighted degrees via one streaming pass over blocks."""
    deg = np.zeros(store.n_pad, np.int32)
    for b in range(store.n_blocks):
        raw_src, _raw_dst, raw_mask = store.block_raw(b)
        if raw_src.shape[0]:
            deg[b * store.V:(b + 1) * store.V] += np.bincount(
                np.asarray(raw_src)[np.asarray(raw_mask)],
                minlength=store.V).astype(np.int32)
    return deg


def _decompose_store(store: BlockStore, *, deg: np.ndarray | None,
                     mem_budget: int | None,
                     max_rounds: int | None) -> OutOfCoreResult:
    compiles0, csecs0 = compile_count(), compile_seconds()
    n, V, n_blocks = store.n, store.V, store.n_blocks
    n_pad = store.n_pad
    if n == 0:
        zero = MessageStats(*(np.zeros(0, np.int64),) * 3)
        return OutOfCoreResult(core=np.zeros(0, np.int32), rounds=0,
                               converged=True, stats=zero)

    # dense host vertex state — the out-of-core tier's only O(n) arrays
    if deg is None:
        deg_pad = _store_degrees(store)
    else:
        deg_pad = np.zeros(n_pad, np.int32)
        deg_pad[:n] = np.asarray(deg, np.int32)
    deg64 = deg_pad[:n].astype(np.int64)
    est = deg_pad.copy()
    # round-1 frontier: vertices that received the degree broadcast. Using
    # it as the compute mask too is exact (deg-0 vertices hold est 0, a
    # fixpoint) and lets round 1 already skip all-isolated blocks.
    active_mask = np.zeros(n_pad, bool)
    active_mask[:n] = deg_pad[:n] > 0
    n_iters = _bs_iters(int(deg_pad.max()) if n_pad else 0)
    cap = max_rounds if max_rounds is not None else n + 1

    msgs = [int(deg64.sum())]  # round 0: degree broadcast = 2m
    active = [n, int((deg64 > 0).sum())]
    changed_counts = [n]

    cache = BlockCache(store, budget_bytes=mem_budget)
    skipped = block_rounds = 0
    rounds, converged = 0, False
    # per-block device geometry: each block ships only its pow2 LENGTH
    # BUCKET of arc slots (tail padding beyond its real run is dropped),
    # so the straggler block's A doesn't inflate every superstep. row_off
    # is the block's CSR row index over those slots (cached; O(n_pad) ints
    # total — vertex-tier host state).
    a_eff = {b: _bucket(int(store.arcs_per_block[b]), store.A)
             for b in range(n_blocks)}
    row_offs: dict[int, np.ndarray] = {}
    dev_bytes_peak = 0

    rec = _flight.recorder()
    if rec.active:
        rec.start_run("static", "out_of_core", n=n)
        rec.record_round(active[0], msgs[0], changed_counts[0],
                         est=deg_pad[:n])

    with _trace.span("kcore.decompose", n=n, m=int(deg64.sum()) // 2,
                     mode="out_of_core", n_blocks=n_blocks,
                     mem_budget=mem_budget or 0) as _sp:
        t_conv = time.perf_counter()
        while rounds < cap:
            t_r = time.perf_counter() if rec.active else 0.0
            with _trace.span("kcore.round", round=rounds) as rsp:
                est_prev = est.copy()
                changed = np.zeros(n_pad, bool)
                recv = np.zeros(n_pad, bool)
                blocks_hit = 0
                for b in range(n_blocks):
                    lo = b * V
                    if not active_mask[lo:lo + V].any():
                        skipped += 1
                        continue
                    blocks_hit += 1
                    block_rounds += 1
                    blk = cache.get(b)
                    ae = a_eff[b]
                    dev_bytes_peak = max(dev_bytes_peak,
                                         ae * ARC_SLOT_BYTES)
                    src_e, dst_e = blk.src[:ae], blk.dst[:ae]
                    mask_e = blk.mask[:ae]
                    if b not in row_offs:
                        row_offs[b] = np.minimum(
                            np.searchsorted(src_e, np.arange(V + 1)),
                            ae).astype(np.int32)
                    # halo: this block's neighbor estimates, gathered from
                    # the ROUND-START vector (synchronous Jacobi — the
                    # bit-equality contract with every in-memory mode)
                    halo = np.where(mask_e, est_prev[dst_e], 0)
                    new_u, ch_u = _block_superstep(
                        jnp.asarray(est_prev[lo:lo + V]),
                        jnp.asarray(halo.astype(np.int32)),
                        jnp.asarray(src_e),
                        jnp.asarray(row_offs[b]),
                        jnp.asarray(active_mask[lo:lo + V]),
                        n_iters=n_iters)
                    ch_u = np.asarray(ch_u)
                    est[lo:lo + V] = np.asarray(new_u)
                    changed[lo:lo + V] = ch_u
                    # receiver scatter: arcs whose (local) src changed mark
                    # their dst — equals the pull-side segment_sum because
                    # the arc set is symmetric
                    sel = mask_e & ch_u[src_e]
                    if sel.any():
                        recv[dst_e[sel]] = True
                rounds += 1
                if not changed.any():
                    converged = True
                    rsp.set(blocks=blocks_hit, converged=True)
                    break
                msgs.append(int(deg64[changed[:n]].sum()))
                changed_counts.append(int(changed.sum()))
                active.append(int(recv.sum()))
                rsp.set(messages=msgs[-1], changed=changed_counts[-1],
                        blocks=blocks_hit)
                if rec.active:
                    rec.record_round(
                        active[rounds], msgs[-1], changed_counts[-1],
                        est=est[:n], prev_est=est_prev[:n],
                        host_s=time.perf_counter() - t_r)
                active_mask = recv
        wall = time.perf_counter() - t_conv
        _sp.set(rounds=rounds, converged=converged,
                blocks_loaded=cache.loads, blocks_skipped=skipped,
                evictions=cache.evictions)

    stats = MessageStats(
        messages_per_round=np.asarray(msgs, np.int64),
        active_per_round=np.asarray(active[: len(msgs)], np.int64),
        changed_per_round=np.asarray(changed_counts[: len(msgs)], np.int64),
    )
    block_stats = OutOfCoreStats(
        n_blocks=n_blocks, V=V, A=store.A, rounds=rounds,
        blocks_loaded=cache.loads, blocks_skipped=skipped,
        block_rounds=block_rounds, cache_hits=cache.hits,
        evictions=cache.evictions, cache_peak_bytes=cache.peak_bytes,
        mem_budget=mem_budget,
        device_block_bytes=dev_bytes_peak or store.block_arc_bytes,
        total_arc_bytes=store.total_arc_bytes,
        imbalance=store.balance()["imbalance"],
        peak_rss_bytes=peak_rss_bytes(),
        ms_per_round=1e3 * wall / max(rounds, 1),
    )
    _publish_metrics(block_stats)
    if rec.active:
        rec.end_run(converged=converged, messages=int(stats.total_messages),
                    blocks_loaded=block_stats.blocks_loaded,
                    blocks_skipped=block_stats.blocks_skipped,
                    device_block_bytes=block_stats.device_block_bytes,
                    peak_rss_bytes=block_stats.peak_rss_bytes)
    return OutOfCoreResult(
        core=est[:n].astype(np.int32), rounds=rounds, converged=converged,
        stats=stats, recompiles=compile_count() - compiles0,
        compile_s=compile_seconds() - csecs0,
        phase_s={"converge": wall}, block_stats=block_stats)
