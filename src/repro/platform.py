"""Computation-platform configuration layer.

One place (in the spirit of bayespec's ``elisa.util.config``) that decides
WHERE the supersteps run and HOW the Pallas kernels are dispatched, driven
by a flag or an environment variable — so the same entry points cover a
laptop CPU, a forced-multi-device CI lane, and a real TPU/GPU runner:

* ``set_platform("cpu"|"gpu"|"tpu")`` — pick the jax platform (and set the
  recommended XLA perf flags on GPU).
* ``force_host_device_count(n)`` — expose ``n`` host (CPU) devices via
  ``--xla_force_host_platform_device_count``, turning a single machine into
  an in-process mesh for the sharded/fused-sharded paths. Must run before
  jax initializes its backends.
* ``configure_from_env()`` — apply both from ``REPRO_PLATFORM`` /
  ``REPRO_HOST_DEVICES`` (+ ``REPRO_X64``); idempotent and cheap, called by
  the CLIs and ``tests/conftest.py`` so one exported variable reconfigures
  every entry point.
* ``dispatch_mode()`` — the Pallas kernel-dispatch switch (``REPRO_PALLAS``
  = ``auto`` | ``on``/``pallas`` | ``off``/``xla``) consumed by
  ``repro.core.dispatch``: ``auto`` routes the superstep h-index /
  segment-sum to the Pallas kernels only where they compile natively (TPU),
  ``on`` forces them everywhere (interpret mode off-TPU — exact, slow;
  the parity/CI path), ``off`` keeps the plain XLA segment ops.
* ``peaks()`` — per-backend peak FLOP/s and bytes/s for roofline reporting
  (``REPRO_PEAK_GFLOPS`` / ``REPRO_PEAK_GBS`` override).

Everything here touches only ``os.environ`` and ``jax.config`` — importing
this module never initializes a jax backend, so it is always safe to import
first and configure before the rest of the process touches a device.
"""

from __future__ import annotations

import os
import warnings

ENV_PLATFORM = "REPRO_PLATFORM"
ENV_HOST_DEVICES = "REPRO_HOST_DEVICES"
ENV_DISPATCH = "REPRO_PALLAS"
ENV_X64 = "REPRO_X64"
ENV_PEAK_GFLOPS = "REPRO_PEAK_GFLOPS"
ENV_PEAK_GBS = "REPRO_PEAK_GBS"

_PLATFORMS = ("cpu", "gpu", "tpu")

# jax GPU performance-tips flags (safe no-ops elsewhere; only set when the
# gpu platform is selected, mirroring SNIPPETS.md snippet 1)
_GPU_XLA_FLAGS = (
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true"
)

_FORCE_DEVICES_FLAG = "--xla_force_host_platform_device_count"

# per-backend (peak FLOP/s, peak bytes/s): TPU numbers match
# repro.launch.hlo_analysis (v5e-class); GPU ~A100-class; CPU a deliberately
# round server-core estimate — override via REPRO_PEAK_GFLOPS/REPRO_PEAK_GBS
# when calibrating a specific machine. Roofline REPORTING only, never used
# for correctness or dispatch decisions.
_PEAKS = {
    "tpu": (197e12, 819e9),
    "gpu": (312e12, 2.0e12),
    "cpu": (200e9, 50e9),
}

_DISPATCH_MODES = ("auto", "pallas", "xla")
_dispatch_override: str | None = None


# ---------------------------------------------------------------------- #
# Platform / device-count selection
# ---------------------------------------------------------------------- #


def set_platform(platform: str) -> None:
    """Select the jax platform (cpu/gpu/tpu). Call before backend init."""
    if platform not in _PLATFORMS:
        raise ValueError(f"platform must be one of {_PLATFORMS}, got {platform!r}")
    import jax

    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_gpu" not in flags:
            os.environ["XLA_FLAGS"] = f"{flags} {_GPU_XLA_FLAGS}".strip()


def force_host_device_count(n: int) -> None:
    """Expose ``n`` host (CPU) devices to jax — the forced-multi-device lane.

    Rewrites any existing ``--xla_force_host_platform_device_count`` in
    ``XLA_FLAGS`` instead of appending a duplicate, so repeated calls (or a
    CLI flag on top of an exported variable) keep a single source of truth.
    The flag is read when jax initializes its backends; calling this after
    devices exist has no effect on the live process (jax caches backends),
    so configure first — the CLIs and conftest do.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    flags = os.environ.get("XLA_FLAGS", "").split()
    parts = [p for p in flags if not p.startswith(_FORCE_DEVICES_FLAG)]
    parts.append(f"{_FORCE_DEVICES_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(parts)
    if _backends_initialized():
        warnings.warn(
            "force_host_device_count called after jax backends initialized; "
            "the new count only affects fresh processes",
            RuntimeWarning,
            stacklevel=2,
        )


def _backends_initialized() -> bool:
    """Best-effort: has this process already materialized jax devices?"""
    import sys

    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        from jax._src import xla_bridge

        return bool(xla_bridge._backends)
    except Exception:  # jax version drift — assume not initialized
        return False


def configure_from_env() -> dict:
    """Apply ``REPRO_PLATFORM`` / ``REPRO_HOST_DEVICES`` / ``REPRO_X64``.

    Returns the subset of settings that were applied (empty when no
    variable is set). Safe to call repeatedly and from conftest — it only
    mutates ``os.environ`` / ``jax.config``, never initializes a backend.
    """
    applied: dict = {}
    platform = os.environ.get(ENV_PLATFORM, "").strip().lower()
    if platform:
        set_platform(platform)
        applied["platform"] = platform
    ndev = os.environ.get(ENV_HOST_DEVICES, "").strip()
    if ndev:
        force_host_device_count(int(ndev))
        applied["host_devices"] = int(ndev)
    x64 = os.environ.get(ENV_X64, "").strip().lower()
    if x64:
        import jax

        jax.config.update("jax_enable_x64", x64 in ("1", "true", "yes", "on"))
        applied["x64"] = x64 in ("1", "true", "yes", "on")
    return applied


# ---------------------------------------------------------------------- #
# Pallas kernel dispatch mode
# ---------------------------------------------------------------------- #


def normalize_dispatch(mode: str) -> str:
    """Map accepted spellings to the canonical auto/pallas/xla vocabulary."""
    m = mode.strip().lower()
    aliases = {
        "on": "pallas",
        "1": "pallas",
        "true": "pallas",
        "off": "xla",
        "0": "xla",
        "false": "xla",
    }
    m = aliases.get(m, m)
    if m not in _DISPATCH_MODES:
        warnings.warn(
            f"unknown dispatch mode {mode!r} (want auto/on/off); using auto",
            RuntimeWarning,
            stacklevel=2,
        )
        return "auto"
    return m


def dispatch_mode() -> str:
    """Current kernel-dispatch mode: auto | pallas | xla.

    Priority: ``set_dispatch_mode()`` override (CLI flags), then the
    ``REPRO_PALLAS`` environment variable, then ``auto``.
    """
    if _dispatch_override is not None:
        return _dispatch_override
    return normalize_dispatch(os.environ.get(ENV_DISPATCH, "auto"))


def set_dispatch_mode(mode: str | None) -> None:
    """Process-wide dispatch override (None restores env/auto behavior)."""
    global _dispatch_override
    _dispatch_override = None if mode is None else normalize_dispatch(mode)


def interpret_kernels() -> bool:
    """Should Pallas kernels run in interpret mode? (anywhere but real TPU)"""
    import jax

    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------- #
# Roofline peaks / summary
# ---------------------------------------------------------------------- #


def peaks(backend: str | None = None) -> tuple[float, float]:
    """(peak FLOP/s, peak bytes/s) for ``backend`` (default: the active one),
    with ``REPRO_PEAK_GFLOPS`` / ``REPRO_PEAK_GBS`` overrides."""
    if backend is None:
        import jax

        backend = jax.default_backend()
    flops, membw = _PEAKS.get(backend, _PEAKS["cpu"])
    gflops = os.environ.get(ENV_PEAK_GFLOPS, "").strip()
    gbs = os.environ.get(ENV_PEAK_GBS, "").strip()
    if gflops:
        flops = float(gflops) * 1e9
    if gbs:
        membw = float(gbs) * 1e9
    return flops, membw


def summary() -> dict:
    """The resolved platform state (for CLI reports; initializes backends)."""
    import jax

    flops, membw = peaks()
    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "dispatch_mode": dispatch_mode(),
        "interpret_kernels": interpret_kernels(),
        "peak_gflops": round(flops / 1e9, 1),
        "peak_gbs": round(membw / 1e9, 1),
    }
