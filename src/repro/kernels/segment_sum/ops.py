"""Layout builder + jit'd wrapper for the blocked segment-sum kernel."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import platform as _platform


@dataclasses.dataclass(frozen=True)
class BlockedLayout:
    """Host-precomputed edge order/padding such that every ``be``-edge block
    touches one ``R``-row output block (see kernel.py)."""

    order: np.ndarray  # (E,) permutation into the padded stream slots
    e_pad: int
    rows_local: np.ndarray  # (E_pad,) int32; padding slots point at row 0
    pad_mask: np.ndarray  # (E_pad,) bool — True for real edges
    block_row: np.ndarray  # (n_blocks,) int32
    R: int
    be: int
    n_rows_pad: int


def blocked_layout(
    seg_ids: np.ndarray, n_rows: int, *, R: int = 256, be: int = 512
) -> BlockedLayout:
    seg_ids = np.asarray(seg_ids)
    order = np.argsort(seg_ids, kind="stable")
    seg_sorted = seg_ids[order]
    n_rb = max((n_rows + R - 1) // R, 1)
    # edges per row block
    rb_of_edge = seg_sorted // R
    counts = np.bincount(rb_of_edge, minlength=n_rb)
    # >= 1 block per row block even when it has no edges: the kernel
    # zero-initializes an output block on first visit, so every block must
    # be visited (found by hypothesis: E=1, n=17 left rows 16.. garbage).
    blocks_per_rb = np.maximum((counts + be - 1) // be, 1)
    # allocate padded slots per row block
    slot_starts = np.concatenate([[0], np.cumsum(blocks_per_rb * be)[:-1]])
    e_starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = slot_starts[rb_of_edge] + (np.arange(len(seg_sorted)) - e_starts[rb_of_edge])
    e_pad = int((blocks_per_rb * be).sum()) or be
    rows_local = np.zeros(e_pad, np.int32)
    pad_mask = np.zeros(e_pad, bool)
    rows_local[slot] = (seg_sorted % R).astype(np.int32)
    pad_mask[slot] = True
    block_row = np.repeat(np.arange(n_rb), blocks_per_rb).astype(np.int32)
    if block_row.size == 0:
        block_row = np.zeros(1, np.int32)
    perm = np.zeros(e_pad, np.int64)
    perm[slot] = order
    return BlockedLayout(
        order=perm,
        e_pad=e_pad,
        rows_local=rows_local,
        pad_mask=pad_mask,
        block_row=block_row,
        R=R,
        be=be,
        n_rows_pad=n_rb * R,
    )


@functools.partial(jax.jit, static_argnames=("R", "n_blocks_out", "n_rows"))
def _run(vals_padded, rows_local, block_row, R, n_blocks_out, n_rows):
    # deferred Pallas import: blocked_layout stays usable (and this module
    # importable) on jax builds without Pallas
    from repro.kernels.segment_sum.kernel import segment_sum_pallas

    out = segment_sum_pallas(
        vals_padded,
        rows_local[:, None],
        block_row,
        n_blocks_out,
        R=R,
        interpret=_platform.interpret_kernels(),
    )
    return out[:n_rows]


def segment_sum_blocked(vals, seg_ids_layout: BlockedLayout, n_rows: int):
    """vals: (E, F) in ORIGINAL edge order. Returns (n_rows, F)."""
    lo = seg_ids_layout
    vals = jnp.asarray(vals)
    if vals.ndim == 1:
        vals = vals[:, None]
    vp = jnp.zeros((lo.e_pad, vals.shape[1]), vals.dtype)
    slots = jnp.asarray(lo.pad_mask).nonzero(size=int(lo.pad_mask.sum()))[0]
    vp = vp.at[slots].set(vals[jnp.asarray(lo.order[lo.pad_mask])])
    return _run(
        vp,
        jnp.asarray(lo.rows_local),
        jnp.asarray(lo.block_row),
        lo.R,
        lo.n_rows_pad // lo.R,
        n_rows,
    )
