"""Pallas TPU segment-sum over row-block-grouped sorted COO.

The scatter in ``jax.ops.segment_sum`` is the message-aggregation hot spot of
both the k-core engine and every assigned GNN. TPUs have no efficient
scatter; the TPU-native formulation is a ONE-HOT MATMUL per edge block
(rows_local one-hot (be, R) x values (be, F) on the MXU) accumulated into a
VMEM-resident output row block.

Layout contract (built by ops.blocked_layout): edges are sorted by segment
and PADDED so each edge block of ``be`` edges touches exactly one output row
block of ``R`` rows; ``block_row[i]`` (scalar-prefetched — the out BlockSpec
index map reads it) names that row block. Sorted edges mean each out block
is visited by consecutive grid steps, so the accumulate-in-VMEM pattern is
safe on TPU's sequential grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _seg_kernel(block_row_ref, vals_ref, rows_ref, out_ref, *, R: int):
    i = pl.program_id(0)
    first = jnp.logical_or(i == 0, block_row_ref[jnp.maximum(i - 1, 0)] != block_row_ref[i])

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...]  # (be, F)
    rows = rows_ref[...]  # (be, 1) local row in [0, R)
    iota = jax.lax.broadcasted_iota(jnp.int32, (rows.shape[0], R), 1)
    onehot = (rows == iota).astype(vals.dtype)
    # (R, be) x (be, F) on the MXU
    out_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())), preferred_element_type=out_ref.dtype
    )


def segment_sum_pallas(vals, rows_local, block_row, n_blocks_out: int, *, R: int, interpret: bool):
    """vals: (E_pad, F); rows_local: (E_pad, 1) int32 row-within-block;
    block_row: (n_edge_blocks,) int32 out-block id per edge block.
    Returns (n_blocks_out * R, F)."""
    E, F = vals.shape
    be = E // block_row.shape[0]
    grid = (block_row.shape[0],)
    return pl.pallas_call(
        functools.partial(_seg_kernel, R=R),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((be, F), lambda i, br: (i, 0)),
                pl.BlockSpec((be, 1), lambda i, br: (i, 0)),
            ],
            out_specs=pl.BlockSpec((R, F), lambda i, br: (br[i], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_blocks_out * R, F), vals.dtype),
        interpret=interpret,
    )(block_row, vals, rows_local)
