"""Pure-jnp oracle: jax.ops.segment_sum."""

from __future__ import annotations

import jax


def segment_sum_ref(vals, seg_ids, n: int):
    return jax.ops.segment_sum(vals, seg_ids, num_segments=n)
