from repro.kernels.segment_sum.ops import (
    blocked_layout,
    segment_sum_blocked,
)

__all__ = ["blocked_layout", "segment_sum_blocked"]
