"""segment_sum kernel package — attribute access defers the Pallas import
(repro.core must stay importable on jax builds without Pallas)."""

__all__ = ["blocked_layout", "segment_sum_blocked"]


def __getattr__(name):
    if name in __all__:
        from repro.kernels.segment_sum import ops

        return getattr(ops, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
