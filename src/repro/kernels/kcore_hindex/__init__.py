from repro.kernels.kcore_hindex.ops import hindex_rows

__all__ = ["hindex_rows"]
