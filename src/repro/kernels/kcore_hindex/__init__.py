"""kcore_hindex kernel package — attribute access defers the Pallas import
(repro.core must stay importable on jax builds without Pallas)."""

__all__ = ["hindex_rows"]


def __getattr__(name):
    if name in __all__:
        from repro.kernels.kcore_hindex import ops

        return getattr(ops, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
