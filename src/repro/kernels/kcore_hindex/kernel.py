"""Pallas TPU kernel: rowwise clipped h-index — the k-core round hot spot.

Input is the degree-bucketed ELL tile (rows × width neighbor-estimate
window, already gathered; sentinel slots hold 0) plus each row's current
estimate. Output is the new estimate

    h(u) = max k in [0, est_u] s.t. |{j : min(vals[u,j], est_u) >= k}| >= k.

TPU mapping: the whole (TR, W) tile lives in VMEM; the h-index is computed by
a branch-free vectorized binary search — each probe is one VPU compare +
row-reduction, ``n_iters = ceil(log2(maxdeg+1))+1`` probes. No sort, no
scatter, no data-dependent control flow: this is the paper's per-vertex
``updateCore`` procedure reshaped into rectangular SIMD work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _hindex_kernel(nbr_ref, estu_ref, out_ref, *, n_iters: int):
    vals = nbr_ref[...]  # (TR, W) int32
    est_u = estu_ref[...]  # (TR, 1) int32
    vals = jnp.minimum(vals, est_u)  # clip at own estimate

    lo = jnp.zeros_like(est_u)
    hi = est_u

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2  # probe k (>= 1 when hi > lo)
        k = jnp.maximum(mid, 1)
        cnt = jnp.sum((vals >= k).astype(jnp.int32), axis=1, keepdims=True)
        ok = cnt >= mid
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo, _ = lax.fori_loop(0, n_iters, body, (lo, hi))
    out_ref[...] = lo


def hindex_rows_pallas(nbr_est, est_u2d, *, n_iters: int, row_tile: int, interpret: bool):
    """nbr_est: (R, W) int32 (R % row_tile == 0), est_u2d: (R, 1) int32."""
    rows, width = nbr_est.shape
    grid = (rows // row_tile,)
    return pl.pallas_call(
        functools.partial(_hindex_kernel, n_iters=n_iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, width), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        interpret=interpret,
    )(nbr_est, est_u2d)
