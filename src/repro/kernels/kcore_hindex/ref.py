"""Pure-jnp oracle for the kcore_hindex kernel.

Deliberately uses a DIFFERENT algorithm than the kernel (sort-based h-index
identity instead of binary search) so the two cross-validate:

    h(values) = max_i min(sorted_desc[i], i+1)        (1-based i)
"""

from __future__ import annotations

import jax.numpy as jnp


def hindex_rows_ref(nbr_est, est_u, n_iters: int = 0):
    """nbr_est: (R, W) int32 (sentinel slots 0), est_u: (R,) int32 → (R,)."""
    vals = jnp.minimum(nbr_est, est_u[:, None])
    s = -jnp.sort(-vals, axis=1)  # descending
    ranks = jnp.arange(1, s.shape[1] + 1, dtype=s.dtype)
    return jnp.max(jnp.minimum(s, ranks[None, :]), axis=1)
