"""Jit'd wrapper for the kcore_hindex Pallas kernel.

Handles row padding to the tile multiple, 2-D reshape of the estimate
column, VMEM-aware row-tile selection, and interpret-mode fallback off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import platform as _platform

_VMEM_BUDGET_BYTES = 4 * 1024 * 1024  # per-block neighbor tile budget


def _pick_row_tile(width: int) -> int:
    rows = _VMEM_BUDGET_BYTES // max(width * 4, 1)
    rows = max(8, min(256, rows))
    return 1 << (rows.bit_length() - 1)  # round down to power of two


@functools.partial(jax.jit, static_argnames=("n_iters",))
def hindex_rows(nbr_est, est_u, n_iters: int):
    """Rowwise clipped h-index. nbr_est (R, W) int32, est_u (R,) int32 → (R,).

    Drop-in replacement for core.kcore.hindex_rows_ref. The Pallas kernel
    import is deferred to trace time so importing this module stays safe on
    jax builds without Pallas.
    """
    from repro.kernels.kcore_hindex.kernel import hindex_rows_pallas

    rows, width = nbr_est.shape
    tile = _pick_row_tile(width)
    pad = (-rows) % tile
    if pad:
        nbr_est = jnp.pad(nbr_est, ((0, pad), (0, 0)))
        est_u = jnp.pad(est_u, (0, pad))
    out = hindex_rows_pallas(
        nbr_est,
        est_u[:, None],
        n_iters=n_iters,
        row_tile=tile,
        interpret=_platform.interpret_kernels(),
    )
    return out[:rows, 0]
