"""Pallas TPU flash attention (blockwise online softmax).

Grid (B*H, n_q_blocks, n_kv_blocks) with the kv dimension innermost — TPU
grids execute sequentially per core, so the VMEM scratch accumulators
(running max m, denominator l, output acc) persist across kv blocks of one
(bh, q) cell and are initialized/finalized with @pl.when.

Supports causal masking and sliding windows via absolute positions; GQA is
handled by the ops.py wrapper (kv head broadcast happens in the BlockSpec
index map — no materialized repeat).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    bq: int,
    bk: int,
    n_kv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s * scale  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # (bq, bk)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q, k, v, *, causal: bool, window: int | None, bq: int, bk: int, interpret: bool
):
    """q: (BH, Sq, d), k/v: (BH, Sk, d) — heads pre-flattened; kv may have
    fewer BH rows than q (GQA): index map folds q-head -> kv-head."""
    BHq, Sq, d = q.shape
    BHk, Sk, _ = k.shape
    rep = BHq // BHk
    n_q, n_kv = Sq // bq, Sk // bk
    scale = 1.0 / (d**0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk, n_kv=n_kv
    )
    return pl.pallas_call(
        kernel,
        grid=(BHq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BHq, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
