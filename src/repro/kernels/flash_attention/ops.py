"""Jit'd wrapper: shape plumbing (B,H grouping, GQA), block-size selection,
padding, interpret fallback off-TPU."""

from __future__ import annotations

import functools

import jax

from repro import platform as _platform


def _pick_blocks(Sq: int, Sk: int, d: int) -> tuple[int, int]:
    bq = min(512, Sq)
    while Sq % bq:
        bq //= 2
    bk = min(512, Sk)
    while Sk % bk:
        bk //= 2
    return max(bq, 1), max(bk, 1)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None):
    """q: (B, Sq, Hq, d), k/v: (B, Sk, Hkv, d) -> (B, Sq, Hq, d).

    Drop-in for the XLA chunked path in models/transformer (same masking
    semantics: causal + optional sliding window over absolute positions).
    """
    from repro.kernels.flash_attention.kernel import flash_attention_pallas

    B, Sq, Hq, d = q.shape
    _, Sk, Hkv, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, d)
    bq, bk = _pick_blocks(Sq, Sk, d)
    out = flash_attention_pallas(
        qf,
        kf,
        vf,
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        interpret=_platform.interpret_kernels(),
    )
    return out.reshape(B, Hq, Sq, d).transpose(0, 2, 1, 3)
