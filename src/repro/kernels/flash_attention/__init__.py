"""flash_attention kernel package — attribute access defers the Pallas import."""

__all__ = ["flash_attention"]


def __getattr__(name):
    if name in __all__:
        from repro.kernels.flash_attention import ops

        return getattr(ops, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
