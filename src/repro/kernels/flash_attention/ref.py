"""Pure-jnp oracle for flash attention (materialized softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q: (BH, Sq, d), k/v: (BHk, Sk, d) with BH % BHk == 0 (GQA)."""
    BHq, Sq, d = q.shape
    BHk, Sk, _ = k.shape
    rep = BHq // BHk
    if rep > 1:
        k = jnp.repeat(k, rep, axis=0)
        v = jnp.repeat(v, rep, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) / (d**0.5)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
