"""Pallas TPU fused embedding-bag: gather + in-register reduce.

DIN's hot path (kernel_taxonomy §RecSys): (B, L) item-id bags against a
(V, D) table. The XLA path materializes the (B, L, D) gathered tensor in
HBM before reducing; this kernel keeps the accumulator for one bag tile in
VMEM and DMA-gathers one row at a time from the HBM-resident table (the
indices are scalar-prefetched so the gather addresses are known to the DMA
engine ahead of the loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, table_ref, out_ref, *, L: int, bb: int):
    i = pl.program_id(0)
    acc = jnp.zeros(out_ref.shape, jnp.float32)  # (bb, D)

    def body(j, acc):
        def row(b, acc):
            ix = idx_ref[i * bb + b, j]
            valid = ix >= 0
            r = pl.load(table_ref, (pl.dslice(jnp.maximum(ix, 0), 1), slice(None)))  # (1, D)
            return acc.at[b].add(jnp.where(valid, r[0], 0.0).astype(jnp.float32))

        return jax.lax.fori_loop(0, bb, row, acc)

    acc = jax.lax.fori_loop(0, L, body, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


def embedding_bag_pallas(table, indices, *, bb: int, interpret: bool):
    """table: (V, D); indices: (B, L) int32 (−1 = padding) -> (B, D) sums."""
    V, D = table.shape
    B, L = indices.shape
    grid = (B // bb,)
    return pl.pallas_call(
        functools.partial(_bag_kernel, L=L, bb=bb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((bb, D), lambda i, idx: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(indices, table)
