"""embedding_bag kernel package — attribute access defers the Pallas import."""

__all__ = ["embedding_bag_fused"]


def __getattr__(name):
    if name in __all__:
        from repro.kernels.embedding_bag import ops

        return getattr(ops, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
