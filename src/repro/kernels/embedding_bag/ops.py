"""Jit'd wrapper for the fused embedding-bag kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import platform as _platform


@functools.partial(jax.jit, static_argnames=())
def embedding_bag_fused(table, indices):
    """table (V, D), indices (B, L) int32 (−1 pad) -> (B, D) sum-bags."""
    from repro.kernels.embedding_bag.kernel import embedding_bag_pallas

    B, L = indices.shape
    bb = 8
    pad = (-B) % bb
    if pad:
        indices = jnp.pad(indices, ((0, pad), (0, 0)), constant_values=-1)
    out = embedding_bag_pallas(table, indices, bb=bb, interpret=_platform.interpret_kernels())
    return out[:B]
