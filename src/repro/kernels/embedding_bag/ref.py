"""Oracle: the XLA take + masked-sum path from models/recsys."""

from repro.models.recsys.embedding_bag import embedding_bag


def embedding_bag_ref(table, indices):
    return embedding_bag(table, indices, mode="sum")
