"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step) — the property fault-tolerant
restarts and elastic rescaling rely on: a resumed run consumes byte-identical
batches without any data-service coordination. Token streams are Zipf-ish
(power-law unigram) with induced bigram structure so the LM loss actually
decreases during the e2e example runs.
"""

from __future__ import annotations

import numpy as np


def synth_lm_batch(vocab: int, batch: int, seq_len: int, *, seed: int,
                   step: int) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # power-law unigrams + deterministic "grammar": x_{t+1} depends on x_t
    base = rng.zipf(1.5, size=(batch, seq_len)).clip(max=vocab // 2)
    shift = (np.arange(seq_len) % 7)[None, :]
    tokens = ((base + shift * 31) % vocab).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    return tokens, labels


def lm_batch_stream(vocab: int, batch: int, seq_len: int, *, seed: int = 0,
                    start_step: int = 0):
    step = start_step
    while True:
        yield synth_lm_batch(vocab, batch, seq_len, seed=seed, step=step)
        step += 1
