from repro.data.pipeline import lm_batch_stream, synth_lm_batch

__all__ = ["lm_batch_stream", "synth_lm_batch"]
