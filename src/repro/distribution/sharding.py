"""Sharding rules: how each model family maps onto the production mesh.

Mesh contract (launch/mesh.py): axes ("data", "model") single pod,
("pod", "data", "model") multi-pod. "pod" composes with "data" as the outer
data-parallel axis (hierarchical gradient all-reduce); FSDP parameter
sharding uses the "data" axis; tensor/expert parallelism uses "model".

The model code is mesh-agnostic: it calls ``constrain(x, rules.<key>)``,
which no-ops when rules is None (single-device smoke tests).
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Activation NamedShardings for the LM stack (None entries = no-op)."""
    data_axes: tuple          # logical data-parallel axes, e.g. ("pod","data")
    model_axis: str | None    # tensor-parallel axis
    # activations (NamedSharding each)
    tokens: object            # (B, S)
    residual: object          # (B, S, d) — sequence-parallel between blocks
    residual_decode: object   # (B, 1, d)
    attn_q: object            # (B, S, HQ, Dh) — flat-head layout
    kv_cache: object          # (B, Hkv, T, Dh)
    moe_x: object             # (B, S, d) pre-dispatch
    moe_dispatch: object      # (B, S, E, C)
    moe_buf: object           # (B, E, C, d)
    moe_hidden: object        # (B, E, C, f)
    logits_chunk: object      # (B, chunk, V)
    ffn_hidden: object        # (B, S, f)


def lm_rules(mesh: jax.sharding.Mesh, cfg) -> ShardingRules:
    axes = mesh.axis_names
    model = "model" if "model" in axes else None
    data = tuple(a for a in axes if a != "model")
    dp = data if len(data) > 1 else (data[0] if data else None)
    # Train/prefill attention runs in the flat-head layout (B, S, HQ, Dh)
    # with KV expanded to HQ (model.py), so the head dim shards over
    # "model" for every assigned arch (HQ in {16, 48, 56} vs 16: GSPMD pads
    # 56 -> 64, a 1.14x waste; kv-head counts like 8 or 1 would force
    # involuntary replication instead).
    model_size = mesh.shape[model] if model else 1
    attn_q = P(dp, None, model, None)
    # Decode cache keeps the grouped (B, Hkv, T, Dh) layout: shard kv heads
    # when they divide the model axis (pjit input shardings require exact
    # divisibility), else shard the TIME dim (GQA-8/MQA: the distributed
    # softmax gather is cheap at decode).
    shard_kv_heads = model_size > 0 and cfg.n_kv_heads % model_size == 0
    kv_cache = P(dp, model, None, None) if shard_kv_heads else \
        P(dp, None, model, None)

    def named(spec):
        # NamedSharding (not bare PartitionSpec): with_sharding_constraint
        # must not depend on an ambient `with mesh:` context.
        return NamedSharding(mesh, spec)

    return ShardingRules(
        data_axes=data, model_axis=model,
        tokens=named(P(dp, None)),
        residual=named(P(dp, model, None)),   # sequence parallelism
        residual_decode=named(P(dp, None, None)),
        attn_q=named(attn_q),
        kv_cache=named(kv_cache),
        moe_x=named(P(dp, None, None)),                 # pre-dispatch tokens
        moe_dispatch=named(P(dp, None, model, None)),   # (B, S, E, C)
        moe_buf=named(P(dp, model, None, None)),        # (B, E, C, d) — EP
        moe_hidden=named(P(dp, model, None, None)),     # (B, E, C, f)
        logits_chunk=named(P(dp, None, model)),
        ffn_hidden=named(P(dp, None, model)),
    )


def replicated_rules() -> None:
    """Smoke-test rules: no constraints."""
    return None


def constrain(x, sharding):
    """with_sharding_constraint; None = no-op (single-device smoke path).

    Deliberately NO exception swallowing: a failing constraint is a bug in
    the sharding rules and must surface in the dry-run."""
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


# ---------------------------------------------------------------------- #
# Parameter specs (FSDP over "data", TP over "model")
# ---------------------------------------------------------------------- #

def lm_param_specs(cfg) -> dict:
    """PartitionSpec tree matching models/transformer param structure.

    Layout: stacked layers lead with L (never sharded); TP shards the
    head/ff output dim over "model"; FSDP shards the d_model input dim over
    "data". Embedding: vocab over "model", d over "data".
    """
    attn = {
        "wq": P(None, "data", "model"),
        "wk": P(None, "data", "model"),
        "wv": P(None, "data", "model"),
        "wo": P(None, "model", "data"),
    }
    if cfg.qkv_bias:
        attn.update({"bq": P(None, "model"), "bk": P(None, "model"),
                     "bv": P(None, "model")})
    layers: dict = {
        "attn": attn,
        "norm1": P(None, None),
        "norm2": P(None, None),
    }
    if cfg.moe:
        # Expert parallelism: E over "model"; FSDP: d over "data".
        moe = {
            "router": P(None, "data", None),
            "w_up": P(None, "model", "data", None),
            "w_down": P(None, "model", None, "data"),
        }
        if cfg.mlp_type == "swiglu":
            moe["w_gate"] = P(None, "model", "data", None)
        if cfg.moe.n_shared:
            moe["shared"] = _mlp_specs(cfg, stacked=True)
        layers["moe"] = moe
    else:
        layers["mlp"] = _mlp_specs(cfg, stacked=True)
    specs = {
        "embed": P("model", "data"),
        "layers": layers,
        "norm_f": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P("model", "data")
    return specs


def _mlp_specs(cfg, stacked: bool) -> dict:
    lead = (None,) if stacked else ()
    d = {
        "w_up": P(*lead, "data", "model"),
        "w_down": P(*lead, "model", "data"),
    }
    if cfg.mlp_type == "swiglu":
        d["w_gate"] = P(*lead, "data", "model")
    return d
