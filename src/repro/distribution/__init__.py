from repro.distribution.sharding import (
    ShardingRules,
    constrain,
    lm_param_specs,
    lm_rules,
    replicated_rules,
)

__all__ = ["ShardingRules", "constrain", "lm_param_specs", "lm_rules",
           "replicated_rules"]
