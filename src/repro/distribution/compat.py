"""jax version + topology compatibility shims.

The repo pins jax 0.4.37 (the container's baked-in jax_pallas toolchain) but
several distribution APIs moved across jax releases:

  * ``jax.sharding.AxisType`` (and ``make_mesh(..., axis_types=...)``) only
    exist on jax >= 0.5; on 0.4.x every mesh axis is implicitly Auto.
  * ``jax.shard_map`` was promoted out of ``jax.experimental.shard_map``
    and its replication-check kwarg renamed ``check_rep`` -> ``check_vma``.

Everything in the repo that builds meshes or shard_maps goes through these
wrappers so the same code runs on the pinned 0.4.x and on newer jax.

This module is ALSO the only place that touches ``jax.distributed``: the
multi-process (multi-host) helpers below let the fused sharded runtime span
processes — ``init_multiprocess`` brings a rank into the coordination
service (with the CPU-collectives hint 0.4.x needs), ``global_mesh`` builds
a mesh over every global device, and ``stage_to_mesh`` /
``fetch_replicated`` move host arrays across the single-vs-multi-process
boundary (``jnp.asarray`` and ``np.asarray`` are process-local and fail on
cross-process global arrays).
"""

from __future__ import annotations

from typing import Sequence

import jax
import numpy as np


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]
              ) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


# ------------------------------------------------------------------ #
# Multi-process (jax.distributed) topology
# ------------------------------------------------------------------ #

def _distributed_client():
    """The live jax.distributed client, or None (API is private pre-0.5)."""
    state = getattr(jax.distributed, "global_state", None)
    if state is None:
        try:
            from jax._src.distributed import global_state as state
        except ImportError:
            return None
    return getattr(state, "client", None)


def cpu_collectives_hint() -> None:
    """Select a CPU cross-process collectives backend where one is needed.

    On the pinned 0.4.x the CPU backend refuses multi-process computations
    unless ``jax_cpu_collectives_implementation`` is set (gloo ships in the
    container's jaxlib); newer jax picks a default itself. Must run BEFORE
    the backend initializes — ``init_multiprocess`` calls this first.
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):
        pass  # option gone (newer jax defaults correctly) — nothing to do


def init_multiprocess(coordinator_address: str, num_processes: int,
                      process_id: int) -> None:
    """Join this process into a ``jax.distributed`` service.

    Every rank of a multi-host run calls this before touching any device;
    afterwards ``jax.devices()`` is the GLOBAL device list and
    ``global_mesh`` spans it. Idempotent per process (jax forbids double
    initialization; a repeat call is a no-op). Deliberately avoids
    ``jax.process_count()`` here — merely asking would initialize the
    backend, after which jax refuses to join a coordination service.
    """
    if _distributed_client() is not None:
        return
    cpu_collectives_hint()
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)


def is_multiprocess() -> bool:
    return jax.process_count() > 1


def global_mesh(axis_name: str = "shard") -> jax.sharding.Mesh:
    """1-D mesh over EVERY global device (all processes' devices)."""
    return make_mesh((len(jax.devices()),), (axis_name,))


def is_multiprocess_mesh(mesh: jax.sharding.Mesh) -> bool:
    """True when ``mesh`` spans devices owned by more than one process."""
    return len({d.process_index for d in mesh.devices.flat}) > 1


def stage_to_mesh(arr: np.ndarray, mesh: jax.sharding.Mesh,
                  spec) -> jax.Array:
    """Build a global device array from a host copy every process holds.

    ``jnp.asarray`` commits to a process-local device and cannot feed a
    cross-process jit; ``jax.make_array_from_callback`` assembles the global
    array from per-shard slices instead — each process serves only the
    shards its own devices own. Works identically on a single-process mesh,
    where it degenerates to a plain device_put with ``spec``.
    """
    arr = np.asarray(arr)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    return jax.make_array_from_callback(arr.shape, sharding,
                                        lambda idx: arr[idx])


def fetch_replicated(x, mesh: jax.sharding.Mesh) -> np.ndarray:
    """Host copy of a global array, valid on every process.

    Non-fully-addressable arrays (outputs sharded across processes) are
    first replicated with a collective identity jit — afterwards each
    process holds the complete value and the numpy conversion is local.
    """
    if getattr(x, "is_fully_addressable", True):
        return np.asarray(x)
    from jax.sharding import PartitionSpec as P

    rep = jax.jit(
        lambda a: a,
        out_shardings=jax.sharding.NamedSharding(mesh, P()))(x)
    return np.asarray(rep.addressable_data(0))
