"""jax version compatibility shims.

The repo pins jax 0.4.37 (the container's baked-in jax_pallas toolchain) but
several distribution APIs moved across jax releases:

  * ``jax.sharding.AxisType`` (and ``make_mesh(..., axis_types=...)``) only
    exist on jax >= 0.5; on 0.4.x every mesh axis is implicitly Auto.
  * ``jax.shard_map`` was promoted out of ``jax.experimental.shard_map``
    and its replication-check kwarg renamed ``check_rep`` -> ``check_vma``.

Everything in the repo that builds meshes or shard_maps goes through these
two wrappers so the same code runs on the pinned 0.4.x and on newer jax.
"""

from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]
              ) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
