"""Fault-tolerant training driver.

Responsibilities at 1000+ node scale (and their single-host analogues used
by tests):

  * checkpoint/restart — periodic save via checkpoint/ (atomic commit);
    startup always resumes from the latest COMMITTED step.
  * failure handling — ``failure_injector`` simulates a host loss at a
    given step (raises); the harness restarts the driver, which restores
    and continues — tests assert bit-exact continuation.
  * elastic scaling — restore re-shards onto whatever mesh the relaunch
    provides (checkpoint format is mesh-agnostic).
  * straggler mitigation — BSP steps are globally synchronous; the driver
    tracks per-step wall time and flags outliers (on real fleets this feeds
    the backup-worker / hot-spare policy; in the one-host simulation it is
    surfaced as a metric). Data order is deterministic in (seed, step), so
    a restarted/elastic run consumes identical batches.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


@dataclasses.dataclass
class TrainDriverConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0      # step_time > factor x median -> flag


class TrainDriver:
    def __init__(self, step_fn: Callable, init_state, batch_fn: Callable,
                 config: TrainDriverConfig,
                 failure_injector: Callable[[int], None] | None = None,
                 state_shardings=None):
        """step_fn(state, batch) -> (state, metrics);
        batch_fn(step) -> batch (deterministic in step)."""
        self.step_fn = step_fn
        self.state = init_state
        self.batch_fn = batch_fn
        self.cfg = config
        self.failure_injector = failure_injector
        self.state_shardings = state_shardings
        self.step = 0
        self.step_times: list[float] = []
        self.stragglers: list[int] = []
        self.metrics_log: list[dict] = []

    # -------------------------------------------------------------- #
    def maybe_restore(self) -> bool:
        last = latest_step(self.cfg.checkpoint_dir)
        if last is None:
            return False
        self.state, self.step = restore_checkpoint(
            self.cfg.checkpoint_dir, self.state,
            shardings=self.state_shardings)
        return True

    def run(self) -> dict:
        self.maybe_restore()
        while self.step < self.cfg.total_steps:
            if self.failure_injector is not None:
                self.failure_injector(self.step)   # may raise HostFailure
            t0 = time.perf_counter()
            batch = self.batch_fn(self.step)
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(jax.tree.leaves(self.state)[0])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-50:]))
            if len(self.step_times) > 5 and dt > self.cfg.straggler_factor * med:
                self.stragglers.append(self.step)
            self.step += 1
            if self.step % self.cfg.checkpoint_every == 0 or \
                    self.step == self.cfg.total_steps:
                save_checkpoint(self.cfg.checkpoint_dir, self.step, self.state)
            if self.step % self.cfg.log_every == 0:
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()} |
                    {"step": self.step, "step_time_s": dt})
        return {
            "final_step": self.step,
            "stragglers": self.stragglers,
            "metrics": self.metrics_log,
        }


class HostFailure(RuntimeError):
    """Simulated node loss."""


def make_failure_injector(fail_at_step: int):
    fired = {"done": False}

    def inject(step: int) -> None:
        if step == fail_at_step and not fired["done"]:
            fired["done"] = True
            raise HostFailure(f"simulated host loss at step {step}")

    return inject
