from repro.runtime.driver import TrainDriver, TrainDriverConfig

__all__ = ["TrainDriver", "TrainDriverConfig"]
