"""Production mesh builders.

NOTE: functions, not module-level constants — importing this module never
touches jax device state. The dry-run entry point (dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single device.
"""

from __future__ import annotations

import jax

from repro.distribution.compat import make_mesh as _mk


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1) -> jax.sharding.Mesh:
    """Small mesh for CI-scale integration tests."""
    return _mk((n_data, n_model), ("data", "model"))


def flat_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def n_devices(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n
