"""The paper's experiment entry point: distributed k-core decomposition.

    PYTHONPATH=src python -m repro.launch.kcore_run --graph FC --scale 0.2
    PYTHONPATH=src python -m repro.launch.kcore_run --graph chain --n 2000
    PYTHONPATH=src python -m repro.launch.kcore_run --graph FC --mode block_gs
    PYTHONPATH=src python -m repro.launch.kcore_run --graph FC --fused
    PYTHONPATH=src python -m repro.launch.kcore_run --graph ba --mesh 4 --fused
    PYTHONPATH=src python -m repro.launch.kcore_run --graph ba --fused --dispatch on
    PYTHONPATH=src python -m repro.launch.kcore_run --graph LJ1 --scale 0.01 \
        --out-of-core --mem-budget $((4 << 20))

Prints the paper's measurement set: total messages, messages/active nodes
per round, rounds to convergence, work bound, heartbeat-model overhead, and
the simulated-network runtime — plus validation vs the BZ oracle.

``--fused`` runs the whole round loop as ONE device-resident
``lax.while_loop`` (the shared fused runtime, repro/core/runtime.py) with
bit-equal message accounting; ``--mesh N`` runs the sharded engine on an
N-device ("data",) mesh (forced host devices when the platform has fewer —
the flag must precede the first jax import, so mesh runs defer all jax
imports like kcore_serve does). The two compose: ``--mesh N --fused`` nests
the masked shard_map superstep inside the while_loop.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def parse_args() -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="FC", help="SNAP abbrev (Table I) or chain/ba/er")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", default="jacobi", choices=["jacobi", "block_gs"])
    ap.add_argument("--backend", default="segment", choices=["segment", "ell", "ell_pallas"])
    ap.add_argument(
        "--fused",
        action="store_true",
        help="run the round loop as one device-resident while_loop "
        "(jacobi only; accounting bit-equal to the host loop)",
    )
    ap.add_argument(
        "--out-of-core",
        action="store_true",
        help="block-cycling decomposition on bounded device memory "
        "(repro.core.outofcore): arc blocks spill to disk and cycle "
        "through an LRU cache; bills bit-equal to the in-memory modes",
    )
    ap.add_argument(
        "--mem-budget",
        type=int,
        default=None,
        metavar="BYTES",
        help="out-of-core LRU block-cache budget in bytes (drives the "
        "block-count plan; default: 8 blocks, unbounded cache)",
    )
    ap.add_argument(
        "--blocks",
        type=int,
        default=None,
        metavar="N",
        help="force the out-of-core block count instead of planning it "
        "from --mem-budget",
    )
    ap.add_argument(
        "--mesh",
        type=int,
        default=0,
        metavar="N",
        help="run the sharded engine on an N-device ('data',) mesh "
        "(forces N host devices when the platform has fewer)",
    )
    ap.add_argument(
        "--platform",
        default=None,
        choices=["cpu", "gpu", "tpu"],
        help="select the jax platform (repro.platform.set_platform)",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=0,
        metavar="N",
        help="force N host (CPU) devices (repro.platform; applied before "
        "jax backend init, like REPRO_HOST_DEVICES)",
    )
    ap.add_argument(
        "--dispatch",
        default=None,
        choices=["auto", "pallas", "xla", "on", "off"],
        help="superstep kernel dispatch (repro.core.dispatch): auto routes "
        "to the Pallas kernels only where they compile natively; on/pallas "
        "forces them (interpret mode off-TPU), off/xla keeps the XLA "
        "segment ops. Default: the REPRO_PALLAS env var, else auto",
    )
    ap.add_argument("--json", action="store_true")
    ap.add_argument(
        "--trace",
        default=None,
        metavar="OUT.json",
        help="enable span tracing and export a Chrome trace_event JSON "
        "(open in Perfetto / chrome://tracing)",
    )
    ap.add_argument(
        "--metrics",
        action="store_true",
        help="dump the process metrics registry after the run "
        "(see --metrics-format / --metrics-out)",
    )
    ap.add_argument(
        "--metrics-format",
        default="json",
        choices=["json", "prom"],
        help="stdout format for --metrics: structured JSON (default) or "
        "the Prometheus text exposition format",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        metavar="PATH",
        help="also write the metrics registry to a file (implies "
        "--metrics); format inferred from the extension: .prom/.txt -> "
        "Prometheus text, anything else -> JSON",
    )
    ap.add_argument(
        "--flight",
        default=None,
        metavar="OUT.json",
        help="enable the convergence flight recorder + invariant monitor "
        "and dump the per-round ring and health verdict as JSON",
    )
    args = ap.parse_args()
    if args.metrics_out:
        args.metrics = True
    if args.mesh and (args.mode != "jacobi" or args.backend != "segment"):
        # the sharded engine is jacobi/segment only; refuse rather than
        # silently running (and reporting) a different mode than asked
        ap.error("--mesh supports --mode jacobi --backend segment only")
    if args.out_of_core and (args.mesh or args.fused or args.mode != "jacobi"
                             or args.backend != "segment"):
        ap.error("--out-of-core is its own engine: jacobi/segment only, "
                 "no --mesh/--fused")
    if (args.mem_budget or args.blocks) and not args.out_of_core:
        ap.error("--mem-budget/--blocks require --out-of-core")
    return args


def build_graph(args, generators):
    if args.graph == "chain":
        return generators.chain(args.n)
    if args.graph == "ba":
        return generators.barabasi_albert(args.n, 4, seed=args.seed)
    if args.graph == "er":
        return generators.erdos_renyi(args.n, 4 * args.n, seed=args.seed)
    return generators.snap_analogue(args.graph, scale=args.scale, seed=args.seed)


def main() -> None:
    args = parse_args()
    # platform layer first: env-driven config plus the CLI flags, all of
    # which must precede the first jax backend init in the process
    from repro import platform

    platform.configure_from_env()
    if args.platform:
        platform.set_platform(args.platform)
    if args.devices:
        platform.force_host_device_count(args.devices)
    if args.dispatch:
        platform.set_dispatch_mode(args.dispatch)
    if args.mesh:
        # must precede the first jax import anywhere in the process
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={args.mesh}"
        ).strip()

    from repro.core import (
        KCoreConfig,
        bz_core_numbers,
        kcore_decompose,
        kcore_decompose_sharded,
        work_bound,
    )
    from repro.core.cost_model import DATACENTER, INTERNET, TPU_POD, simulate_runtime
    from repro.core.messages import heartbeat_overhead
    from repro.graph import generators
    from repro.obs import metrics, trace

    if args.trace:
        trace.enable()
    if args.flight:
        from repro.obs import flight, health

        flight.enable()
        health.install()

    g = build_graph(args, generators)
    t0 = time.perf_counter()
    if args.out_of_core:
        from repro.core.outofcore import outofcore_decompose

        res = outofcore_decompose(g, mem_budget=args.mem_budget,
                                  n_blocks=args.blocks)
    elif args.mesh:
        from repro.distribution.compat import make_mesh

        mesh = make_mesh((args.mesh,), ("data",))
        res = kcore_decompose_sharded(g, mesh, ("data",), fused=args.fused)
    else:
        config = KCoreConfig(mode=args.mode, backend=args.backend)
        res = kcore_decompose(g, config, fused=args.fused)
    wall = time.perf_counter() - t0

    ref = bz_core_numbers(g)
    ok = bool((res.core == ref).all())
    wb = work_bound(g, res.core)
    hb = heartbeat_overhead(res.stats)
    report = {
        "graph": args.graph,
        "n": g.n,
        "m": g.m,
        "avg_deg": round(g.avg_deg, 1),
        "max_deg": g.max_deg,
        "max_core": int(res.core.max()) if g.n else 0,
        "mode": args.mode,
        "backend": args.backend,
        "fused": args.fused,
        "dispatch": res.dispatch,
        "mesh": args.mesh or 1,
        "correct_vs_BZ": ok,
        "rounds": res.rounds,
        "converged": res.converged,
        "total_messages": res.stats.total_messages,
        "work_bound": wb,
        "messages_over_bound": round(res.stats.total_messages / max(wb, 1), 3),
        "messages_per_round": res.stats.messages_per_round.tolist()[:20],
        "active_per_round": res.stats.active_per_round.tolist()[:20],
        "heartbeats": hb["heartbeat_messages"],
        "wall_s": round(wall, 2),
        "recompiles": res.recompiles,
        "compile_s": round(res.compile_s, 3),
        "phase_s": {k: round(v, 4) for k, v in res.phase_s.items()},
        "simulated_runtime_s": {
            m.name: round(simulate_runtime(res.stats, m)["total_s"], 4)
            for m in (INTERNET, DATACENTER, TPU_POD)
        },
    }
    if args.out_of_core and res.block_stats is not None:
        report["out_of_core"] = res.block_stats.to_json()
    if args.json:
        print(json.dumps(report, indent=1))
    else:
        for k, v in report.items():
            print(f"{k}: {v}")
    if args.trace:
        trace.export(args.trace)
        print(f"trace: {args.trace} ({len(trace.events())} events)")
    if args.metrics:
        # fold the run's headline numbers into the process registry so the
        # dump is useful even for a single static decomposition
        labels = {"graph": args.graph}
        metrics.counter("kcore_rounds_total", **labels).inc(res.rounds)
        metrics.counter("kcore_messages_total", **labels).inc(int(res.stats.total_messages))
        metrics.gauge("kcore_compile_seconds", **labels).set(res.compile_s)
        metrics.gauge("kcore_wall_seconds", **labels).set(wall)
        for phase, secs in res.phase_s.items():
            metrics.gauge("kcore_phase_seconds", graph=args.graph, phase=phase).set(secs)
        if args.metrics_format == "prom":
            print(metrics.to_prometheus(), end="")
        else:
            print(json.dumps({"metrics": metrics.to_json()}, indent=1))
        if args.metrics_out:
            prom_file = args.metrics_out.endswith((".prom", ".txt"))
            with open(args.metrics_out, "w") as f:
                if prom_file:
                    f.write(metrics.to_prometheus())
                else:
                    json.dump({"metrics": metrics.to_json()}, f, indent=1)
            print(f"metrics: {args.metrics_out} ({'prom' if prom_file else 'json'})")
    if args.flight:
        from repro.obs import flight, health

        payload = flight.to_json()
        payload["health"] = health.verdict()
        with open(args.flight, "w") as f:
            json.dump(payload, f)
        print(
            f"flight: {args.flight} (runs={payload['runs']} "
            f"rounds={payload['rounds_recorded']} health={payload['health']['status']})"
        )
    assert ok, "core numbers disagree with BZ oracle!"


if __name__ == "__main__":
    main()
