"""Trip-count-aware jaxpr FLOPs counter — cross-check for cost_analysis().

Walks the closed jaxpr of a step function, counting dot_general FLOPs
(2*M*N*K with batch dims) and multiplying scan/while bodies by their trip
counts. This is the MODEL-side count used for the MODEL_FLOPS / HLO_FLOPs
"useful compute" ratio in EXPERIMENTS.md §Roofline (it sees remat recompute
exactly as XLA executes it, because remat regions appear as separate eqns).
"""

from __future__ import annotations

import jax
import numpy as np


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    m = np.prod([a.shape[i] for i in range(len(a.shape))
                 if i not in lc and i not in lb], dtype=float)
    n = np.prod([b.shape[i] for i in range(len(b.shape))
                 if i not in rc and i not in rb], dtype=float)
    k = np.prod([a.shape[i] for i in lc], dtype=float)
    batch = np.prod([a.shape[i] for i in lb], dtype=float)
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel spatial * in_channels)
    k_elems = np.prod(rhs.shape, dtype=float) / max(rhs.shape[-1], 1)
    return 2.0 * np.prod(out.shape, dtype=float) * k_elems


# Memory-traffic ops: operands stream HBM<->VMEM once each (fusion folds
# elementwise chains into these, so elementwise ops are NOT counted).
_MEM_OPS = {"dot_general", "conv_general_dilated", "gather", "scatter",
            "scatter-add", "scatter_add", "dynamic_update_slice",
            "dynamic_slice", "take", "sort", "top_k", "reduce_sum",
            "segment_sum", "cumsum", "argsort"}


def _aval_bytes(v) -> float:
    aval = v.aval
    if not hasattr(aval, "shape"):
        return 0.0
    return float(np.prod(aval.shape, dtype=float) *
                 np.dtype(aval.dtype).itemsize)


def jaxpr_cost(jaxpr) -> tuple[float, float]:
    """(flops, hbm_bytes) with exact scan trip-count multipliers."""
    flops = 0.0
    nbytes = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            flops += _dot_flops(eqn)
        elif name == "conv_general_dilated":
            flops += _conv_flops(eqn)
        if name in _MEM_OPS:
            nbytes += sum(_aval_bytes(v) for v in eqn.invars) + \
                sum(_aval_bytes(v) for v in eqn.outvars)
        if name == "scan":
            f, b = jaxpr_cost(eqn.params["jaxpr"].jaxpr)
            L = eqn.params["length"]
            flops += L * f
            nbytes += L * b
        elif name == "while":
            # body counted once; our hot loops are lax.scan (exact above).
            f, b = jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)
            flops += f
            nbytes += b
        elif name == "cond":
            costs = [jaxpr_cost(br.jaxpr) for br in eqn.params["branches"]]
            if costs:
                flops += max(c[0] for c in costs)
                nbytes += max(c[1] for c in costs)
        elif name == "shard_map":
            # body avals are per-device: scale back to global
            mesh = eqn.params.get("mesh")
            ndev = float(np.prod(list(mesh.shape.values()))) if mesh is not \
                None else 1.0
            sub = eqn.params["jaxpr"]
            f, b = jaxpr_cost(getattr(sub, "jaxpr", sub))
            flops += ndev * f
            nbytes += ndev * b
        elif eqn.params:
            for key in ("jaxpr", "call_jaxpr"):
                sub = eqn.params.get(key)
                if sub is not None:
                    f, b = jaxpr_cost(getattr(sub, "jaxpr", sub))
                    flops += f
                    nbytes += b
    return flops, nbytes


def step_flops(fn, *args) -> float:
    """Total dot/conv FLOPs of one (unsharded) step."""
    return step_cost(fn, *args)[0]


def step_cost(fn, *args) -> tuple[float, float]:
    """(FLOPs, HBM-bytes proxy) of one (unsharded, logical) step."""
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_cost(closed.jaxpr)
