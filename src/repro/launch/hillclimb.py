import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration driver (EXPERIMENTS.md §Perf): lowers VARIANTS of the
three hillclimb cells and prints their roofline terms without touching the
baseline records.

    PYTHONPATH=src python -m repro.launch.hillclimb mixtral_cap
    PYTHONPATH=src python -m repro.launch.hillclimb --list
"""

import argparse
import dataclasses
import json
import time

import jax

from repro.configs import get_config
from repro.launch import hlo_analysis, jaxpr_cost
from repro.launch.dryrun import _mem_dict, build_cell
from repro.launch.mesh import make_production_mesh, n_devices


def measure(arch_cfg, arch: str, shape_name: str) -> dict:
    """Lower a (possibly modified) config for one cell; return terms."""
    import repro.configs.registry as registry
    # temporarily override the registry entry so build_cell sees the variant
    orig = registry.get_config
    registry.get_config = lambda a: arch_cfg if a == arch else orig(a)
    try:
        import repro.launch.dryrun as dr
        dr.get_config = registry.get_config
        mesh = make_production_mesh()
        chips = n_devices(mesh)
        step, args, in_sh, out_sh = build_cell(arch, shape_name, mesh)
        jflops, jbytes = jaxpr_cost.step_cost(step, *args)
        t0 = time.time()
        compiled = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=(0, 1) if shape_name.startswith(
                               "train") else ()).lower(*args).compile()
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        roof = hlo_analysis.Roofline(
            flops=jflops, hbm_bytes=jbytes,
            coll_bytes=coll["total_bytes"] * chips, chips=chips)
        mem = _mem_dict(compiled.memory_analysis())
        return {
            "compute_s": round(roof.compute_s, 4),
            "memory_s": round(roof.memory_s, 4),
            "collective_s": round(roof.collective_s, 4),
            "dominant": roof.dominant,
            "mem_GB": round(mem.get("per_device_live_bytes", 0) / 1e9, 2),
            "compile_s": round(time.time() - t0, 1),
        }
    finally:
        registry.get_config = orig


VARIANTS = {}


def variant(name):
    def deco(fn):
        VARIANTS[name] = fn
        return fn
    return deco


@variant("mixtral_base")
def mixtral_base():
    return measure(get_config("mixtral-8x22b"), "mixtral-8x22b", "train_4k")


@variant("mixtral_cap110")
def mixtral_cap110():
    cfg = get_config("mixtral-8x22b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=1.10))
    return measure(cfg, "mixtral-8x22b", "train_4k")


@variant("mixtral_dots_remat")
def mixtral_dots_remat():
    cfg = dataclasses.replace(get_config("mixtral-8x22b"),
                              remat_policy="dots")
    return measure(cfg, "mixtral-8x22b", "train_4k")


@variant("mixtral_cap110_dots")
def mixtral_cap110_dots():
    cfg = get_config("mixtral-8x22b")
    cfg = dataclasses.replace(
        cfg, remat_policy="dots",
        moe=dataclasses.replace(cfg.moe, capacity_factor=1.10))
    return measure(cfg, "mixtral-8x22b", "train_4k")


@variant("mixtral_micro4_dots")
def mixtral_micro4_dots():
    cfg = get_config("mixtral-8x22b")
    cfg = dataclasses.replace(
        cfg, remat_policy="dots", train_microbatches=16,
        moe=dataclasses.replace(cfg.moe, capacity_factor=1.10))
    return measure(cfg, "mixtral-8x22b", "train_4k")


@variant("mixtral_alldots")
def mixtral_alldots():
    cfg = get_config("mixtral-8x22b")
    cfg = dataclasses.replace(
        cfg, remat_policy="all_dots", train_microbatches=16,
        moe=dataclasses.replace(cfg.moe, capacity_factor=1.10))
    return measure(cfg, "mixtral-8x22b", "train_4k")


@variant("mixtral_alldots_m64")
def mixtral_alldots_m64():
    cfg = get_config("mixtral-8x22b")
    cfg = dataclasses.replace(
        cfg, remat_policy="all_dots", train_microbatches=64,
        moe=dataclasses.replace(cfg.moe, capacity_factor=1.10))
    return measure(cfg, "mixtral-8x22b", "train_4k")


@variant("graphcast_products")
def graphcast_products():
    return measure(get_config("graphcast"), "graphcast", "ogb_products")


@variant("din_train")
def din_train():
    return measure(get_config("din"), "din", "train_batch")


@variant("qwen2moe_base")
def qwen2moe_base():
    return measure(get_config("qwen2-moe-a2.7b"), "qwen2-moe-a2.7b",
                   "train_4k")


@variant("qwen2moe_cap105")
def qwen2moe_cap105():
    cfg = get_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, remat_policy="dots",
        moe=dataclasses.replace(cfg.moe, capacity_factor=1.05))
    return measure(cfg, "qwen2-moe-a2.7b", "train_4k")


@variant("din_fullshard")
def din_fullshard():
    os.environ["REPRO_DIN_FULLSHARD"] = "1"
    try:
        cfg = dataclasses.replace(get_config("din"), n_items=1_000_448)
        return measure(cfg, "din", "train_batch")
    finally:
        del os.environ["REPRO_DIN_FULLSHARD"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        print("\n".join(VARIANTS))
        return
    for name in (args.names or list(VARIANTS)):
        res = VARIANTS[name]()
        print(f"{name}: {json.dumps(res)}", flush=True)


if __name__ == "__main__":
    main()
