"""Serving launcher: prefill + decode loop with batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models.transformer import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.key(args.seed))
    B, P, G = args.batch, args.prompt_len, args.gen

    prompts = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab)
    max_len = P + G
    cache = M.init_kv_cache(cfg, B, max_len)

    prefill = jax.jit(lambda p, t: M.prefill(p, cfg, t))
    decode = jax.jit(lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos),
                     donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, pc = prefill(params, prompts)
    # place prefill kv into the serving cache
    T = cache["k"].shape[3]
    Tp = pc["k"].shape[3]
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], pc["k"], (0, 0, 0, (P - Tp) % T if cfg.swa_window
                                  else 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], pc["v"], (0, 0, 0, (P - Tp) % T if cfg.swa_window
                                  else 0, 0)),
    }
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    for i in range(G - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(P + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"arch={cfg.name} served batch={B} prompt={P} generated={G} "
          f"tokens in {dt:.2f}s ({B * G / dt:.1f} tok/s)")
    print("sample:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
