"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --smoke --steps 50 --batch 8 --seq 128

On a real fleet the same entry point runs under the production mesh
(--mesh pod1/pod2 uses the 256/512-device configuration; this container
exposes one CPU device, so full-mesh runs are for TPU deployments — the
dry-run proves they compile). --smoke trains the reduced config on the
local device through the full fault-tolerant driver.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke
from repro.data import synth_lm_batch
from repro.models.transformer import model as M
from repro.models.transformer.steps import make_train_step
from repro.optim import adamw_init
from repro.runtime import TrainDriver, TrainDriverConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family != "lm":
        raise SystemExit("train.py drives the LM family; use kcore_run.py "
                         "or the examples for graph/recsys work")

    params = M.init_params(cfg, jax.random.key(args.seed))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, rules=None, total_steps=args.steps),
                   donate_argnums=(0, 1))

    def step_fn(state, batch):
        params, opt = state
        tokens, labels = batch
        params, opt, metrics = step(params, opt, tokens, labels)
        return (params, opt), metrics

    def batch_fn(i):
        t, l = synth_lm_batch(cfg.vocab, args.batch, args.seq,
                              seed=args.seed, step=i)
        return jax.numpy.asarray(t), jax.numpy.asarray(l)

    driver = TrainDriver(
        step_fn, (params, opt), batch_fn,
        TrainDriverConfig(total_steps=args.steps,
                          checkpoint_every=args.ckpt_every,
                          checkpoint_dir=args.ckpt_dir))
    report = driver.run()
    losses = [m["loss"] for m in report["metrics"]]
    print(f"arch={cfg.name} steps={report['final_step']} "
          f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"stragglers={len(report['stragglers'])}")


if __name__ == "__main__":
    main()
