import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines, before ANY other import: jax locks the
# device count on first initialization. Everything else (smoke tests,
# benches) must see the real single device, so this flag lives ONLY here.

# Lowering keeps lax.scan loops (compile stays minutes-not-hours across the
# 80-cell grid and memory_analysis reflects the program you would actually
# run). Cost accounting is therefore done loop-aware:
#   * FLOPs / HBM bytes: trip-count-exact jaxpr walk (launch/jaxpr_cost) —
#     XLA's HloCostAnalysis counts while bodies ONCE, so it under-counts by
#     the trip count (validated: on a fully-unrolled small config the two
#     agree; see EXPERIMENTS.md §Roofline methodology).
#   * collective bytes: post-SPMD HLO parse with while-trip multipliers
#     (launch/hlo_analysis.collective_bytes).

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell:
    jit(step, in_shardings, out_shardings).lower(*specs).compile()
then record memory_analysis() (fits?), cost_analysis() (FLOPs/bytes) and the
collective schedule (parsed from post-SPMD HLO) into a JSON blob consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod1
    PYTHONPATH=src python -m repro.launch.dryrun --arch kcore --graph LJ1
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config, get_shapes
from repro.configs.registry import shape_by_name
from repro.launch import hlo_analysis, jaxpr_cost
from repro.launch.mesh import make_production_mesh, n_devices
from repro.optim import adamw_init

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# long_500k needs sub-quadratic attention: only mixtral (SWA) runs it.
SKIP = {
    ("qwen2-moe-a2.7b", "long_500k"): "full attention (no sub-quadratic path)",
    ("yi-34b", "long_500k"): "full attention (no sub-quadratic path)",
    ("granite-34b", "long_500k"): "full attention (no sub-quadratic path)",
    ("qwen1.5-0.5b", "long_500k"): "full attention (no sub-quadratic path)",
}


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (step, ordered ShapeDtypeStruct args, in_sh, out_sh)."""
    cfg = get_config(arch)
    shape = shape_by_name(arch, shape_name)
    if cfg.family == "lm":
        from repro.models.transformer import steps as S
        step, specs, in_sh, out_sh = S.build_step(cfg, shape, mesh)
        if shape.kind == "train":
            args = (S.param_shapes(cfg), S.opt_shapes(cfg),
                    specs["tokens"], specs["labels"])
        elif shape.kind == "prefill":
            args = (S.param_shapes(cfg), specs["tokens"])
        else:
            args = (S.param_shapes(cfg), specs["token"], specs["cache"],
                    specs["pos"])
        return step, args, in_sh, out_sh
    if cfg.family == "gnn":
        from repro.models.gnn import steps as S
        step, specs, in_sh, out_sh = S.build_step(cfg, shape, mesh)
        opt = jax.eval_shape(adamw_init, specs["_params"])
        args = (specs["_params"], opt, specs["batch"])
        return step, args, in_sh, out_sh
    # recsys
    from repro.models.recsys import steps as S
    from repro.models.recsys import din
    step, specs, in_sh, out_sh = S.build_step(cfg, shape, mesh)
    pshapes = jax.eval_shape(lambda k: din.init_params(cfg, k),
                             jax.random.key(0))
    if shape.kind == "train":
        opt = jax.eval_shape(adamw_init, pshapes)
        args = (pshapes, opt, specs)
    else:
        args = (pshapes, specs)
    return step, args, in_sh, out_sh


def run_cell(arch: str, shape_name: str, mesh_name: str,
             save: bool = True) -> dict:
    if (arch, shape_name) in SKIP:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "SKIP", "reason": SKIP[(arch, shape_name)]}
        if save:
            _save(rec)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    chips = n_devices(mesh)
    t0 = time.time()
    try:
        step, args, in_sh, out_sh = build_cell(arch, shape_name, mesh)
        # trip-count-exact logical cost (global, includes remat recompute)
        jflops, jbytes = jaxpr_cost.step_cost(step, *args)
        # donate aliasable state (params/opt for train, cache for decode) —
        # exactly what the real launcher does, so memory analysis matches.
        shape_obj = shape_by_name(arch, shape_name)
        if shape_obj.kind == "train":
            donate = (0, 1)
        elif shape_obj.kind == "decode" and get_config(arch).family == "lm":
            donate = (2,)
        else:
            donate = ()
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        roof = hlo_analysis.Roofline(
            flops=jflops, hbm_bytes=jbytes,
            coll_bytes=coll["total_bytes"] * chips, chips=chips)
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "OK", "chips": chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": _mem_dict(mem),
            "roofline": roof.to_dict(),
            "collectives": coll,
            "xla_cost_analysis_per_device": {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            },
        }
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash --all
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "FAIL", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    if save:
        _save(rec)
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        live = out.get("argument_size_in_bytes", 0) + \
            out.get("output_size_in_bytes", 0) + \
            out.get("temp_size_in_bytes", 0) - \
            out.get("alias_size_in_bytes", 0)
        out["per_device_live_bytes"] = live
        out["fits_16GB"] = bool(live < 16e9)
    return out


def _save(rec: dict) -> None:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json".replace("/", "-")
    (OUT_DIR / name).write_text(json.dumps(rec, indent=1))


# ---------------------------------------------------------------------- #
# k-core engine cells (the paper's own workload)
# ---------------------------------------------------------------------- #

def run_kcore_cell(graph_abbrev: str, mesh_name: str, save=True) -> dict:
    from repro.core.kcore import _bs_iters, make_sharded_superstep
    from repro.graph.generators import SNAP_BY_ABBREV
    from repro.graph.partition import ShardedGraph

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    chips = n_devices(mesh)
    entry = SNAP_BY_ABBREV[graph_abbrev]
    # dry-run lowers with the ORIGINAL graph sizes (ShapeDtypeStructs only)
    n, arcs = entry.n, 2 * entry.m
    V = -(-n // chips)
    A = -(-arcs // chips)
    sg = ShardedGraph(
        n_shards=chips, n_real=n, verts_per_shard=V, arcs_per_shard=A,
        src=None, dst=None, arc_mask=None, deg=None, vert_mask=None)
    n_iters = _bs_iters(entry.max_deg)
    superstep, _ = make_sharded_superstep(sg, mesh, mesh.axis_names, n_iters)
    i32 = jax.numpy.int32
    st = lambda dt: jax.ShapeDtypeStruct((chips, V), dt)
    at = lambda dt: jax.ShapeDtypeStruct((chips, A), dt)
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = NamedSharding(mesh, P(tuple(mesh.axis_names)))
    rep = NamedSharding(mesh, P())
    t0 = time.time()
    try:
        args = (st(i32), at(i32), at(i32), at(jax.numpy.bool_), st(i32))
        jflops, jbytes = jaxpr_cost.step_cost(superstep, *args)
        jitted = jax.jit(superstep, in_shardings=(sh,) * 5,
                         out_shardings=(sh, rep, rep))
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        roof = hlo_analysis.Roofline(
            flops=jflops, hbm_bytes=jbytes,
            coll_bytes=coll["total_bytes"] * chips, chips=chips)
        rec = {
            "arch": "kcore", "shape": graph_abbrev, "mesh": mesh_name,
            "status": "OK", "chips": chips,
            "n": n, "arcs": arcs, "bs_iters": n_iters,
            "compile_s": round(time.time() - t0, 1),
            "memory": _mem_dict(compiled.memory_analysis()),
            "roofline": roof.to_dict(),
            "collectives": coll,
        }
    except Exception as e:  # noqa: BLE001
        rec = {"arch": "kcore", "shape": graph_abbrev, "mesh": mesh_name,
               "status": "FAIL", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    if save:
        _save(rec)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--graph", default=None, help="kcore: SNAP abbrev")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for s in get_shapes(arch):
                cells.append((arch, s.name))
    elif args.arch == "kcore":
        rec = run_kcore_cell(args.graph or "FC", args.mesh)
        print(json.dumps({k: v for k, v in rec.items() if k != "traceback"},
                         indent=1))
        return
    else:
        shapes = [args.shape] if args.shape else \
            [s.name for s in get_shapes(args.arch)]
        cells = [(args.arch, s) for s in shapes]

    for arch, shape in cells:
        rec = run_cell(arch, shape, args.mesh)
        status = rec["status"]
        extra = rec.get("reason") or rec.get("error") or ""
        roof = rec.get("roofline", {})
        dom = roof.get("dominant", "")
        mem = rec.get("memory", {}).get("per_device_live_bytes")
        memgb = f"{mem/1e9:.2f}GB" if mem else "?"
        print(f"[{status}] {arch} x {shape} x {args.mesh} "
              f"mem/dev={memgb} dominant={dom} {extra}", flush=True)


if __name__ == "__main__":
    main()
