"""Roofline-term extraction from compiled dry-run artifacts.

compute  = HLO_FLOPs / (chips * 197e12)      [bf16 MXU peak, v5e-class]
memory   = HLO_bytes / (chips * 819e9)       [HBM bandwidth]
collect. = collective_bytes / (chips * 50e9) [ICI per-link]

cost_analysis() provides FLOPs/bytes; collective bytes are NOT there — they
are parsed from the post-SPMD compiled HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction's
shapes, with op-specific wire multipliers (ring algorithms):
  all-gather: result bytes x (n-1)/n received per device
  all-reduce: 2 x operand bytes x (n-1)/n
  reduce-scatter: operand bytes x (n-1)/n
  all-to-all / collective-permute: operand bytes
Post-SPMD shapes are per-device, so terms are already per-chip.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE)

_REPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# iota format: replica_groups=[n_groups,group_size]<=[total]
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?(%[\w.-]+|[\w.-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_CALL_REF_RE = re.compile(
    r"(?:to_apply=|calls=|body=|condition=|branch_computations=\{)"
    r"\s*(%[\w.-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\)(?:.*?)(?:condition=(%[\w.-]+)).*?(?:body=(%[\w.-]+))"
    r"|while\(.*?\)(?:.*?)(?:body=(%[\w.-]+)).*?(?:condition=(%[\w.-]+))")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict[str, str]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip()) if "{" in line and "->" in line \
            else None
        if m:
            name = m.group(2)
            if not name.startswith("%"):
                name = "%" + name
            cur = name
            comps[cur] = []
            if m.group(1):
                comps["__ENTRY__"] = [name]
            continue
        if cur is not None:
            comps[cur].append(line)
    return {k: "\n".join(v) if k != "__ENTRY__" else v[0]
            for k, v in comps.items()}


def _wire_bytes(line: str, shape_str: str, kind: str) -> float:
    nbytes = _shape_bytes(shape_str)
    gm = _REPL_GROUPS_RE.search(line)
    if gm:
        gsize = len(gm.group(1).split(","))
    else:
        gi = _IOTA_GROUPS_RE.search(line)
        gsize = int(gi.group(2)) if gi else 2
    ring = (gsize - 1) / max(gsize, 1)
    if kind == "all-gather":
        return nbytes * ring
    if kind == "all-reduce":
        return 2 * nbytes * ring
    if kind == "reduce-scatter":
        return nbytes * ring
    return float(nbytes)          # all-to-all, collective-permute


def collective_bytes(hlo_text: str) -> dict:
    """Sum wire bytes per collective kind from (post-SPMD) HLO text,
    LOOP-AWARE: collectives inside while bodies are multiplied by the
    loop trip count (extracted from the largest constant in the loop's
    condition computation — exact for lax.scan/fori lowerings, whose
    condition is ``compare(i, length)``).

    ``-done`` halves of async pairs are skipped (counted at ``-start``)."""
    comps = _split_computations(hlo_text)
    entry = comps.pop("__ENTRY__", None)

    # per-computation raw collective bytes
    raw: dict[str, dict] = {}
    for cname, body in comps.items():
        per_kind: dict[str, float] = {}
        counts: dict[str, int] = {}
        for line in body.splitlines():
            m = _COLL_RE.match(line)
            if not m:
                continue
            shape_str, kind = m.group(1), m.group(2)
            if f"{kind}-done" in line:
                continue
            per_kind[kind] = per_kind.get(kind, 0.0) + \
                _wire_bytes(line, shape_str, kind)
            counts[kind] = counts.get(kind, 0) + 1
        raw[cname] = {"bytes": per_kind, "counts": counts}

    # call graph with while-body trip multipliers
    callees: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    for cname, body in comps.items():
        for line in body.splitlines():
            if "while(" in line:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond = wm.group(1) or wm.group(4)
                    wbody = wm.group(2) or wm.group(3)
                    trip = 1.0
                    if cond in comps:
                        consts = [int(c) for c in
                                  _CONST_RE.findall(comps[cond])]
                        trip = float(max(consts)) if consts else 1.0
                    if wbody in comps:
                        callees[cname].append((wbody, max(trip, 1.0)))
                    continue
            for ref in _CALL_REF_RE.findall(line):
                if ref in comps:
                    callees[cname].append((ref, 1.0))

    # effective multiplier per computation from ENTRY
    mult: dict[str, float] = {}

    def visit(c: str, m: float, depth: int = 0) -> None:
        if depth > 64:
            return
        mult[c] = max(mult.get(c, 0.0), m)
        for callee, k in callees.get(c, ()):  # noqa: B007
            visit(callee, m * k, depth + 1)

    roots = [entry] if entry in comps else \
        [c for c in comps if not any(
            any(cal == c for cal, _ in v) for v in callees.values())]
    for r in roots:
        visit(r, 1.0)

    per_kind: dict[str, float] = {}
    counts: dict[str, int] = {}
    static_counts: dict[str, int] = {}
    for cname, info in raw.items():
        m = mult.get(cname, 1.0)
        for kind, b in info["bytes"].items():
            per_kind[kind] = per_kind.get(kind, 0.0) + b * m
            counts[kind] = counts.get(kind, 0) + \
                int(round(info["counts"][kind] * m))
            static_counts[kind] = static_counts.get(kind, 0) + \
                info["counts"][kind]
    return {"bytes_by_kind": per_kind,
            "counts": counts,
            "static_counts": static_counts,
            "total_bytes": sum(per_kind.values())}


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
        }


def roofline_from_compiled(compiled, chips: int, *,
                           per_device_cost: bool = True) -> Roofline:
    """Build roofline terms from a compiled executable.

    XLA:CPU cost analysis reports the PER-DEVICE (post-SPMD) module; flops
    are whole-step per device, so the per-chip terms divide by 1 — we keep
    the interface uniform by multiplying back to global then dividing by
    chips in the properties."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    if per_device_cost:
        flops *= chips
        nbytes *= chips
    coll = collective_bytes(compiled.as_text())
    return Roofline(flops=flops, hbm_bytes=nbytes,
                    coll_bytes=coll["total_bytes"] * chips, chips=chips)
