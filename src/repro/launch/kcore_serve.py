"""Streaming k-core serving loop: churn batches interleaved with query load.

    PYTHONPATH=src python -m repro.launch.kcore_serve --graph EEN --scale 0.27
    PYTHONPATH=src python -m repro.launch.kcore_serve --graph FC \
        --batches 10 --churn 0.01 --queries 100000 --verify
    PYTHONPATH=src python -m repro.launch.kcore_serve --graph ba --mesh 4 \
        --frontier sharded --verify

Each tick applies one churn batch (--churn fraction of current edges, split
between deletes and inserts) through the incremental engine, then answers a
batched query load (--queries core-number lookups plus k-core membership and
max-k probes) — the paper's million-client scenario, served from a maintained
index instead of a per-request decomposition. Prints one CSV row per tick:
incremental vs from-scratch message bill, re-convergence rounds, region size,
and query throughput. --verify additionally checks every tick against the BZ
oracle (slow; for demos and CI smoke).

--mesh N runs the maintenance engine mesh-native on an N-device ("data",)
mesh: the initial decomposition and the per-batch masked supersteps execute
as shard_map programs. If fewer than N real devices exist, N host (CPU)
devices are forced via XLA_FLAGS — which only works because this module
defers every jax import until after the flag is set, so keep --mesh runs to
fresh processes. Cores and message counts are identical to the
single-device engine on any mesh (that equality is CI-tested).
"""

from __future__ import annotations

import argparse
import os
import time


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="EEN",
                    help="SNAP abbrev (Table I) or chain/ba/er")
    ap.add_argument("--scale", type=float, default=0.27)
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--churn", type=float, default=0.01,
                    help="fraction of edges churned per batch")
    ap.add_argument("--queries", type=int, default=100_000,
                    help="core-number lookups per tick")
    ap.add_argument("--frontier", default="dense",
                    choices=["dense", "compact", "sharded", "auto"])
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="run mesh-native on an N-device ('data',) mesh; "
                         "forces N host devices when fewer exist (must be "
                         "set before jax initializes — fresh process only). "
                         "0 = single device (default)")
    ap.add_argument("--verify", action="store_true",
                    help="check vs the BZ oracle every tick (slow)")
    return ap.parse_args()


def build_graph(args, generators):
    if args.graph == "chain":
        return generators.chain(args.n)
    if args.graph == "ba":
        return generators.barabasi_albert(args.n, 4, seed=args.seed)
    if args.graph == "er":
        return generators.erdos_renyi(args.n, 4 * args.n, seed=args.seed)
    return generators.snap_analogue(args.graph, scale=args.scale,
                                    seed=args.seed)


def main() -> None:
    args = parse_args()
    if args.mesh:
        # must precede the first jax import anywhere in the process
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={args.mesh}"
        ).strip()

    import numpy as np

    from repro.core import bz_core_numbers, kcore_decompose
    from repro.graph import generators
    from repro.streaming import (KCoreServer, Request, StreamingConfig,
                                 random_churn_batch)

    mesh = None
    if args.mesh:
        from repro.distribution.compat import make_mesh
        mesh = make_mesh((args.mesh,), ("data",))
        if args.frontier == "dense":
            args.frontier = "sharded"

    g = build_graph(args, generators)
    t0 = time.perf_counter()
    server = KCoreServer(g, StreamingConfig(frontier=args.frontier),
                         mesh=mesh)
    print(f"# graph={args.graph} n={g.n} m={g.m} mesh={args.mesh or 1} "
          f"frontier={args.frontier} "
          f"init_messages={server.engine.init_result.stats.total_messages} "
          f"init_wall_s={time.perf_counter() - t0:.2f}")
    rng = np.random.default_rng(args.seed)

    cols = ("tick,m,inserted,deleted,inc_messages,scratch_messages,ratio,"
            "rounds,region,seed_changed,mode,patch_s,queries,query_s,max_k,"
            "verified")
    print(cols)
    for tick in range(args.batches):
        b = max(2, int(args.churn * server.engine.graph.m))
        batch = random_churn_batch(server.engine.graph, b // 2, b - b // 2,
                                   rng)
        res = server.update(batch)

        # query load: batched core-number lookups + membership/max-k probes
        n = server.engine.graph.n
        qids = rng.integers(0, n, size=args.queries)
        reqs = [Request(op="core", vertices=qids),
                Request(op="in_kcore", vertices=qids[: args.queries // 2],
                        k=max(server.max_k() - 1, 1)),
                Request(op="members", k=server.max_k()),
                Request(op="max_k")]
        t0 = time.perf_counter()
        server.serve(reqs)
        query_s = time.perf_counter() - t0

        scratch = kcore_decompose(server.engine.graph)
        verified = ""
        if args.verify:
            ok = bool((res.core == bz_core_numbers(server.engine.graph)).all())
            verified = str(ok)
            assert ok, "incremental cores diverged from the BZ oracle!"
        ratio = res.total_messages / max(scratch.stats.total_messages, 1)
        print(",".join(str(c) for c in (
            tick, server.engine.graph.m, res.delta.inserted.shape[0],
            res.delta.deleted.shape[0], res.total_messages,
            scratch.stats.total_messages, round(ratio, 4), res.rounds,
            res.region_size, res.seed_changed, res.mode,
            round(res.patch_s, 5), args.queries,
            round(query_s, 4), server.max_k(), verified)))

    print(f"# final_stats={server.stats()}")


if __name__ == "__main__":
    main()
