"""Streaming k-core serving loop: churn batches interleaved with query load.

    PYTHONPATH=src python -m repro.launch.kcore_serve --graph EEN --scale 0.27
    PYTHONPATH=src python -m repro.launch.kcore_serve --graph FC \
        --batches 10 --churn 0.01 --queries 100000 --verify
    PYTHONPATH=src python -m repro.launch.kcore_serve --graph ba --mesh 4 \
        --frontier sharded --verify

    # temporal replay: slide a window over a timestamped event stream
    PYTHONPATH=src python -m repro.launch.kcore_serve --events snap:FC \
        --scale 0.05 --window 3000 --stride 500 --verify
    PYTHONPATH=src python -m repro.launch.kcore_serve --events trace.npz \
        --window 60 --stride 10 --by time --queries 10000

Each tick applies one churn batch (--churn fraction of current edges, split
between deletes and inserts) through the incremental engine, then answers a
batched query load (--queries core-number lookups plus k-core membership and
max-k probes) — the paper's million-client scenario, served from a maintained
index instead of a per-request decomposition. Prints one CSV row per tick:
incremental vs from-scratch message bill, re-convergence rounds, region size,
and query throughput. --verify additionally checks every tick against the BZ
oracle (slow; for demos and CI smoke).

--events switches the update source from synthetic uniform churn to a
TEMPORAL REPLAY (repro.temporal): a tick slides a count- or time-based
window (--window/--stride/--by) over the event stream, the insert/expire
delta re-converges incrementally, and every boundary's core vector is
checkpointed into the server's as-of ring — each tick additionally answers
a ``core_asof`` query against a random retained boundary. --events takes a
path (.npz or text event log) or a generator spec: ``snap:<ABBREV>``
(temporal SNAP analogue at --scale, with --remove-frac removal events),
``ba`` (timestamped preferential attachment at --n), or ``contact``
(contact-network bursts at --n).

--concurrent N serves the read side from an N-worker snapshot-isolated
pool (streaming.concurrent): reads keep answering the last converged
fixpoint while the single writer re-converges, and with --listen the
/query/* HTTP routes go live for external clients. --checkpoint-dir DIR
adds warm restarts: the latest checkpoint in DIR is loaded at startup,
and the full server state (engine CSR + cores + window cursor + as-of
ring) is saved on exit — including a SIGTERM/SIGINT drain — so a killed
replay resumes in lockstep (bit-equal cores and message bills; the
per-tick RNG is derived from (seed, tick), never threaded through the
loop).

--mesh N runs the maintenance engine mesh-native on an N-device ("data",)
mesh: the initial decomposition and the per-batch masked supersteps execute
as shard_map programs. If fewer than N real devices exist, N host (CPU)
devices are forced via XLA_FLAGS — which only works because this module
defers every jax import until after the flag is set, so keep --mesh runs to
fresh processes. Cores and message counts are identical to the
single-device engine on any mesh (that equality is CI-tested).
"""

from __future__ import annotations

import argparse
import os
import time


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="EEN",
                    help="SNAP abbrev (Table I) or chain/ba/er")
    ap.add_argument("--scale", type=float, default=0.27)
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--churn", type=float, default=0.01,
                    help="fraction of edges churned per batch")
    ap.add_argument("--queries", type=int, default=100_000,
                    help="core-number lookups per tick")
    ap.add_argument("--frontier", default="dense",
                    choices=["dense", "compact", "sharded", "fused", "auto"],
                    help="engine execution mode; fused = one device-"
                         "resident while_loop per batch (mesh-aware)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="run mesh-native on an N-device ('data',) mesh; "
                         "forces N host devices when fewer exist (must be "
                         "set before jax initializes — fresh process only). "
                         "0 = single device (default)")
    ap.add_argument("--verify", action="store_true",
                    help="check vs the BZ oracle every tick (slow)")
    ap.add_argument("--platform", default=None,
                    choices=["cpu", "gpu", "tpu"],
                    help="select the jax platform (repro.platform)")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="force N host (CPU) devices before backend init "
                         "(repro.platform; like REPRO_HOST_DEVICES)")
    ap.add_argument("--dispatch", default=None,
                    choices=["auto", "pallas", "xla", "on", "off"],
                    help="superstep kernel dispatch (repro.core.dispatch); "
                         "default: the REPRO_PALLAS env var, else auto")
    # temporal replay mode (repro.temporal)
    ap.add_argument("--events", default=None, metavar="SRC",
                    help="replay a timestamped event stream instead of "
                         "synthetic churn: a .npz/text event-log path or "
                         "a generator spec (snap:<ABBREV> | ba | contact)")
    ap.add_argument("--window", type=float, default=2000,
                    help="window size: events (--by count) or time span "
                         "(--by time)")
    ap.add_argument("--stride", type=float, default=500,
                    help="window advance per tick, same unit as --window")
    ap.add_argument("--by", default="count", choices=["count", "time"])
    ap.add_argument("--remove-frac", type=float, default=0.15,
                    help="removal-event fraction for generated traces")
    ap.add_argument("--asof-capacity", type=int, default=16,
                    help="retained window boundaries for core_asof queries")
    ap.add_argument("--concurrent", type=int, default=0, metavar="N",
                    help="serve reads from an N-worker snapshot-isolated "
                         "pool while the single writer re-converges "
                         "(streaming.concurrent); with --listen, also "
                         "mounts live /query/* HTTP routes. 0 = the "
                         "sequential serve loop (default)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="warm restarts: resume from the latest checkpoint "
                         "in DIR at startup (if any) and save the full "
                         "server state there on exit — including a SIGTERM/"
                         "SIGINT drain. A resumed replay continues in "
                         "lockstep: identical batches, cores, and message "
                         "bills to an uninterrupted run")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="enable span tracing and export a Chrome "
                         "trace_event JSON (open in Perfetto)")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the server metrics registry (JSON, incl. "
                         "per-op latency histograms) after the run")
    ap.add_argument("--listen", type=int, default=None, metavar="PORT",
                    help="serve live observability over HTTP while the "
                         "loop runs: /metrics (Prometheus), /healthz "
                         "(invariant-monitor verdict), /debug/flight "
                         "(recent convergence rounds). Implies the flight "
                         "recorder + invariant monitor. 0 = ephemeral port")
    ap.add_argument("--flight", default=None, metavar="OUT.json",
                    help="enable the convergence flight recorder + "
                         "invariant monitor and dump the round ring, "
                         "watch timelines, and health verdict as JSON "
                         "after the run")
    return ap.parse_args()


def _fmt_stats(stats: dict) -> dict:
    """Round the raw-float walls/latencies for the human-readable footer.

    ``KCoreServer.stats()`` reports exact float seconds (a query wall is
    tens of microseconds — rounding at the measurement layer would zero
    it); presentation-side rounding belongs here, at the CLI."""
    def _r(v):
        if isinstance(v, float):
            return round(v, 6)
        if isinstance(v, dict):
            return {k: _r(x) for k, x in v.items()}
        return v

    return {k: _r(v) for k, v in stats.items()}


def _tick_rng(seed: int, tick: int):
    """Per-tick RNG derived from (seed, tick) — NOT one stream threaded
    through the loop — so a run resumed from a checkpoint at tick T draws
    exactly what the uninterrupted run drew at T (lockstep replay; the
    warm-restart test asserts bit-equal cores AND message bills)."""
    import numpy as np
    return np.random.default_rng((int(seed), int(tick)))


def _install_stop():
    """SIGTERM/SIGINT → graceful drain: the serving loop finishes its
    current tick, then checkpoints (with --checkpoint-dir) and exits 0."""
    import signal
    import threading
    stop = threading.Event()

    def _handler(signum, frame):  # noqa: ARG001 - signal API
        if not stop.is_set():
            print(f"# signal {signum}: draining after current tick",
                  flush=True)
        stop.set()

    signal.signal(signal.SIGTERM, _handler)
    signal.signal(signal.SIGINT, _handler)
    return stop


def _maybe_restore(args, server) -> int:
    """Warm restart: load the latest checkpoint in --checkpoint-dir (if
    any) into the freshly constructed server. Returns the tick to resume
    from (the checkpoint's step; 0 = fresh start)."""
    if not args.checkpoint_dir:
        return 0
    from repro.checkpoint import latest_step, restore_checkpoint
    step = latest_step(args.checkpoint_dir)
    if step is None:
        return 0
    state, _ = restore_checkpoint(args.checkpoint_dir,
                                  like=server.state_dict(), step=step)
    server.load_state_dict(state)
    print(f"# resumed: step {step} from {args.checkpoint_dir} "
          f"(m={server.engine.m} max_k={server.max_k()} "
          f"asof_boundaries={len(server.asof_ring)})", flush=True)
    return int(step)


def _front_end(args, server, httpd=None):
    """--concurrent N: wrap the server in the snapshot-isolated threaded
    front end and (with --listen) mount it on the /query/* HTTP routes."""
    if not args.concurrent:
        return None
    from repro.streaming import ConcurrentKCoreServer
    front = ConcurrentKCoreServer(server, read_workers=args.concurrent,
                                  checkpoint_dir=args.checkpoint_dir)
    if httpd is not None:
        httpd.attach_query_backend(front)
        print(f"# obs: /query/* mounted ({args.concurrent} read workers)",
              flush=True)
    return front


def _save_on_exit(args, front, server, tick: int) -> None:
    """Drain the front end and persist full server state for warm restart."""
    if front is not None:
        path = front.drain(save=bool(args.checkpoint_dir), step=tick)
    elif args.checkpoint_dir:
        from repro.checkpoint import save_checkpoint
        path = save_checkpoint(args.checkpoint_dir, int(tick),
                               server.state_dict())
    else:
        return
    if path:
        print(f"# checkpoint: step {tick} -> {path}", flush=True)


def build_graph(args, generators):
    if args.graph == "chain":
        return generators.chain(args.n)
    if args.graph == "ba":
        return generators.barabasi_albert(args.n, 4, seed=args.seed)
    if args.graph == "er":
        return generators.erdos_renyi(args.n, 4 * args.n, seed=args.seed)
    return generators.snap_analogue(args.graph, scale=args.scale,
                                    seed=args.seed)


def build_event_log(args):
    """Resolve --events: a generator spec or an on-disk log."""
    from repro import temporal
    src = args.events
    if src.startswith("snap:"):
        return temporal.temporal_snap_analogue(
            src.split(":", 1)[1], scale=args.scale, seed=args.seed,
            remove_frac=args.remove_frac)
    if src == "ba":
        return temporal.temporal_barabasi_albert(
            args.n, 4, seed=args.seed, remove_frac=args.remove_frac)
    if src == "contact":
        return temporal.contact_bursts(args.n, seed=args.seed)
    return temporal.load_event_log(src)


def replay_serve(args, mesh, httpd=None) -> None:
    """Temporal replay loop: window advances + query load + as-of probes."""
    import numpy as np

    from repro.core import kcore_decompose
    from repro.streaming import KCoreServer, Request, StreamingConfig
    from repro.temporal import WindowedKCoreEngine, check_step

    log = build_event_log(args)
    t0 = time.perf_counter()
    weng = WindowedKCoreEngine(log, args.window, args.stride, by=args.by,
                               config=StreamingConfig(
                                   frontier=args.frontier),
                               mesh=mesh)
    server = KCoreServer(windowed=weng, asof_capacity=args.asof_capacity)
    if httpd is not None:
        httpd.add_registry(server.metrics)
    start_tick = _maybe_restore(args, server)
    front = _front_end(args, server, httpd=httpd)
    stop = _install_stop()
    print(f"# events={args.events} n={log.n} log_events={len(log)} "
          f"adds={log.num_adds} window={args.window} stride={args.stride} "
          f"by={args.by} mesh={args.mesh or 1} frontier={args.frontier} "
          f"init_wall_s={time.perf_counter() - t0:.2f}", flush=True)

    print("tick,t_hi,m,inserted,deleted,inc_messages,scratch_messages,"
          "ratio,rounds,mode,patch_s,compactions,occupancy,queries,query_s,"
          "max_k,asof_t,verified", flush=True)
    tick = start_tick
    while not weng.done and tick < args.batches and not stop.is_set():
        rng = _tick_rng(args.seed, tick)
        ws = (front.advance_window() if front is not None
              else server.advance_window())
        res = ws.result

        qids = rng.integers(0, log.n, size=args.queries)
        asof_t = float(rng.choice(server.asof_boundaries()))
        reqs = [Request(op="core", vertices=qids),
                Request(op="in_kcore", vertices=qids[: args.queries // 2],
                        k=max(server.max_k() - 1, 1)),
                Request(op="core_asof", t=asof_t,
                        vertices=qids[: args.queries // 2]),
                Request(op="max_k")]
        t0 = time.perf_counter()
        if front is not None:
            front.serve_concurrent(reqs)
        else:
            server.serve(reqs)
        query_s = time.perf_counter() - t0

        wg = weng.window_graph()
        scratch = kcore_decompose(wg)
        verified = ""
        if args.verify:
            verified = str(check_step(weng, ws))
        ratio = res.total_messages / max(scratch.stats.total_messages, 1)
        print(",".join(str(c) for c in (
            tick, round(ws.t_hi, 3), ws.m, res.delta.inserted.shape[0],
            res.delta.deleted.shape[0], res.total_messages,
            scratch.stats.total_messages, round(ratio, 4), res.rounds,
            res.mode, round(res.patch_s, 5), res.csr_compactions,
            round(res.csr_occupancy, 3), args.queries, round(query_s, 4),
            server.max_k(), round(asof_t, 3), verified)), flush=True)
        tick += 1

    print(f"# asof_boundaries={np.round(server.asof_boundaries(), 3).tolist()}")
    stats = front.stats() if front is not None else server.stats()
    print(f"# final_stats={_fmt_stats(stats)}")
    _save_on_exit(args, front, server, tick)
    _finish_obs(args, server)


def _finish_obs(args, server) -> None:
    """Shared --trace/--metrics/--flight tail of both serving loops."""
    if args.trace:
        from repro.obs import trace
        trace.export(args.trace)
        print(f"# trace: {args.trace} ({len(trace.events())} events)")
    if args.metrics:
        import json as _json
        print(_json.dumps({"server_metrics": server.metrics.to_json()},
                          indent=1))
    if args.flight:
        import json as _json

        from repro.obs import flight, health
        payload = flight.to_json()
        payload["health"] = health.verdict()
        with open(args.flight, "w") as f:
            _json.dump(payload, f)
        print(f"# flight: {args.flight} "
              f"(runs={payload['runs']} rounds={payload['rounds_recorded']} "
              f"health={payload['health']['status']})")


def main() -> None:
    args = parse_args()
    # platform layer first: env-driven config plus the CLI flags, all of
    # which must precede the first jax backend init in the process
    from repro import platform
    platform.configure_from_env()
    if args.platform:
        platform.set_platform(args.platform)
    if args.devices:
        platform.force_host_device_count(args.devices)
    if args.dispatch:
        platform.set_dispatch_mode(args.dispatch)
    if args.mesh:
        # must precede the first jax import anywhere in the process
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={args.mesh}"
        ).strip()

    # live observability starts BEFORE the heavy jax init below, so
    # external pollers can already reach /healthz while the backend and
    # the initial decomposition warm up (repro.obs is stdlib+numpy only)
    httpd = None
    if args.listen is not None or args.flight:
        from repro.obs import flight, health
        flight.enable()
        health.install()
        if args.listen is not None:
            from repro.obs.http import start_server
            httpd = start_server(port=args.listen)
            print(f"# obs: listening on {httpd.url} "
                  "(/metrics /healthz /debug/flight)", flush=True)

    import numpy as np

    from repro.core import bz_core_numbers, kcore_decompose
    from repro.graph import generators
    from repro.streaming import (KCoreServer, Request, StreamingConfig,
                                 random_churn_batch)

    mesh = None
    if args.mesh:
        from repro.distribution.compat import make_mesh
        mesh = make_mesh((args.mesh,), ("data",))
        if args.frontier == "dense":
            args.frontier = "sharded"

    if args.trace:
        from repro.obs import trace
        trace.enable()

    if args.events:
        replay_serve(args, mesh, httpd=httpd)
        return

    g = build_graph(args, generators)
    t0 = time.perf_counter()
    server = KCoreServer(g, StreamingConfig(frontier=args.frontier),
                         mesh=mesh)
    if httpd is not None:
        httpd.add_registry(server.metrics)
    print(f"# graph={args.graph} n={g.n} m={g.m} mesh={args.mesh or 1} "
          f"frontier={args.frontier} "
          f"init_messages={server.engine.init_result.stats.total_messages} "
          f"init_wall_s={time.perf_counter() - t0:.2f}", flush=True)
    start_tick = _maybe_restore(args, server)
    front = _front_end(args, server, httpd=httpd)
    stop = _install_stop()

    cols = ("tick,m,inserted,deleted,inc_messages,scratch_messages,ratio,"
            "rounds,region,seed_changed,mode,patch_s,queries,query_s,max_k,"
            "verified")
    print(cols, flush=True)
    tick = start_tick
    while tick < args.batches and not stop.is_set():
        rng = _tick_rng(args.seed, tick)
        b = max(2, int(args.churn * server.engine.graph.m))
        batch = random_churn_batch(server.engine.graph, b // 2, b - b // 2,
                                   rng)
        res = front.update(batch) if front is not None \
            else server.update(batch)

        # query load: batched core-number lookups + membership/max-k probes
        n = server.engine.graph.n
        qids = rng.integers(0, n, size=args.queries)
        reqs = [Request(op="core", vertices=qids),
                Request(op="in_kcore", vertices=qids[: args.queries // 2],
                        k=max(server.max_k() - 1, 1)),
                Request(op="members", k=server.max_k()),
                Request(op="max_k")]
        t0 = time.perf_counter()
        if front is not None:
            front.serve_concurrent(reqs)
        else:
            server.serve(reqs)
        query_s = time.perf_counter() - t0

        scratch = kcore_decompose(server.engine.graph)
        verified = ""
        if args.verify:
            ok = bool((res.core == bz_core_numbers(server.engine.graph)).all())
            verified = str(ok)
            assert ok, "incremental cores diverged from the BZ oracle!"
        ratio = res.total_messages / max(scratch.stats.total_messages, 1)
        print(",".join(str(c) for c in (
            tick, server.engine.graph.m, res.delta.inserted.shape[0],
            res.delta.deleted.shape[0], res.total_messages,
            scratch.stats.total_messages, round(ratio, 4), res.rounds,
            res.region_size, res.seed_changed, res.mode,
            round(res.patch_s, 5), args.queries,
            round(query_s, 4), server.max_k(), verified)), flush=True)
        tick += 1

    stats = front.stats() if front is not None else server.stats()
    print(f"# final_stats={_fmt_stats(stats)}")
    _save_on_exit(args, front, server, tick)
    _finish_obs(args, server)


if __name__ == "__main__":
    main()
