"""Timestamped edge-event logs — the temporal input format.

The paper evaluates static snapshots, but real SNAP graphs arrive as
timestamped edge events (the streaming/parallel k-core line in PAPERS.md
studies exactly this regime). An ``EventLog`` is the columnar form of such
a stream: parallel numpy arrays (time, u, v, kind) sorted by time, where
kind is +1 (add) or -1 (remove).

dataCleanse rules at construction (mirroring graph/structs.Graph):

  * self-loop events are dropped — they can never affect any window;
  * endpoints are stored canonically as (min, max) — the stream is
    undirected;
  * duplicate events are KEPT (unlike Graph edges): an add of an edge that
    is already present, or a remove of one that is absent, is a legal
    no-op at materialization time. The graph of any event range is defined
    by replaying the range onto an empty graph under set semantics —
    equivalently, an edge is present iff its LAST event in the range is an
    add (``edges_between``).

On-disk formats (graph/io.py-style loaders):

  * text — one event per line, ``t u v +`` / ``t u v -``, ``#`` comments;
  * npz  — the columnar arrays verbatim plus the vertex universe ``n``.

Trace generators at the bottom produce realistic temporal workloads:
timestamped preferential attachment, contact-network bursts, and
``temporal_snap_analogue`` which assigns growth-ordered, heavy-tailed
inter-arrival times to the existing SNAP analogues (graph/generators.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph import generators as gen
from repro.graph.structs import Graph

ADD = np.int8(1)
REMOVE = np.int8(-1)


@dataclasses.dataclass(frozen=True)
class EdgeEvent:
    """One timestamped edge event (scalar view into an EventLog)."""

    t: float
    u: int
    v: int
    kind: int                 # +1 add, -1 remove

    @property
    def is_add(self) -> bool:
        return self.kind > 0


@dataclasses.dataclass(frozen=True)
class EventLog:
    """Columnar timestamped edge-event stream, sorted by time."""

    time: np.ndarray          # (E,) float64 — monotone non-decreasing
    u: np.ndarray             # (E,) int64   — canonical u < v
    v: np.ndarray             # (E,) int64
    kind: np.ndarray          # (E,) int8    — +1 add, -1 remove
    n: int                    # vertex universe (fixed over the stream)

    # ------------------------------------------------------------------ #
    @classmethod
    def make(cls, time, u, v, kind, n: int | None = None) -> "EventLog":
        """dataCleanse + canonicalize a raw event stream.

        Events must already be in time order (monotone non-decreasing);
        self-loops are dropped, endpoints canonicalized to (min, max).
        """
        time = np.asarray(time, np.float64).reshape(-1)
        u = np.asarray(u, np.int64).reshape(-1)
        v = np.asarray(v, np.int64).reshape(-1)
        kind = np.asarray(kind, np.int8).reshape(-1)
        if not (time.shape == u.shape == v.shape == kind.shape):
            raise ValueError("event columns must have equal length")
        if time.size and (np.diff(time) < 0).any():
            raise ValueError("event timestamps must be non-decreasing")
        if u.size and min(u.min(), v.min()) < 0:
            raise ValueError("negative vertex id in event log")
        if not np.isin(kind, (ADD, REMOVE)).all():
            raise ValueError("event kind must be +1 (add) or -1 (remove)")
        keep = u != v
        time, u, v, kind = time[keep], u[keep], v[keep], kind[keep]
        uu, vv = np.minimum(u, v), np.maximum(u, v)
        nn = int(n) if n is not None else (int(vv.max()) + 1 if vv.size
                                           else 0)
        if vv.size and vv.max() >= nn:
            raise ValueError(f"vertex id {int(vv.max())} outside universe "
                             f"n={nn}")
        return cls(time=time, u=uu, v=vv, kind=kind, n=nn)

    @classmethod
    def from_events(cls, events, n: int | None = None) -> "EventLog":
        """Build from an iterable of EdgeEvent / (t, u, v, kind) tuples."""
        rows = [(e.t, e.u, e.v, e.kind) if isinstance(e, EdgeEvent) else e
                for e in events]
        arr = (np.asarray(rows, np.float64).reshape(-1, 4) if rows
               else np.zeros((0, 4)))
        return cls.make(arr[:, 0], arr[:, 1], arr[:, 2],
                        arr[:, 3].astype(np.int8), n=n)

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return int(self.time.shape[0])

    def __getitem__(self, i: int) -> EdgeEvent:
        return EdgeEvent(t=float(self.time[i]), u=int(self.u[i]),
                         v=int(self.v[i]), kind=int(self.kind[i]))

    @property
    def t_min(self) -> float:
        return float(self.time[0]) if len(self) else 0.0

    @property
    def t_max(self) -> float:
        return float(self.time[-1]) if len(self) else 0.0

    @property
    def num_adds(self) -> int:
        return int((self.kind > 0).sum())

    def index_at_time(self, t: float) -> int:
        """Number of events with time < t (window boundaries use [lo, hi))."""
        return int(np.searchsorted(self.time, t, side="left"))

    # ------------------------------------------------------------------ #
    def edges_between(self, lo: int, hi: int) -> np.ndarray:
        """Canonical (k, 2) edge set of event range [lo, hi).

        Defined by replay-from-empty under set semantics; since an add
        forces presence and a remove forces absence regardless of prior
        state, an edge is present iff its last event in the range is an
        add.
        """
        lo, hi = max(int(lo), 0), min(int(hi), len(self))
        if hi <= lo:
            return np.zeros((0, 2), np.int64)
        uu, vv, kk = self.u[lo:hi], self.v[lo:hi], self.kind[lo:hi]
        key = uu * np.int64(self.n) + vv
        # stable sort by key keeps time order within a key; last wins
        order = np.argsort(key, kind="stable")
        key_s = key[order]
        last = np.flatnonzero(np.append(key_s[1:] != key_s[:-1], True))
        sel = order[last][kk[order[last]] > 0]
        edges = np.stack([uu[sel], vv[sel]], axis=1)
        return edges[np.lexsort((edges[:, 1], edges[:, 0]))]

    def graph_between(self, lo: int, hi: int) -> Graph:
        """Materialize the Graph of event range [lo, hi) on the full
        vertex universe."""
        return Graph.from_edges(self.edges_between(lo, hi), n=self.n)

    # ------------------------------------------------------------------ #
    # IO — graph/io.py-style text + columnar npz
    # ------------------------------------------------------------------ #
    def to_text(self) -> str:
        lines = [f"# temporal edge-event log n={self.n} events={len(self)}"]
        for i in range(len(self)):
            mark = "+" if self.kind[i] > 0 else "-"
            lines.append(f"{self.time[i]:.6f}\t{self.u[i]}\t{self.v[i]}"
                         f"\t{mark}")
        return "\n".join(lines) + "\n"

    def save_npz(self, path: str) -> None:
        # np.savez appends .npz when missing; normalize up front so the
        # path handed back to load_event_log always takes the npz branch
        if not str(path).endswith(".npz"):
            path = f"{path}.npz"
        np.savez(path, time=self.time, u=self.u, v=self.v, kind=self.kind,
                 n=np.int64(self.n))


def parse_event_text(text: str, n: int | None = None) -> EventLog:
    """Parse the text format: ``t u v +|-`` per line, ``#`` comments.

    A missing kind column means add (a plain timestamped edge list is a
    valid all-arrivals log); a present one must be ``+`` or ``-`` — any
    other token is rejected rather than silently treated as an add."""
    time, u, v, kind = [], [], [], []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith(("#", "%")):
            continue
        parts = line.replace(",", " ").split()
        time.append(float(parts[0]))
        u.append(int(parts[1]))
        v.append(int(parts[2]))
        if len(parts) > 3:
            if parts[3] not in ("+", "-"):
                raise ValueError(f"bad event kind {parts[3]!r} in line "
                                 f"{line!r} (want + or -)")
            kind.append(REMOVE if parts[3] == "-" else ADD)
        else:
            kind.append(ADD)
    return EventLog.make(time, u, v, kind, n=n)


def load_event_log(path: str, n: int | None = None) -> EventLog:
    """Load an event log from .npz (columnar) or text (edge-event lines)."""
    if str(path).endswith(".npz"):
        with np.load(path) as z:
            return EventLog.make(z["time"], z["u"], z["v"], z["kind"],
                                 n=int(z["n"]) if n is None else n)
    with open(path) as f:
        return parse_event_text(f.read(), n=n)


# ---------------------------------------------------------------------- #
# Temporal trace generators
# ---------------------------------------------------------------------- #

def _heavy_tail_dt(rng: np.random.Generator, size: int,
                   mean_dt: float) -> np.ndarray:
    """Lognormal inter-arrival times (sigma=1): bursty but integrable,
    normalized to the requested mean."""
    dt = rng.lognormal(mean=0.0, sigma=1.0, size=size)
    return dt * (mean_dt / max(dt.mean(), 1e-12))

def _with_removals(time, uu, vv, rng, remove_frac: float,
                   mean_lifetime: float):
    """Give a ``remove_frac`` subset of arrivals an exponential-lifetime
    removal event; merge and re-sort by time (stable, so an edge's remove
    stays after its add under equal timestamps)."""
    kind = np.full(time.shape[0], ADD, np.int8)
    if remove_frac <= 0 or time.size == 0:
        return time, uu, vv, kind
    sel = np.flatnonzero(rng.random(time.shape[0]) < remove_frac)
    rt = time[sel] + rng.exponential(mean_lifetime, size=sel.size)
    time = np.concatenate([time, rt])
    uu = np.concatenate([uu, uu[sel]])
    vv = np.concatenate([vv, vv[sel]])
    kind = np.concatenate([kind, np.full(sel.size, REMOVE, np.int8)])
    order = np.argsort(time, kind="stable")
    return time[order], uu[order], vv[order], kind[order]


def temporal_barabasi_albert(n: int, m_attach: int, seed: int = 0,
                             mean_dt: float = 1.0,
                             remove_frac: float = 0.0,
                             mean_lifetime: float | None = None) -> EventLog:
    """Timestamped preferential attachment.

    The BA analogue's edges already carry an arrival order (vertex v joins
    at step v and attaches); we realize it as an event stream with
    heavy-tailed inter-arrival times. ``remove_frac`` of the arrivals get
    an exponential-lifetime removal event (link decay)."""
    g = gen.barabasi_albert(n, m_attach, seed=seed)
    half = g.src < g.dst
    uu = g.src[half].astype(np.int64)
    vv = g.dst[half].astype(np.int64)
    # attachment order: the joining endpoint is the larger id
    order = np.argsort(np.maximum(uu, vv), kind="stable")
    uu, vv = uu[order], vv[order]
    rng = np.random.default_rng(seed + 1)
    time = np.cumsum(_heavy_tail_dt(rng, uu.shape[0], mean_dt))
    if mean_lifetime is None:
        mean_lifetime = 0.25 * float(time[-1]) if time.size else 1.0
    return EventLog.make(*_with_removals(time, uu, vv, rng, remove_frac,
                                         mean_lifetime), n=n)


def contact_bursts(n: int, n_bursts: int = 40, group_size: int = 12,
                   edges_per_burst: int = 30, burst_len: float = 5.0,
                   gap: float = 2.0, seed: int = 0) -> EventLog:
    """Contact-network bursts: a random group meets, its contact edges
    appear spread over the burst, and every contact is torn down at the
    burst's end — a heavily add/remove-churned stream with frequent
    re-insertion of recurring contacts."""
    rng = np.random.default_rng(seed)
    time, uu, vv, kind = [], [], [], []
    t0 = 0.0
    for _ in range(n_bursts):
        group = rng.choice(n, size=min(group_size, n), replace=False)
        a = group[rng.integers(0, group.size, size=edges_per_burst)]
        b = group[rng.integers(0, group.size, size=edges_per_burst)]
        keep = a != b
        a, b = a[keep], b[keep]
        at = t0 + np.sort(rng.random(a.size)) * burst_len
        time.append(at)
        uu.append(a)
        vv.append(b)
        kind.append(np.full(a.size, ADD, np.int8))
        # teardown: every contact of the burst removed at the burst end
        end = t0 + burst_len
        time.append(np.full(a.size, end))
        uu.append(a)
        vv.append(b)
        kind.append(np.full(a.size, REMOVE, np.int8))
        t0 = end + rng.exponential(gap)
    time = np.concatenate(time) if time else np.zeros(0)
    order = np.argsort(time, kind="stable")
    return EventLog.make(time[order], np.concatenate(uu)[order],
                         np.concatenate(vv)[order],
                         np.concatenate(kind)[order], n=n)


def temporal_snap_analogue(abbrev: str, scale: float = 1.0, seed: int = 0,
                           mean_dt: float = 1.0,
                           remove_frac: float = 0.0,
                           mean_lifetime: float | None = None) -> EventLog:
    """Temporal realization of a Table-I SNAP analogue.

    Takes the static analogue's edge set (graph/generators.snap_analogue)
    and assigns realistic arrival dynamics: growth order (an edge arrives
    roughly when its younger endpoint joins, with jitter, matching how the
    social/web originals accreted) and heavy-tailed inter-arrival times.
    ``remove_frac`` turns a subset into add+remove pairs (unfriend /
    link-decay events), exercising deletions inside windows."""
    g = gen.snap_analogue(abbrev, scale=scale, seed=seed)
    half = g.src < g.dst
    uu = g.src[half].astype(np.int64)
    vv = g.dst[half].astype(np.int64)
    rng = np.random.default_rng(seed + 2)
    # growth order with jitter: rank by younger endpoint, perturbed so the
    # stream is not a clean vertex-id sort (real timestamps are noisy)
    rank = np.maximum(uu, vv) + rng.normal(0.0, 0.05 * max(g.n, 1),
                                           size=uu.shape[0])
    order = np.argsort(rank, kind="stable")
    uu, vv = uu[order], vv[order]
    time = np.cumsum(_heavy_tail_dt(rng, uu.shape[0], mean_dt))
    if mean_lifetime is None:
        mean_lifetime = 0.25 * float(time[-1]) if time.size else 1.0
    return EventLog.make(*_with_removals(time, uu, vv, rng, remove_frac,
                                         mean_lifetime), n=g.n)
