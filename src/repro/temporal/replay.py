"""Replay driver: windowed stream -> core-evolution trajectory.

Drives a ``WindowedKCoreEngine`` over a full ``EventLog`` and records one
``ReplayRecord`` per window advance: the per-step ``BatchResult`` stats
(message bill, rounds, frontier sizes, execution mode, CSR patch health)
plus core-evolution signals (max/mean core, tracked-vertex core series).
``oracle_every=k`` cross-checks every k-th boundary — cores against the
sequential BZ oracle on an independently materialized window graph, and
the engine's maintained edge set against ``EventLog.edges_between`` — so a
long replay cannot silently drift.

This is the paper-faithful temporal workload: instead of synthetic uniform
churn (benchmarks/streaming_maintenance.py), batches are whatever the
timestamped stream actually did in each stride.
"""

from __future__ import annotations

import dataclasses
import time as _time

import numpy as np

from repro.core.bz import bz_core_numbers
from repro.core.kcore import KCoreConfig
from repro.core.messages import heartbeat_overhead
from repro.obs import flight as _flight
from repro.obs import health as _health
from repro.streaming.engine import StreamingConfig
from repro.temporal.events import EventLog
from repro.temporal.window import WindowedKCoreEngine, WindowStep


@dataclasses.dataclass(frozen=True)
class ReplayRecord:
    """Per-step scalars of one window advance (flat — CSV/JSON-ready)."""

    step: int
    lo: int
    hi: int
    t_lo: float
    t_hi: float
    m: int                    # window graph edges after the step
    inserted: int
    deleted: int
    messages: int
    rounds: int
    frontier_peak: int        # max active vertices in any round
    region: int
    mode: str
    patch_ms: float
    step_ms: float            # wall time of the whole advance
    # remaining per-phase walls of the underlying batch (engine-measured,
    # same boundaries as the trace spans; patch+seed+converge+reconstruct
    # ~= the batch's share of step_ms)
    seed_ms: float = 0.0
    converge_ms: float = 0.0
    reconstruct_ms: float = 0.0
    # modeled termination-detection bill for this step's re-convergence
    # (core.messages.heartbeat_overhead at round granularity)
    heartbeats: int = 0
    recompiles: int = 0       # fresh XLA compilations this step caused
    csr_compactions: int = 0
    csr_dead_frac: float = 0.0
    csr_occupancy: float = 0.0
    core_max: int = 0
    core_mean: float = 0.0
    oracle_ok: bool | None = None   # None = not checked this step
    # flight-recorder join (zeros/None when recording is disabled):
    flight_rounds: int = 0          # rounds the recorder captured this step
    health_ok: bool | None = None   # invariant-monitor verdict so far


@dataclasses.dataclass
class ReplayTrajectory:
    """A replayed stream's core-evolution time series."""

    records: list[ReplayRecord]
    tracked: np.ndarray       # (T,) vertex ids with a full core time series
    core_series: np.ndarray   # (steps, T) int32 — tracked cores per step

    def series(self, field: str) -> np.ndarray:
        """One record field as a (steps,) array."""
        return np.asarray([getattr(r, field) for r in self.records])

    @property
    def total_messages(self) -> int:
        return int(self.series("messages").sum())

    def summary(self) -> dict:
        if not self.records:
            return {"steps": 0}
        msgs = self.series("messages")
        return {
            "steps": len(self.records),
            "total_messages": int(msgs.sum()),
            "mean_messages": round(float(msgs.mean()), 1),
            "mean_rounds": round(float(self.series("rounds").mean()), 2),
            "mean_m": round(float(self.series("m").mean()), 1),
            "max_core_seen": int(self.series("core_max").max()),
            "mean_patch_ms": round(float(self.series("patch_ms").mean()), 3),
            "mean_seed_ms": round(float(self.series("seed_ms").mean()), 3),
            "mean_converge_ms": round(
                float(self.series("converge_ms").mean()), 3),
            "mean_reconstruct_ms": round(
                float(self.series("reconstruct_ms").mean()), 3),
            "mean_step_ms": round(float(self.series("step_ms").mean()), 3),
            "total_heartbeats": int(self.series("heartbeats").sum()),
            "recompiles": int(self.series("recompiles").sum()),
            "oracle_checks": int(sum(r.oracle_ok is not None
                                     for r in self.records)),
            "compactions": int(self.records[-1].csr_compactions),
        }


def record_step(ws: WindowStep, wall_s: float,
                oracle_ok: bool | None) -> ReplayRecord:
    """Flatten one WindowStep into a ReplayRecord."""
    res = ws.result
    actives = res.stats.active_per_round
    core = res.core
    hb = heartbeat_overhead(res.stats)
    rec = _flight.recorder()
    flight_rounds = rec.last_run_rounds if rec.active else 0
    health_ok = _health.get_monitor().ok if rec.active else None
    return ReplayRecord(
        step=ws.step, lo=ws.lo, hi=ws.hi,
        t_lo=round(ws.t_lo, 6), t_hi=round(ws.t_hi, 6), m=ws.m,
        inserted=int(res.delta.inserted.shape[0]),
        deleted=int(res.delta.deleted.shape[0]),
        messages=int(res.total_messages), rounds=int(res.rounds),
        frontier_peak=int(actives.max()) if actives.size else 0,
        region=int(res.region_size), mode=res.mode,
        patch_ms=round(res.patch_s * 1e3, 3),
        step_ms=round(wall_s * 1e3, 3),
        seed_ms=round(res.seed_s * 1e3, 3),
        converge_ms=round(res.converge_s * 1e3, 3),
        reconstruct_ms=round(res.reconstruct_s * 1e3, 3),
        heartbeats=int(hb["heartbeat_messages"]),
        recompiles=int(res.recompiles),
        csr_compactions=int(res.csr_compactions),
        csr_dead_frac=round(res.csr_dead_frac, 4),
        csr_occupancy=round(res.csr_occupancy, 4),
        core_max=int(core.max()) if core.size else 0,
        core_mean=round(float(core.mean()), 4) if core.size else 0.0,
        oracle_ok=oracle_ok,
        flight_rounds=flight_rounds,
        health_ok=health_ok,
    )


def check_step(weng: WindowedKCoreEngine, ws: WindowStep) -> bool:
    """BZ-oracle + edge-set cross-check of one boundary (raises on
    divergence; returns True so callers can record the check happened).

    Explicit raises, not asserts: --verify must keep verifying under
    ``python -O``."""
    wg = weng.window_graph()
    ref = weng.log.edges_between(ws.lo, ws.hi)
    if not (weng.window_edges.shape == ref.shape
            and (weng.window_edges == ref).all()):
        raise AssertionError(
            f"step {ws.step}: maintained window edge set diverged from "
            "EventLog.edges_between")
    eng_g = weng.engine.graph
    if not (eng_g.m == wg.m and (eng_g.src == wg.src).all()
            and (eng_g.dst == wg.dst).all()):
        raise AssertionError(
            f"step {ws.step}: engine graph != materialized window graph")
    if not (ws.result.core == bz_core_numbers(wg)).all():
        raise AssertionError(
            f"step {ws.step}: windowed cores diverged from the BZ oracle")
    return True


def replay(log: EventLog, window, stride, by: str = "count",
           config: StreamingConfig = StreamingConfig(),
           kcore_config: KCoreConfig = KCoreConfig(),
           mesh=None, axis_names=("data",),
           oracle_every: int = 0, track=None,
           max_steps: int | None = None) -> ReplayTrajectory:
    """Replay a whole event stream through a sliding window.

    ``oracle_every=k`` BZ-verifies every k-th boundary plus the final one
    (0 = never). ``track`` selects vertices whose core time series is kept
    per step: an int means "that many evenly spaced ids", an array means
    those ids, None tracks nothing.
    """
    weng = WindowedKCoreEngine(log, window, stride, by=by, config=config,
                               kcore_config=kcore_config, mesh=mesh,
                               axis_names=axis_names)
    if track is None:
        tracked = np.zeros(0, np.int64)
    elif np.isscalar(track):
        tracked = np.unique(np.linspace(0, max(log.n - 1, 0),
                                        int(track)).astype(np.int64))
    else:
        tracked = np.asarray(track, np.int64).reshape(-1)

    records: list[ReplayRecord] = []
    series: list[np.ndarray] = []
    while not weng.done and (max_steps is None
                             or weng.steps_taken < max_steps):
        t0 = _time.perf_counter()
        ws = weng.advance()
        wall_s = _time.perf_counter() - t0
        oracle_ok = None
        last = weng.done or (max_steps is not None
                             and weng.steps_taken >= max_steps)
        if oracle_every and (ws.step % oracle_every == 0 or last):
            oracle_ok = check_step(weng, ws)
        records.append(record_step(ws, wall_s, oracle_ok))
        if tracked.size:
            series.append(ws.result.core[tracked].copy())
    core_series = (np.stack(series) if series
                   else np.zeros((len(records), tracked.size), np.int32))
    return ReplayTrajectory(records=records, tracked=tracked,
                            core_series=core_series)
