"""Sliding-window k-core maintenance over a timestamped event stream.

``WindowedKCoreEngine`` slides a window over an ``EventLog`` and turns each
advance into one ``EdgeBatch`` for the PR-1/2 ``StreamingKCoreEngine``:
events entering at the head whose edges become present are inserts, edges
expiring out of the tail (or removed by in-window remove events) are
deletes. The engine therefore maintains EXACT core numbers of the window
graph at every boundary — the window semantics are defined by
``EventLog.edges_between`` (replay-from-empty / last-event-wins), and the
batch fed downstream is precisely the set difference between consecutive
window edge sets, so advancing by k strides is equivalent to applying one
explicit EdgeBatch (property-tested in tests/test_temporal.py).

Two window kinds, both with configurable stride:

  * ``by="count"`` — the window covers the last ``window`` events; a stride
    admits ``stride`` new events (uniform event-rate slicing);
  * ``by="time"``  — the window covers timestamps in [t_hi - window, t_hi);
    a stride advances t_hi by ``stride`` (wall-clock slicing; steps see as
    many events as actually arrived).

The vertex universe is fixed to ``log.n`` up front so core vectors are
comparable across the whole replay (an absent vertex has core 0), and all
streaming frontier modes (dense/compact/sharded/fused/auto, optional
mesh) pass straight through to the maintenance engine. The window size
also pre-seeds the engine's padded-shape floors (CSR slack and
``min_arc_capacity``) so a replay-from-empty neither compacts per insert
nor recompiles its jitted programs at every pow2 size on the way up.

The as-of store (``CoreCheckpointRing``: a bounded ring of (t, core)
snapshots pushed at window boundaries, answering "core numbers at time t"
in O(1) for any retained boundary) lives with the serving layer in
streaming/server.py; re-exported from ``repro.temporal`` for convenience.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.kcore import KCoreConfig
from repro.graph.structs import Graph
from repro.obs import flight as _flight
from repro.obs import trace as _trace
from repro.streaming.delta import EdgeBatch, edge_keys
from repro.streaming.engine import (BatchResult, StreamingConfig,
                                    StreamingKCoreEngine)
from repro.temporal.events import EventLog

WINDOW_KINDS = ("count", "time")


@dataclasses.dataclass(frozen=True)
class WindowStep:
    """Outcome of one window advance."""

    step: int                 # 0-based advance index
    lo: int                   # event index range [lo, hi) of the window
    hi: int
    t_lo: float               # timestamps covered by the window
    t_hi: float
    batch: EdgeBatch          # the delta fed to the streaming engine
    result: BatchResult       # its outcome (exact cores, stats, health)
    m: int                    # edges in the window graph after the step

    @property
    def core(self) -> np.ndarray:
        return self.result.core


class WindowedKCoreEngine:
    """Exact k-core maintenance of a sliding window over an EventLog."""

    def __init__(self, log: EventLog, window, stride, by: str = "count",
                 config: StreamingConfig = StreamingConfig(),
                 kcore_config: KCoreConfig = KCoreConfig(),
                 mesh=None, axis_names=("data",)):
        if by not in WINDOW_KINDS:
            raise ValueError(f"unknown window kind {by!r}")
        if window <= 0 or stride <= 0:
            raise ValueError("window and stride must be positive")
        if by == "count":
            # count mode truncates to whole events; a fractional stride
            # would truncate to 0 and the window would never advance
            if int(window) < 1 or int(stride) < 1:
                raise ValueError("count-based window and stride must be "
                                 ">= 1 event")
            window, stride = int(window), int(stride)
        self.log = log
        self.by = by
        self.window = window
        self.stride = stride
        self.n = log.n
        # The engine starts on an EMPTY graph, so degree-proportional CSR
        # slack would size every row at min_slack and the first windows
        # would compact on almost every insert. Bump min_slack to the mean
        # degree the window will actually carry (slack never changes cores
        # or message bills — only patch cost).
        if self.n:
            if by == "count":
                w_events = float(window)
            else:
                span = max(log.t_max - log.t_min, 1e-12)
                w_events = float(window) / span * max(len(log), 1)
            est = int(np.ceil(3.0 * min(w_events, len(log))
                              / max(self.n, 1)))
            if est > config.min_slack:
                config = dataclasses.replace(config, min_slack=est)
            # pre-seed the engine's padded live-arc shape to the expected
            # window load (2 arcs per event over-counts removes — padding
            # only), so the replay's jitted programs compile at the steady
            # shape on step 0 instead of once per pow2 size on the way up
            cap_floor = int(2 * min(w_events, len(log)))
            if cap_floor > config.min_arc_capacity:
                config = dataclasses.replace(config,
                                             min_arc_capacity=cap_floor)
        self.config = config
        empty = Graph.from_edges(np.zeros((0, 2), np.int64), n=self.n)
        self.engine = StreamingKCoreEngine(empty, config, kcore_config,
                                           mesh=mesh, axis_names=axis_names)
        # cursor: hi event index (count) / t_hi timestamp (time); the
        # window starts empty and slides in from the stream's beginning
        self._hi = 0
        self._t_hi = log.t_min
        self._edges = np.zeros((0, 2), np.int64)
        self._edges.setflags(write=False)
        self.steps_taken = 0

    # ------------------------------------------------------------------ #
    @property
    def core(self) -> np.ndarray:
        """Exact core numbers of the current window graph."""
        return self.engine.core

    @property
    def bounds(self) -> tuple[int, int]:
        """Current window as an event index range [lo, hi)."""
        if self.by == "count":
            hi = min(self._hi, len(self.log))
            return max(0, hi - int(self.window)), hi
        lo = self.log.index_at_time(self._t_hi - self.window)
        return lo, self.log.index_at_time(self._t_hi)

    @property
    def t_bounds(self) -> tuple[float, float]:
        """Current window's time span [t_lo, t_hi)."""
        if self.by == "time":
            return float(self._t_hi - self.window), float(self._t_hi)
        lo, hi = self.bounds
        t_lo = float(self.log.time[lo]) if hi > lo else float(self._t_hi)
        t_hi = float(self.log.time[hi - 1]) if hi > lo else float(self._t_hi)
        return t_lo, t_hi

    @property
    def window_edges(self) -> np.ndarray:
        """Canonical (m, 2) edge set of the current window (read-only —
        the delta bookkeeping diffs against it; callers copy to mutate)."""
        return self._edges

    @property
    def done(self) -> bool:
        """True once the window head has consumed the whole stream."""
        if self.by == "count":
            return self._hi >= len(self.log)
        return self._t_hi > self.log.t_max

    # ------------------------------------------------------------------ #
    def state_dict(self) -> dict:
        """Checkpointable pytree: inner engine state + window position.

        The EventLog itself is NOT captured — it is an input, deterministic
        from its source (path or generator spec + seed), and typically far
        larger than the engine state. A restore therefore needs the same
        log the checkpointed run was replaying (kcore_serve rebuilds it
        from the --events spec) and resumes the replay in lockstep:
        identical window batches, cores, and message bills.
        """
        return {
            "engine": self.engine.state_dict(),
            "hi": np.asarray(self._hi, np.int64),
            "t_hi": np.asarray(self._t_hi, np.float64),
            "edges": np.asarray(self._edges, np.int64),
            "steps_taken": np.asarray(self.steps_taken, np.int64),
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore ``state_dict`` output in place, onto the same log and
        window geometry this engine was constructed with. No decomposition
        runs — the restored cores are the fixpoint of the restored CSR."""
        self.engine = StreamingKCoreEngine.from_state_dict(
            state["engine"], config=self.config,
            mesh=self.engine.mesh, axis_names=self.engine.axis_names)
        self._hi = int(np.asarray(state["hi"]))
        self._t_hi = float(np.asarray(state["t_hi"]))
        edges = np.array(np.asarray(state["edges"]), np.int64).reshape(-1, 2)
        edges.setflags(write=False)
        self._edges = edges
        self.steps_taken = int(np.asarray(state["steps_taken"]))

    # ------------------------------------------------------------------ #
    def window_graph(self) -> Graph:
        """Materialize the current window graph independently of the
        engine (oracle/verification path — O(w log w))."""
        return Graph.from_edges(self._edges, n=self.n)

    def peek_batch(self, k: int = 1) -> tuple[EdgeBatch, np.ndarray]:
        """The EdgeBatch that advancing by ``k`` strides would apply, and
        the resulting window edge set — without touching the engine."""
        if k < 1:
            raise ValueError("k must be >= 1")
        if self.by == "count":
            hi = min(self._hi + k * int(self.stride), len(self.log))
            lo = max(0, hi - int(self.window))
        else:
            t_hi = self._t_hi + k * self.stride
            lo = self.log.index_at_time(t_hi - self.window)
            hi = self.log.index_at_time(t_hi)
        new_edges = self.log.edges_between(lo, hi)
        old_keys = edge_keys(self._edges, self.n)
        new_keys = edge_keys(new_edges, self.n)
        insert = new_edges[~np.isin(new_keys, old_keys)]
        delete = self._edges[~np.isin(old_keys, new_keys)]
        return EdgeBatch.make(insert=insert, delete=delete), new_edges

    def advance(self, k: int = 1) -> WindowStep:
        """Slide the window forward by ``k`` strides and re-converge.

        The k strides collapse into ONE EdgeBatch (the net difference of
        the window edge sets), so a coarse replay pays one re-convergence
        per advance, not per stride. With tracing on, each advance is a
        ``window.advance`` span: ``window.diff`` (the edge-set diff) plus
        the engine's ``batch`` tree."""
        with _trace.span("window.advance", step=self.steps_taken) as sp:
            # label the streaming engine's upcoming flight run as a
            # temporal window advance (consumed by its next start_run)
            rec = _flight.recorder()
            if rec.active:
                rec.set_context(engine="temporal", step=self.steps_taken)
            with _trace.span("window.diff"):
                batch, new_edges = self.peek_batch(k)
            if self.by == "count":
                self._hi = min(self._hi + k * int(self.stride),
                               len(self.log))
            else:
                self._t_hi = self._t_hi + k * self.stride
            res = self.engine.apply_batch(batch)
            new_edges.setflags(write=False)
            self._edges = new_edges
            lo, hi = self.bounds
            t_lo, t_hi = self.t_bounds
            step = WindowStep(step=self.steps_taken, lo=lo, hi=hi,
                              t_lo=t_lo, t_hi=t_hi, batch=batch, result=res,
                              m=int(new_edges.shape[0]))
            sp.set(inserts=int(batch.insert.shape[0]),
                   deletes=int(batch.delete.shape[0]),
                   rounds=res.rounds, mode=res.mode,
                   messages=res.stats.total_messages)
        self.steps_taken += 1
        return step

    def steps(self, max_steps: int | None = None):
        """Iterate window advances until the stream is consumed."""
        while not self.done:
            if max_steps is not None and self.steps_taken >= max_steps:
                return
            yield self.advance()
