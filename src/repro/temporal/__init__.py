"""Temporal graph subsystem: timestamped event streams, sliding-window
k-core maintenance, and as-of queries.

Layers (built on the streaming maintenance engine, repro.streaming):

  * ``events`` — columnar timestamped edge-event logs (add/remove with
    monotone timestamps), text/npz round-trip, and temporal trace
    generators (timestamped preferential attachment, contact bursts,
    temporal SNAP analogues);
  * ``window`` — ``WindowedKCoreEngine``: slides a count- or time-based
    window over a stream, feeding window advances to the incremental
    engine as EdgeBatches (exact cores at every boundary), plus the
    ``CoreCheckpointRing`` as-of store;
  * ``replay`` — replay driver recording per-step stats into a
    core-evolution trajectory with periodic BZ-oracle cross-checks.
"""

from repro.temporal.events import (ADD, REMOVE, EdgeEvent, EventLog,
                                   contact_bursts, load_event_log,
                                   parse_event_text,
                                   temporal_barabasi_albert,
                                   temporal_snap_analogue)
from repro.temporal.replay import (ReplayRecord, ReplayTrajectory,
                                   check_step, replay)
from repro.temporal.window import WindowedKCoreEngine, WindowStep
# the as-of store lives with the serving layer; re-exported here because
# it is the temporal query surface
from repro.streaming.server import CoreCheckpointRing

__all__ = [
    "ADD",
    "REMOVE",
    "EdgeEvent",
    "EventLog",
    "parse_event_text",
    "load_event_log",
    "temporal_barabasi_albert",
    "contact_bursts",
    "temporal_snap_analogue",
    "WindowedKCoreEngine",
    "WindowStep",
    "CoreCheckpointRing",
    "ReplayRecord",
    "ReplayTrajectory",
    "replay",
    "check_step",
]
