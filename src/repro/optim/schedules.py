"""LR schedules."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, *, warmup: int, total: int, floor: float = 0.1):
    """Scale in (0, 1]: linear warmup then cosine decay. step+1 so the very
    first step already has a nonzero learning rate."""
    step = step.astype(jnp.float32) + 1.0
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos
