"""AdamW with ZeRO-style sharded moments (moments inherit the parameter
sharding, which is itself FSDP-sharded — so optimizer state is fully
distributed) and global-norm gradient clipping."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.float32(lr)}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
