from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_warmup
from repro.optim.compression import (
    topk_compress_decompress,
    int8_compress_decompress,
)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_warmup",
           "topk_compress_decompress", "int8_compress_decompress"]
