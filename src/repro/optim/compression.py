"""Gradient compression for the DP all-reduce path.

Two standard schemes, both with error feedback so compression error is
carried to the next step instead of lost:

  * top-k sparsification (Deep Gradient Compression style): keep the k
    largest-magnitude entries per tensor, all-reduce only those.
  * int8 quantization: per-tensor symmetric scale.

In the single-controller pjit world the all-reduce is implicit (GSPMD emits
it from the psum in the gradient computation), so these are exposed as
pre/post transforms around the gradient: compress → (all-reduce) →
decompress. The dry-run measures the collective-byte reduction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_compress_decompress(g, k_fraction: float, error=None):
    """Returns (g_compressed_dense, new_error). The dense tensor is zero
    outside the top-k support — the all-reduce then moves ~k nonzeros
    (with sparse transport at the collective layer; bytes accounted in the
    cost model as k/|g|)."""
    if error is not None:
        g = g + error
    flat = g.reshape(-1)
    k = max(int(flat.size * k_fraction), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(g) >= thresh
    kept = jnp.where(mask, g, 0)
    new_error = g - kept
    return kept, new_error


def int8_compress_decompress(g, error=None):
    """Symmetric per-tensor int8 quantize → dequantize (4x byte reduction on
    the wire for fp32 grads)."""
    if error is not None:
        g = g + error
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq
