"""mixtral-8x22b [arXiv:2401.04088]: 56L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=32768, MoE 8 experts top-2, sliding-window attention."""

from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=32768,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384, n_shared=0,
                  virtual_split=2),   # 16 virtual experts / 16-way model axis
    swa_window=4096, rope_theta=1_000_000.0,
    train_microbatches=8,
)

SMOKE = LMConfig(
    name="mixtral-8x22b-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=128, vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, n_shared=0,
                  virtual_split=2),
    swa_window=32,
)
