"""mace [arXiv:2206.07697]: higher-order E(3)-equivariant message passing —
2 layers, 128 channels, l_max=2, correlation order 3, 8 radial Bessel fns."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="mace", kind="mace", n_layers=2, d_hidden=128,
    params={"l_max": 2, "correlation": 3, "n_rbf": 8, "cutoff": 5.0,
            "n_species": 10},
)

SMOKE = GNNConfig(
    name="mace-smoke", kind="mace", n_layers=2, d_hidden=16,
    params={"l_max": 2, "correlation": 3, "n_rbf": 4, "cutoff": 5.0,
            "n_species": 4},
)
