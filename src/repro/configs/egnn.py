"""egnn [arXiv:2102.09844]: E(n)-equivariant GNN — 4 layers, d_hidden=64,
scalar-distance messages + coordinate updates."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="egnn", kind="egnn", n_layers=4, d_hidden=64,
    params={"n_species": 10},
)

SMOKE = GNNConfig(
    name="egnn-smoke", kind="egnn", n_layers=2, d_hidden=16,
    params={"n_species": 4},
)
