from repro.configs.base import (
    GNNConfig,
    LMConfig,
    MoEConfig,
    RecSysConfig,
    ShapeSpec,
    shapes_for,
)
from repro.configs.registry import ARCH_IDS, get_config, get_shapes, get_smoke

__all__ = [
    "GNNConfig", "LMConfig", "MoEConfig", "RecSysConfig", "ShapeSpec",
    "shapes_for", "ARCH_IDS", "get_config", "get_shapes", "get_smoke",
]
