"""yi-34b [arXiv:2403.04652]: llama-arch GQA dense — 60L d_model=7168 56H
(GQA kv=8) d_ff=20480 vocab=64000."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="yi-34b",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab=64000, rope_theta=5_000_000.0,
    train_microbatches=4,
)

SMOKE = LMConfig(
    name="yi-34b-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=192, vocab=512,
)
