"""The paper's own workload configs: the 14 SNAP graphs of Table I
(as synthetic analogues — see graph/generators.py) plus the engine config."""

from repro.core.kcore import KCoreConfig
from repro.graph.generators import SNAP_TABLE

CONFIG = KCoreConfig(mode="jacobi", backend="segment")
CONFIG_BEYOND = KCoreConfig(mode="block_gs", backend="segment", n_blocks=16)
GRAPHS = tuple(e.abbrev for e in SNAP_TABLE)
