"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d_model=2048 16H
(GQA kv=16) d_ff=1408 vocab=151936, MoE 60 routed top-4 + 4 shared."""

from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab=151936,
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408, n_shared=4,
                  pad_experts_to=64),  # 64 / 16-way model axis (4 dummies)
    qkv_bias=True, rope_theta=1_000_000.0,
    train_microbatches=2,
)

SMOKE = LMConfig(
    name="qwen2-moe-a2.7b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=96, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=96, n_shared=2,
                  pad_experts_to=10),  # exercises the pad path
    qkv_bias=True,
)
