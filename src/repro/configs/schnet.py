"""schnet [arXiv:1706.08566]: continuous-filter convolutions — 3 interaction
blocks, d_hidden=64, 300 Gaussian RBFs, cutoff 10 Å."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="schnet", kind="schnet", n_layers=3, d_hidden=64,
    params={"n_rbf": 300, "cutoff": 10.0, "n_species": 10},
)

SMOKE = GNNConfig(
    name="schnet-smoke", kind="schnet", n_layers=2, d_hidden=16,
    params={"n_rbf": 16, "cutoff": 10.0, "n_species": 4},
)
