"""``--arch <id>`` registry over the 10 assigned architectures."""

from __future__ import annotations

import importlib

from repro.configs.base import ShapeSpec, shapes_for

_MODULES = {
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "yi-34b": "repro.configs.yi_34b",
    "granite-34b": "repro.configs.granite_34b",
    "qwen1.5-0.5b": "repro.configs.qwen1p5_0p5b",
    "mace": "repro.configs.mace",
    "graphcast": "repro.configs.graphcast",
    "schnet": "repro.configs.schnet",
    "egnn": "repro.configs.egnn",
    "din": "repro.configs.din",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str):
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke(arch: str):
    return importlib.import_module(_MODULES[arch]).SMOKE


def get_shapes(arch: str) -> tuple[ShapeSpec, ...]:
    return shapes_for(get_config(arch))


def shape_by_name(arch: str, shape: str) -> ShapeSpec:
    for s in get_shapes(arch):
        if s.name == shape:
            return s
    raise KeyError(f"{arch} has no shape {shape}")
