"""Config system: architecture configs + input-shape specs + registry.

Every assigned architecture is a frozen dataclass instance in its own
``configs/<id>.py`` file; the registry maps ``--arch <id>`` strings to
(config, shape-set, smoke-config) triples.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping


# ---------------------------------------------------------------------- #
# LM family
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # shared experts (DeepSeek/Qwen-MoE style)
    capacity_factor: float = 1.25
    # Mesh-divisibility transforms (both EXACT math, see models/transformer):
    #   virtual_split: each expert becomes `split` half-width experts whose
    #     contributions sum in the combine einsum (SwiGLU splits along d_ff).
    #   pad_experts_to: dummy experts whose router logits are -inf.
    virtual_split: int = 1
    pad_experts_to: int | None = None

    @property
    def e_pad(self) -> int:
        return self.pad_experts_to or self.n_experts

    @property
    def e_eff(self) -> int:
        return self.e_pad * self.virtual_split

    @property
    def f_eff(self) -> int:
        assert self.d_ff_expert % self.virtual_split == 0
        return self.d_ff_expert // self.virtual_split


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                      # dense FFN width (MoE: shared-path width)
    vocab: int
    d_head: int = 128
    moe: MoEConfig | None = None
    swa_window: int | None = None  # sliding-window attention (Mixtral)
    qkv_bias: bool = False         # Qwen1.5 style
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mlp_type: str = "swiglu"       # "swiglu" (3 matmuls) | "gelu" (2 matmuls)
    train_microbatches: int = 1    # gradient-accumulation steps per batch
    remat_policy: str = "full"     # "full" | "dots" (selective: save
                                   # non-batch matmul outputs, skip fwd
                                   # recompute of the big GEMMs)
    family: str = "lm"

    @property
    def _ff_mats(self) -> int:
        return 3 if self.mlp_type == "swiglu" else 2

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding included)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        attn = d * self.n_heads * self.d_head * 2 + \
            d * self.n_kv_heads * self.d_head * 2
        if self.moe:
            ff = self._ff_mats * d * self.moe.d_ff_expert * self.moe.n_experts \
                + d * self.moe.n_experts  # router
            if self.moe.n_shared:
                ff += self._ff_mats * d * self.moe.d_ff_expert * \
                    self.moe.n_shared + d
        else:
            ff = self._ff_mats * d * f
        emb = V * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ff + 2 * d) + emb + d

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.n_params
        d, L, V = self.d_model, self.n_layers, self.vocab
        attn = d * self.n_heads * self.d_head * 2 + \
            d * self.n_kv_heads * self.d_head * 2
        ff = self._ff_mats * d * self.moe.d_ff_expert * \
            (self.moe.top_k + self.moe.n_shared)
        emb = V * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ff + 2 * d) + emb + d


# ---------------------------------------------------------------------- #
# GNN family
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                      # "mace" | "graphcast" | "schnet" | "egnn"
    n_layers: int
    d_hidden: int
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    family: str = "gnn"


# ---------------------------------------------------------------------- #
# RecSys family
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    embed_dim: int
    seq_len: int
    attn_mlp: tuple[int, ...]
    mlp: tuple[int, ...]
    n_items: int
    n_cates: int
    family: str = "recsys"


# ---------------------------------------------------------------------- #
# Shapes
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode | full_graph | minibatch |
                       # molecule | serve | retrieval
    params: Mapping[str, Any]


LM_SHAPES: tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES: tuple[ShapeSpec, ...] = (
    ShapeSpec("full_graph_sm", "full_graph",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
               "n_classes": 7}),
    ShapeSpec("minibatch_lg", "minibatch",
              {"n_nodes": 232_965, "n_edges": 114_615_892,
               "batch_nodes": 1024, "fanout": (15, 10), "d_feat": 602,
               "n_classes": 41}),
    ShapeSpec("ogb_products", "full_graph",
              {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
               "n_classes": 47}),
    ShapeSpec("molecule", "molecule",
              {"n_nodes": 30, "n_edges": 64, "batch": 128}),
)

RECSYS_SHAPES: tuple[ShapeSpec, ...] = (
    ShapeSpec("train_batch", "train", {"batch": 65536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262_144}),
    ShapeSpec("retrieval_cand", "retrieval",
              {"batch": 1, "n_candidates": 1_000_000}),
)


def shapes_for(cfg) -> tuple[ShapeSpec, ...]:
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
            "recsys": RECSYS_SHAPES}[cfg.family]
