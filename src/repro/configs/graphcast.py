"""graphcast [arXiv:2212.12794]: encoder-processor-decoder mesh GNN —
16 processor layers, d_hidden=512, icosahedral mesh refinement 6, 227 vars.

Grid resolution: 1° lat-lon (181 x 360 = 65,160 grid nodes) — GraphCast's
0.25° grid only changes input_spec constants; 1° keeps the CPU-hosted
dry-run compile tractable (documented deviation, DESIGN.md §6)."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="graphcast", kind="graphcast", n_layers=16, d_hidden=512,
    params={"mesh_refinement": 6, "n_vars": 227, "aggregator": "sum",
            "grid_lat": 181, "grid_lon": 360,
            "mesh_nodes": 40962, "mesh_edges": 327660,  # multimesh union M0..M6
            "grid2mesh_edges": 196608, "mesh2grid_edges": 195480},
)

SMOKE = GNNConfig(
    name="graphcast-smoke", kind="graphcast", n_layers=2, d_hidden=32,
    params={"mesh_refinement": 1, "n_vars": 8, "aggregator": "sum",
            "grid_lat": 7, "grid_lon": 12,
            "mesh_nodes": 42, "mesh_edges": 240,
            "grid2mesh_edges": 252, "mesh2grid_edges": 252},
)
