"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B]: 24L d_model=1024 16H (kv=16)
d_ff=2816 vocab=151936, QKV bias, tied embeddings."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen1.5-0.5b",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=2816, vocab=151936, qkv_bias=True, tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = LMConfig(
    name="qwen1.5-0.5b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=160, vocab=512, qkv_bias=True, tie_embeddings=True,
)
