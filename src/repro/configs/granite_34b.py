"""granite-34b [arXiv:2405.04324]: llama-arch code model, MQA — 88L
d_model=6144 48H (kv=1) d_ff=24576 vocab=49152."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="granite-34b",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
    d_ff=24576, vocab=49152, mlp_type="gelu",
    train_microbatches=4,
)

SMOKE = LMConfig(
    name="granite-34b-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=1, d_head=8,
    d_ff=192, vocab=512, mlp_type="gelu",
)
