"""din [arXiv:1706.06978]: Deep Interest Network — embed_dim=18, history
seq_len=100, target-attention MLP 80-40, prediction MLP 200-80.

Tables: 10^6 items (matches retrieval_cand's candidate count), 10^4
categories."""

from repro.configs.base import RecSysConfig

CONFIG = RecSysConfig(
    name="din", embed_dim=18, seq_len=100, attn_mlp=(80, 40),
    mlp=(200, 80), n_items=1_000_000, n_cates=10_000,
)

SMOKE = RecSysConfig(
    name="din-smoke", embed_dim=8, seq_len=12, attn_mlp=(16, 8),
    mlp=(24, 12), n_items=1000, n_cates=50,
)
