"""Observability layer: tracer, metrics, trace validator, compile-seconds
telemetry, heartbeat accounting, and the end-to-end span-coverage
acceptance (a traced fused replay attributes >= 95% of every batch's wall
to named phase sub-spans)."""

import json
import math
import threading

import numpy as np
import pytest

from repro.core import compile_count, compile_seconds
from repro.core.messages import MessageStats, heartbeat_overhead
from repro.graph import generators as gen
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import Tracer
from repro.obs.validate import (TraceValidationError, span_tree_coverage,
                                validate_chrome_trace)
from repro.obs.validate import main as validate_main
from repro.streaming import KCoreServer, Request
from repro.streaming.delta import EdgeBatch


@pytest.fixture
def tracer():
    """Fresh enabled tracer, independent of the process default."""
    t = Tracer()
    t.enable()
    return t


@pytest.fixture
def default_trace():
    """Enable the process-default tracer for one test, then restore."""
    obs_trace.reset()
    obs_trace.enable()
    yield obs_trace
    obs_trace.disable()
    obs_trace.reset()


# ---------------------------------------------------------------------- #
# Tracer
# ---------------------------------------------------------------------- #

def test_span_nesting_and_attrs(tracer):
    with tracer.span("outer", graph="EEN") as sp:
        with tracer.span("inner"):
            pass
        sp.set(rounds=3)
    evs = tracer.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    inner, outer = evs
    assert outer["args"] == {"graph": "EEN", "rounds": 3}
    assert "args" not in inner
    # inner is contained in outer on the same thread
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 0.01
    assert inner["tid"] == outer["tid"]
    for e in evs:
        assert e["ph"] == "X" and e["dur"] >= 0


def test_disabled_tracer_is_noop_and_shared():
    t = Tracer()
    s1 = t.span("a", x=1)
    s2 = t.span("b")
    assert s1 is s2                       # the shared NULL_SPAN singleton
    with s1 as sp:
        sp.set(anything="ignored")
    assert t.events() == []
    t.annotate(x=1)                       # no-op, no raise
    t.record("c", 0.5)
    assert t.events() == []


def test_record_synthesizes_span_ending_now(tracer):
    import time as _t
    with tracer.span("work"):
        _t.sleep(0.002)  # the "external" work runs inside the open span
        tracer.record("external", 0.001, kind="compile")
    ext, work = tracer.events()
    assert ext["name"] == "external"
    assert ext["args"] == {"kind": "compile"}
    assert ext["dur"] == pytest.approx(1000.0)   # 1ms in us
    # the synthesized span nests inside the open one
    assert work["ts"] <= ext["ts"] + 0.01
    assert ext["ts"] + ext["dur"] <= work["ts"] + work["dur"] + 0.01


def test_tracer_threads_get_own_stacks(tracer):
    def worker():
        with tracer.span("thread-span"):
            pass

    with tracer.span("main-span"):
        th = threading.Thread(target=worker)
        th.start()
        th.join()
    evs = tracer.events()
    tids = {e["name"]: e["tid"] for e in evs}
    assert tids["thread-span"] != tids["main-span"]
    validate_chrome_trace({"traceEvents": evs})   # per-thread nesting holds


def test_export_and_current_and_annotate(tracer, tmp_path):
    with tracer.span("top"):
        assert tracer.current().name == "top"
        tracer.annotate(extra=7)
    path = tracer.export(str(tmp_path / "t.json"))
    doc = json.loads(open(path).read())
    assert doc["traceEvents"][0]["args"] == {"extra": 7}
    assert validate_chrome_trace(doc)["events"] == 1
    tracer.reset()
    assert tracer.events() == []


# ---------------------------------------------------------------------- #
# Metrics
# ---------------------------------------------------------------------- #

def test_counter_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("reqs", op="core")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(5)
    g.inc(-2)
    assert g.value == 3
    # same (name, labels) -> same object; same name other labels -> new one
    assert reg.counter("reqs", op="core") is c
    assert reg.counter("reqs", op="update") is not c
    with pytest.raises(TypeError):
        reg.gauge("reqs", op="core")      # type mismatch on re-registration


def test_histogram_quantiles_exact_within_reservoir():
    h = Histogram(reservoir_size=2048)
    for v in range(1, 1001):              # 1..1000, all retained
        h.observe(float(v))
    snap = h.snapshot()
    assert snap["count"] == 1000 and snap["sum"] == pytest.approx(500500.0)
    assert snap["min"] == 1.0 and snap["max"] == 1000.0
    assert snap["p50"] == pytest.approx(500.5)
    assert snap["p95"] == pytest.approx(950.05)
    assert snap["p99"] == pytest.approx(990.01)


def test_histogram_reservoir_bounds_memory_keeps_exact_totals():
    h = Histogram(reservoir_size=64)
    for v in range(10_000):
        h.observe(float(v))
    assert len(h._reservoir) == 64        # bounded no matter the stream
    assert h.count == 10_000
    assert h.sum == pytest.approx(sum(range(10_000)))
    assert 0 <= h.quantile(0.5) < 10_000


def test_empty_histogram_snapshot():
    snap = Histogram().snapshot()
    assert snap["count"] == 0
    assert snap["p50"] is None and snap["mean"] is None
    assert math.isnan(Histogram().quantile(0.5))


def test_registry_json_and_prometheus_export():
    reg = MetricsRegistry()
    reg.counter("requests_total", op="core").inc(5)
    reg.gauge("window_m").set(1234)
    h = reg.histogram("latency_seconds", op="core")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    js = reg.to_json()
    assert js["requests_total"][0]["value"] == 5
    assert js["latency_seconds"][0]["labels"] == {"op": "core"}
    assert js["latency_seconds"][0]["count"] == 3
    prom = reg.to_prometheus()
    assert '# TYPE requests_total counter' in prom
    assert 'requests_total{op="core"} 5.0' in prom
    assert '# TYPE latency_seconds summary' in prom
    assert 'latency_seconds{op="core",quantile="0.5"}' in prom
    assert 'latency_seconds_count{op="core"} 3' in prom
    reg.reset()
    assert reg.to_json() == {}


def test_prometheus_label_value_escaping():
    # spec-conformant exposition: backslash, double-quote, and newline in
    # label VALUES must be escaped (names are sanitized, values escaped)
    reg = MetricsRegistry()
    reg.counter("paths_total", path='C:\\tmp\\"x"\nend').inc()
    prom = reg.to_prometheus()
    assert 'paths_total{path="C:\\\\tmp\\\\\\"x\\"\\nend"} 1.0' in prom
    assert "\n" not in prom.split('path="', 1)[1].split("} ")[0]


def test_default_registry_module_functions():
    obs_metrics.reset()
    obs_metrics.counter("x").inc()
    assert obs_metrics.to_json()["x"][0]["value"] == 1
    assert "# TYPE x counter" in obs_metrics.to_prometheus()
    obs_metrics.reset()


# ---------------------------------------------------------------------- #
# Validator
# ---------------------------------------------------------------------- #

def _ev(name, ts, dur, tid=1, **args):
    ev = {"name": name, "ph": "X", "ts": ts, "dur": dur, "pid": 1, "tid": tid}
    if args:
        ev["args"] = args
    return ev


def test_validator_accepts_nested_rejects_partial_overlap():
    ok = {"traceEvents": [_ev("a", 0, 100), _ev("b", 10, 20),
                          _ev("c", 40, 20), _ev("d", 200, 5)]}
    s = validate_chrome_trace(ok)
    assert s["events"] == 4 and s["max_depth"] == 2
    bad = {"traceEvents": [_ev("a", 0, 100), _ev("b", 50, 100)]}
    with pytest.raises(TraceValidationError, match="overlap"):
        validate_chrome_trace(bad)


@pytest.mark.parametrize("ev", [
    {"ph": "X", "ts": 0, "dur": 1, "pid": 1, "tid": 1},          # no name
    _ev("a", -1, 5),                                             # negative ts
    _ev("a", 0, -5),                                             # negative dur
    {**_ev("a", 0, 1), "ph": "B"},                               # wrong phase
    {**_ev("a", 0, 1), "pid": "x"},                              # pid type
    {**_ev("a", 0, 1), "args": [1]},                             # args type
])
def test_validator_rejects_malformed_events(ev):
    with pytest.raises(TraceValidationError):
        validate_chrome_trace({"traceEvents": [ev]})


def test_span_tree_coverage_direct_children_only():
    evs = [_ev("batch", 0, 100), _ev("patch", 0, 30),
           _ev("converge", 30, 60), _ev("inner", 35, 10)]
    (cov,) = span_tree_coverage(evs, "batch")
    # inner is a grandchild — only patch+converge count: 90/100
    assert cov["coverage"] == pytest.approx(0.9)
    assert cov["children"] == ["converge", "patch"]


def test_validator_cli(tmp_path, capsys):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(
        {"traceEvents": [_ev("batch", 0, 100), _ev("patch", 0, 99)]}))
    assert validate_main([str(good), "--require-span", "batch",
                          "--min-coverage", "0.95"]) == 0
    assert validate_main([str(good), "--require-span", "missing"]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert validate_main([str(bad)]) == 1
    low = tmp_path / "low.json"
    low.write_text(json.dumps(
        {"traceEvents": [_ev("batch", 0, 100), _ev("patch", 0, 10)]}))
    assert validate_main([str(low), "--require-span", "batch",
                          "--min-coverage", "0.95"]) == 1


# ---------------------------------------------------------------------- #
# Compile telemetry (jit_telemetry.compile_seconds)
# ---------------------------------------------------------------------- #

def test_compile_seconds_tracks_fresh_jit_signature():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def _fresh(x):
        return x * 2 + 1

    c0, s0 = compile_count(), compile_seconds()
    _fresh(jnp.arange(7_919))             # prime-sized: a fresh signature
    dc = compile_count() - c0
    ds = compile_seconds() - s0
    assert dc >= 1
    assert ds > 0.0                       # the compile took real wall time
    # cache hit: neither count nor seconds move
    c1, s1 = compile_count(), compile_seconds()
    _fresh(jnp.arange(7_919))
    assert compile_count() == c1 and compile_seconds() == s1


def test_compile_lands_as_span_when_tracing(default_trace):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def _fresh2(x):
        return x * 3 - 1

    with default_trace.span("host-work"):
        _fresh2(jnp.arange(7_907))        # fresh signature inside the span
    names = [e["name"] for e in default_trace.events()]
    assert "xla.compile" in names
    doc = default_trace.chrome_trace()
    validate_chrome_trace(doc)
    (cov,) = span_tree_coverage(doc["traceEvents"], "host-work")
    assert "xla.compile" in cov["children"]


# ---------------------------------------------------------------------- #
# Heartbeat accounting (core.messages.heartbeat_overhead)
# ---------------------------------------------------------------------- #

def test_heartbeat_overhead_round_granularity():
    stats = MessageStats(
        messages_per_round=np.asarray([100, 50, 20], np.int64),
        active_per_round=np.asarray([10, 6, 2], np.int64),
        changed_per_round=np.asarray([10, 5, 1], np.int64))
    hb = heartbeat_overhead(stats)
    assert hb["heartbeat_messages"] == 18          # one per active per round
    assert hb["bsp_allreduce_rounds"] == stats.rounds
    assert hb["heartbeat_fraction_of_traffic"] == pytest.approx(18 / 170)
    # sparser heartbeat period sums every k-th round's actives
    hb2 = heartbeat_overhead(stats, heartbeat_every_rounds=2)
    assert hb2["heartbeat_messages"] == 10 + 2


def test_heartbeat_overhead_zero_traffic_guard():
    stats = MessageStats(*(np.zeros(0, np.int64),) * 3)
    hb = heartbeat_overhead(stats)
    assert hb["heartbeat_messages"] == 0
    assert hb["heartbeat_fraction_of_traffic"] == 0


# ---------------------------------------------------------------------- #
# End-to-end: engines emit well-formed, well-attributed traces
# ---------------------------------------------------------------------- #

def test_static_decompose_phase_walls_without_tracing():
    g = gen.erdos_renyi(300, 900, seed=3)
    from repro.core import kcore_decompose
    res = kcore_decompose(g)
    assert obs_trace.enabled() is False
    assert res.phase_s.get("converge", 0) > 0
    assert res.compile_s >= 0.0
    fused = kcore_decompose(g, fused=True)
    assert fused.phase_s.get("device-converge", 0) > 0
    assert "host-reconstruct" in fused.phase_s


def test_traced_fused_replay_meets_span_coverage_acceptance(default_trace):
    """The ISSUE acceptance: a fused streaming replay's trace attributes
    >= 95% of every batch span's wall to its named phase children."""
    from repro.streaming import StreamingConfig
    from repro.temporal import replay, temporal_barabasi_albert

    log = temporal_barabasi_albert(400, 3, seed=1, remove_frac=0.1)
    traj = replay(log, window=max(len(log) // 4, 10),
                  stride=max(len(log) // 8, 5),
                  config=StreamingConfig(frontier="fused"), max_steps=4)
    assert traj.records, "replay produced no steps"
    rec = traj.records[-1]
    assert rec.converge_ms >= 0 and rec.seed_ms >= 0
    assert rec.heartbeats > 0

    doc = default_trace.chrome_trace()
    summary = validate_chrome_trace(doc)   # schema + nesting
    assert summary["names"].get("batch", 0) == len(traj.records)
    assert summary["names"].get("window.advance", 0) == len(traj.records)
    cov = span_tree_coverage(doc["traceEvents"], "batch")
    assert len(cov) == len(traj.records)
    worst = min(c["coverage"] for c in cov)
    assert worst >= 0.95, f"batch span child coverage {worst:.3f} < 0.95"
    for c in cov:
        assert {"csr-patch", "seed", "converge"} <= set(c["children"])


def test_server_latency_histograms_by_op():
    g = gen.erdos_renyi(200, 500, seed=2)
    srv = KCoreServer(g)
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(40):
        reqs.append(Request(op="core", vertices=rng.integers(0, g.n, 8)))
        reqs.append(Request(op="max_k"))
    srv.serve(reqs)
    ins = np.asarray([[0, 5], [1, 7]])
    srv.update(EdgeBatch.make(insert=ins))

    stats = srv.stats()
    # raw float walls: no fixed rounding at the measurement layer
    assert isinstance(stats["query_wall_s"], float)
    assert stats["query_wall_s"] > 0
    lat = stats["latency"]
    # STABLE schema: every op is present, exercised or not (dashboards
    # key on op names; zero-request ops show count 0 / null quantiles)
    assert set(lat) == set(KCoreServer.OPS)
    for op in ("core", "max_k"):
        snap = lat[op]
        assert snap["count"] == 40
        assert 0 < snap["p50"] <= snap["p95"] <= snap["p99"]
        assert snap["min"] <= snap["mean"] <= snap["max"]
        assert snap["sum"] >= snap["count"] * snap["min"]
    assert lat["update"]["count"] == 1
    for op in ("members", "core_asof", "advance_window"):
        assert lat[op]["count"] == 0
        assert lat[op]["p50"] is None and lat[op]["min"] is None
    # per-server registries: a second server starts clean but with the
    # full op schema already registered
    srv2 = KCoreServer(gen.erdos_renyi(50, 100, seed=4))
    lat2 = srv2.stats()["latency"]
    assert set(lat2) == set(KCoreServer.OPS)
    assert all(s["count"] == 0 for s in lat2.values())
    prom = srv.metrics.to_prometheus()
    assert 'server_request_seconds{op="core",quantile="0.99"}' in prom
