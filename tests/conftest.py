import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see the real single device
# (the 512-device override lives ONLY in repro.launch.dryrun).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
