import numpy as np
import pytest

import repro.platform

# Platform config BEFORE anything touches a jax backend: by default no
# variable is set and tests see the real single device (the 512-device
# override lives ONLY in repro.launch.dryrun). CI's forced-multi-device
# lane exports REPRO_HOST_DEVICES=4 and runs the mesh tests in-process.
repro.platform.configure_from_env()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
