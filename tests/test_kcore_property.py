"""Hypothesis property tests for the k-core system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see "
                    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import KCoreConfig, bz_core_numbers, kcore_decompose
from repro.core.kcore import _bs_iters
from repro.graph.structs import Graph


@st.composite
def random_graphs(draw):
    n = draw(st.integers(2, 60))
    n_edges = draw(st.integers(0, 150))
    edges = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        min_size=n_edges, max_size=n_edges))
    return Graph.from_edges(np.asarray(edges, np.int64).reshape(-1, 2), n=n)


@settings(max_examples=40, deadline=None)
@given(random_graphs())
def test_engine_equals_bz_on_random_graphs(g):
    res = kcore_decompose(g)
    assert res.converged
    assert (res.core == bz_core_numbers(g)).all()


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_locality_theorem_at_fixpoint(g):
    """Theorem II.1: core(u) = max k with >= k neighbors of core >= k."""
    core = np.asarray(kcore_decompose(g).core)
    for u in range(g.n):
        nbr = core[g.neighbors(u)]
        k = core[u]
        assert (nbr >= k).sum() >= k
        assert (nbr >= k + 1).sum() < k + 1


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_monotone_bounds(g):
    """0 <= core <= deg, and core <= max over neighbors' degrees."""
    res = kcore_decompose(g)
    assert (res.core >= 0).all()
    assert (res.core <= g.deg).all()


@settings(max_examples=20, deadline=None)
@given(random_graphs(), st.integers(2, 6))
def test_block_gs_matches_for_any_block_count(g, nb):
    ref = bz_core_numbers(g)
    res = kcore_decompose(g, KCoreConfig(mode="block_gs", n_blocks=nb))
    assert (res.core == ref).all()


@settings(max_examples=20, deadline=None)
@given(random_graphs())
def test_subgraph_monotonicity(g):
    """Removing edges never increases any core number."""
    if g.m < 2:
        return
    core_full = np.asarray(kcore_decompose(g).core)
    # drop half the (undirected) edges
    keep = np.arange(g.m) % 2 == 0
    und = np.stack([g.src, g.dst], 1)
    und = und[und[:, 0] < und[:, 1]][keep]
    g2 = Graph.from_edges(und, n=g.n)
    core_sub = np.asarray(kcore_decompose(g2).core)
    assert (core_sub <= core_full).all()


def test_bs_iters_covers_range():
    for md in [0, 1, 2, 3, 100, 38625]:
        it = _bs_iters(md)
        assert 2 ** it > md
