"""k-truss extra (paper §V future work): BSP iteration vs peeling oracle."""

import pytest

from repro.core.ktruss import ktruss_bsp, ktruss_peeling
from repro.graph import generators as gen


def test_complete_graph_truss():
    """K5: every edge lies in 3 triangles -> truss number 5."""
    truss = ktruss_peeling(gen.complete(5))
    assert all(v == 5 for v in truss.values())


def test_triangle_plus_tail():
    from repro.graph.structs import Graph
    g = Graph.from_edges([(0, 1), (1, 2), (0, 2), (2, 3)], n=4)
    truss = ktruss_peeling(g)
    assert truss[(0, 1)] == truss[(0, 2)] == truss[(1, 2)] == 3
    assert truss[(2, 3)] == 2


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bsp_matches_peeling(seed):
    g = gen.erdos_renyi(40, 140, seed=seed)
    ref = ktruss_peeling(g)
    est, stats = ktruss_bsp(g)
    assert est == ref
    assert stats.rounds >= 1
