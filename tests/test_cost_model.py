"""Cost-model seed selection (ISSUE 5): the per-batch choice between the
tight subcore upper bound and a plain degree seed
(repro.core.cost_model.choose_seed) — which replaced the old 25%-churn
``bulk_seed_frac`` step function. Both seeds are sound, so these tests pin
the DECISION (and its telemetry) at the old step-function boundary: bulk
loads whose cores rise by many levels pick degrees, mid-churn batches whose
cores barely move keep the tight bound even when their insert fraction is
far past 25%, and the engine stays BZ-exact either way."""

import numpy as np

from repro.core import bz_core_numbers
from repro.core.cost_model import (SeedCostModel, choose_seed,
                                   estimate_ub_passes)
from repro.graph import generators as gen
from repro.graph.structs import Graph
from repro.streaming import EdgeBatch, StreamingKCoreEngine

MODEL = SeedCostModel()  # defaults: degree wins iff est_passes > 6


def _star_batch(hub_edges):
    """(b, 2) inserts all incident to vertex 0."""
    return np.asarray([(0, i + 1) for i in range(hub_edges)], np.int64)


def test_estimate_passes_empty_and_capped():
    deg = np.array([5, 5, 5], np.int64)
    core = np.zeros(3, np.int64)
    assert estimate_ub_passes(np.zeros((0, 2), np.int64), deg, core) == 0
    # a single inserted edge can raise cores by at most 1 (subcore theorem)
    one = np.asarray([[0, 1]], np.int64)
    assert estimate_ub_passes(one, deg, core) == 1


def test_estimate_passes_headroom_capped():
    # vertex 0 takes 5 inserts but its core already equals deg - 1: the
    # headroom (deg - old_core), not the insert count, bounds the raise
    ins = _star_batch(5)
    deg = np.array([10, 3, 3, 3, 3, 3], np.int64)
    core = np.array([9, 1, 1, 1, 1, 1], np.int64)
    assert estimate_ub_passes(ins, deg, core) == 1


def test_choice_boundary_default_model():
    """Default model: degree iff est_passes > (16 - 4) / 2 = 6."""
    deg = np.full(10, 20, np.int64)
    core = np.zeros(10, np.int64)
    six = choose_seed(_star_batch(6), deg, core, MODEL)
    seven = choose_seed(_star_batch(7), deg, core, MODEL)
    assert six.strategy == "tight" and six.est_passes == 6
    assert seven.strategy == "degree" and seven.est_passes == 7
    assert seven.tight_cost > seven.degree_cost
    assert six.tight_cost <= six.degree_cost


def test_mid_churn_spread_batch_stays_tight():
    """A >25% insert fraction whose per-vertex raise potential is ~1 (the
    old step function's wall cliff) now keeps the tight bound."""
    n = 40
    deg = np.full(n, 3, np.int64)
    core = np.full(n, 2, np.int64)
    # 20 inserts, each on distinct endpoints: ins_deg <= 1 everywhere
    ins = np.asarray([(2 * i, 2 * i + 1) for i in range(n // 2)], np.int64)
    choice = choose_seed(ins, deg, core, MODEL)
    assert choice.strategy == "tight"
    assert choice.est_passes <= 1


def test_engine_bulk_fill_picks_degree_seed():
    """A window filling from empty is the canonical bulk load: every
    vertex's core rises by many levels, the model must pick degrees."""
    eng = StreamingKCoreEngine(Graph.from_edges(np.zeros((0, 2)), n=10))
    iu = np.triu_indices(10, k=1)
    res = eng.apply_batch(EdgeBatch.make(insert=np.stack(iu, axis=1)))
    assert res.seed_strategy == "degree"
    assert res.seed_est_passes > 6
    assert (res.core == 9).all()
    assert (res.core == bz_core_numbers(eng.graph)).all()


def test_engine_mid_churn_picks_tight_seed():
    """~33% insert fraction, spread so no core moves much: the old step
    function would have taken the degree-seed wall cliff; the cost model
    keeps the tight bound and the low message bill."""
    g = gen.cycle(30)
    eng = StreamingKCoreEngine(g)
    chords = np.asarray([(i, i + 15) for i in range(15)], np.int64)
    res = eng.apply_batch(EdgeBatch.make(insert=chords))
    assert res.seed_strategy == "tight"
    assert res.seed_est_passes <= 2
    assert (res.core == bz_core_numbers(eng.graph)).all()


def test_engine_delete_only_batch_is_tight_with_zero_passes():
    g = gen.barabasi_albert(60, 3, seed=4)
    eng = StreamingKCoreEngine(g)
    from repro.streaming import canonical_edges

    res = eng.apply_batch(EdgeBatch.make(delete=canonical_edges(g)[:5]))
    assert res.seed_strategy == "tight"
    assert res.seed_est_passes == 0
    assert (res.core == bz_core_numbers(eng.graph)).all()
