"""Static fused runtime (ISSUE 5): ``kcore_decompose(..., fused=True)`` —
the paper's from-scratch decomposition as one device-resident while_loop
through the shared runtime (core/runtime.py) — must be EXACT-equal to the
host round loop in cores AND per-round accounting (messages / active /
changed per round, round count, convergence flag), on every backend config,
with a max_rounds cap, and through the sharded variant."""

import numpy as np
import pytest

from repro.core import KCoreConfig, bz_core_numbers, kcore_decompose, \
    kcore_decompose_sharded
from repro.distribution.compat import make_mesh
from repro.graph import generators as gen
from repro.graph.structs import Graph


def assert_result_equal(ref, got):
    """Full KCoreResult accounting equality (not just the cores)."""
    assert (ref.core == got.core).all()
    assert (ref.stats.messages_per_round
            == got.stats.messages_per_round).all()
    assert (ref.stats.active_per_round == got.stats.active_per_round).all()
    assert (ref.stats.changed_per_round
            == got.stats.changed_per_round).all()
    assert ref.rounds == got.rounds
    assert ref.converged == got.converged


GRAPHS = {
    "ba": lambda: gen.barabasi_albert(250, 4, seed=7),
    "er": lambda: gen.erdos_renyi(180, 700, seed=3),
    "chain": lambda: gen.chain(120),
    "star": lambda: gen.star(30),
    "complete": lambda: gen.complete(12),
    "edgeless": lambda: Graph.from_edges(np.zeros((0, 2), np.int64), n=9),
}


@pytest.mark.parametrize("name", sorted(GRAPHS))
def test_static_fused_equals_host_loop(name):
    g = GRAPHS[name]()
    ref = kcore_decompose(g)
    fus = kcore_decompose(g, fused=True)
    assert_result_equal(ref, fus)
    assert (fus.core == bz_core_numbers(g)).all()


def test_static_fused_via_config_flag():
    g = gen.barabasi_albert(150, 3, seed=1)
    ref = kcore_decompose(g)
    fus = kcore_decompose(g, KCoreConfig(fused=True))
    assert_result_equal(ref, fus)
    # keyword overrides the config in both directions
    assert_result_equal(ref, kcore_decompose(g, KCoreConfig(fused=True),
                                             fused=False))


def test_static_fused_backend_configs_identical():
    """The fused runtime is backend-independent (it always stages the
    segment arrays); every backend's host loop must match it bit-exactly."""
    g = gen.barabasi_albert(150, 3, seed=2)
    fus = kcore_decompose(g, fused=True)
    for backend in ("segment", "ell"):
        host = kcore_decompose(g, KCoreConfig(backend=backend))
        assert_result_equal(host, fus)


def test_static_fused_rejects_block_gs():
    g = gen.cycle(10)
    with pytest.raises(ValueError, match="jacobi"):
        kcore_decompose(g, KCoreConfig(mode="block_gs"), fused=True)


def test_static_fused_respects_max_rounds_cap():
    """A tight cap must stop the while_loop exactly where the host loop
    stops — same partial estimate, same accounting, converged=False."""
    g = gen.chain(60)
    ref = kcore_decompose(g, KCoreConfig(max_rounds=3))
    fus = kcore_decompose(g, KCoreConfig(max_rounds=3), fused=True)
    assert not ref.converged
    assert_result_equal(ref, fus)


def test_static_fused_sharded_1dev_mesh():
    g = gen.barabasi_albert(200, 4, seed=5)
    mesh = make_mesh((1,), ("data",))
    ref = kcore_decompose(g)
    fus = kcore_decompose_sharded(g, mesh, ("data",), fused=True)
    assert_result_equal(ref, fus)
    assert (fus.core == bz_core_numbers(g)).all()


def test_static_fused_reports_recompile_telemetry():
    """Back-to-back identical fused runs must be all cache hits — the
    O(log)-compiles claim of BENCH_static.json, measured not asserted."""
    g = gen.barabasi_albert(130, 3, seed=11)
    first = kcore_decompose(g, fused=True)
    second = kcore_decompose(g, fused=True)
    assert first.recompiles >= 0
    assert second.recompiles == 0
    assert_result_equal(first, second)
