"""Hypothesis property tests for the fused convergence path (ISSUE 4):
on randomized churn batches over random graphs, ``fused`` and
``fused_sharded`` must produce identical cores AND identical per-round
message bills to the host-loop ``dense`` mode, and all of them the exact
BZ cores — duplicate pairs, self-loops, no-op churn, and empty batches
included."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see "
                    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import bz_core_numbers
from repro.distribution.compat import make_mesh
from repro.graph.structs import Graph
from repro.streaming import (EdgeBatch, StreamingConfig,
                             StreamingKCoreEngine)
# tests/ is not a package; pytest puts it on sys.path (prepend import mode)
from test_fused import assert_exact_equal


@st.composite
def graph_and_churn(draw):
    """Small random graph + a short sequence of messy churn batches:
    duplicate pairs, self-loops, no-op inserts/deletes, empty batches,
    and deletes of never-present edges are all the common case."""
    n = draw(st.integers(2, 12))

    def pairs(max_len):
        return draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            max_size=max_len))

    edges = pairs(30)
    batches = [EdgeBatch.make(insert=pairs(10), delete=pairs(10))
               for _ in range(draw(st.integers(1, 3)))]
    return n, edges, batches


@settings(max_examples=20, deadline=None)
@given(graph_and_churn())
def test_fused_modes_exact_property(case):
    """Property (ISSUE 4 acceptance): after EVERY batch, fused and
    sharded+fused produce identical cores AND identical per-round message
    bills to dense, and all three equal the BZ oracle."""
    n, edges, batches = case
    g = Graph.from_edges(np.asarray(edges, np.int64).reshape(-1, 2), n=n)
    mesh = make_mesh((1,), ("data",))
    dense = StreamingKCoreEngine(g, StreamingConfig(frontier="dense"))
    fused = StreamingKCoreEngine(g, StreamingConfig(frontier="fused"))
    fsh = StreamingKCoreEngine(g, StreamingConfig(frontier="fused"),
                               mesh=mesh)
    for batch in batches:
        r1 = dense.apply_batch(batch)
        r2 = fused.apply_batch(batch)
        r3 = fsh.apply_batch(batch)
        assert_exact_equal(r1, r2)
        assert_exact_equal(r1, r3)
        assert (r1.core == bz_core_numbers(dense.graph)).all()
