"""Temporal subsystem: event-log format + IO, window semantics (hypothesis
property: k window advances == one explicit EdgeBatch), the replay driver,
as-of serving, and the 10k-vertex acceptance replay in all frontier modes."""

import numpy as np
import pytest

from repro.core import bz_core_numbers
from repro.graph import generators as gen
from repro.streaming import (EdgeBatch, KCoreServer, Request,
                             StreamingConfig, StreamingKCoreEngine)
from repro.temporal import (ADD, REMOVE, CoreCheckpointRing, EventLog,
                            WindowedKCoreEngine, contact_bursts,
                            load_event_log, parse_event_text, replay,
                            temporal_barabasi_albert,
                            temporal_snap_analogue)


# ---------------------------------------------------------------------- #
# Event log format
# ---------------------------------------------------------------------- #

def test_event_log_datacleanse_and_canonical():
    log = EventLog.make(time=[0.0, 1.0, 2.0, 3.0],
                        u=[5, 2, 3, 1], v=[1, 2, 0, 5],
                        kind=[1, 1, 1, -1], n=6)
    # self-loop (2,2) dropped; endpoints canonicalized to (min, max)
    assert len(log) == 3
    assert log.u.tolist() == [1, 0, 1]
    assert log.v.tolist() == [5, 3, 5]
    assert log.num_adds == 2
    ev = log[2]
    assert (ev.t, ev.u, ev.v, ev.is_add) == (3.0, 1, 5, False)


def test_event_log_rejects_bad_input():
    with pytest.raises(ValueError):        # non-monotone time
        EventLog.make([1.0, 0.5], [0, 1], [1, 2], [1, 1])
    with pytest.raises(ValueError):        # bad kind
        EventLog.make([0.0], [0], [1], [2])
    with pytest.raises(ValueError):        # id outside universe
        EventLog.make([0.0], [0], [9], [1], n=4)
    with pytest.raises(ValueError):        # negative id
        EventLog.make([0.0], [-1], [1], [1])


def test_edges_between_last_event_wins():
    # duplicate add/remove of one edge inside a range + re-insertion
    log = EventLog.make(
        time=[0, 1, 2, 3, 4, 5],
        u=[0, 0, 0, 1, 0, 1],
        v=[1, 1, 1, 2, 1, 2],
        kind=[ADD, REMOVE, ADD, ADD, REMOVE, REMOVE], n=3)
    assert log.edges_between(0, 4).tolist() == [[0, 1], [1, 2]]
    assert log.edges_between(0, 5).tolist() == [[1, 2]]   # (0,1) removed
    assert log.edges_between(0, 6).tolist() == []
    assert log.edges_between(2, 4).tolist() == [[0, 1], [1, 2]]
    # a range starting at a remove: the edge is absent there
    assert log.edges_between(1, 2).tolist() == []
    g = log.graph_between(0, 4)
    assert g.n == 3 and g.m == 2


def test_text_and_npz_round_trip(tmp_path):
    log = temporal_barabasi_albert(40, 2, seed=3, remove_frac=0.3)
    txt = parse_event_text(log.to_text(), n=log.n)
    assert len(txt) == len(log) and txt.n == log.n
    assert (txt.u == log.u).all() and (txt.kind == log.kind).all()
    assert np.allclose(txt.time, log.time)

    p = tmp_path / "log.npz"
    log.save_npz(str(p))
    npz = load_event_log(str(p))
    assert len(npz) == len(log) and npz.n == log.n
    assert (npz.u == log.u).all() and (npz.v == log.v).all()
    assert (npz.kind == log.kind).all() and (npz.time == log.time).all()

    # kind column optional in text: plain timestamped edge list = all adds
    plain = parse_event_text("0.5 0 1\n1.5 1 2\n# c\n", n=3)
    assert plain.num_adds == 2
    # an unrecognized kind token must be rejected, not silently read as add
    with pytest.raises(ValueError):
        parse_event_text("0.5 0 1 r\n", n=3)


def test_generators_are_valid_logs():
    for log in (temporal_barabasi_albert(60, 3, seed=1, remove_frac=0.2),
                contact_bursts(50, n_bursts=8, seed=1),
                temporal_snap_analogue("FC", scale=0.02, seed=1,
                                       remove_frac=0.2)):
        assert len(log) > 0
        assert (np.diff(log.time) >= 0).all()
        assert (log.u < log.v).all()
        assert int(log.v.max()) < log.n
        assert np.isin(log.kind, (ADD, REMOVE)).all()
        assert log.num_adds > 0
    # contact bursts tear every contact down again
    clog = contact_bursts(50, n_bursts=8, seed=1)
    assert (clog.kind == REMOVE).sum() > 0
    assert len(clog.edges_between(0, len(clog))) == 0


# ---------------------------------------------------------------------- #
# Window semantics: k advances == one explicit EdgeBatch
# (seeded spot-check here; the hypothesis sweep over random event logs
# lives in test_temporal_property.py)
# ---------------------------------------------------------------------- #

def _random_log(rng, n, n_events):
    u = rng.integers(0, n, size=n_events)
    v = rng.integers(0, n, size=n_events)
    kind = rng.choice([1, -1], size=n_events)
    time = np.cumsum(rng.integers(0, 4, size=n_events).astype(np.float64))
    return EventLog.make(time, u, v, kind, n=n)


def check_window_advance_equals_explicit_batch(log, window, stride, j, k):
    """After j warm-up advances, advancing k more strides must equal
    (a) one advance(k) call and (b) applying the equivalent explicit
    EdgeBatch to a StreamingKCoreEngine directly — same graph, same
    cores, and both exactly the BZ cores of the window graph."""
    wa = WindowedKCoreEngine(log, window, stride)
    wb = WindowedKCoreEngine(log, window, stride)
    for _ in range(j):
        wa.advance()
        wb.advance()

    # the direct path starts from the mid-point window graph
    mid_graph = wa.window_graph()
    direct = StreamingKCoreEngine(mid_graph)
    batch, _ = wa.peek_batch(k)

    for _ in range(k):
        wa.advance()               # k single advances
    wb.advance(k)                  # one k-stride advance
    res = direct.apply_batch(batch)    # one explicit EdgeBatch

    ga, gb, gd = wa.engine.graph, wb.engine.graph, direct.graph
    assert ga.m == gb.m == gd.m
    assert (ga.src == gb.src).all() and (ga.src == gd.src).all()
    assert (ga.dst == gb.dst).all() and (ga.dst == gd.dst).all()
    assert (wa.core == wb.core).all()
    assert (wa.core == res.core).all()
    # and the maintained edge set matches the declarative window semantics
    lo, hi = wa.bounds
    assert (wa.window_edges == log.edges_between(lo, hi)).all()
    assert (wa.core == bz_core_numbers(wa.window_graph())).all()


def test_window_advance_equals_explicit_batch_seeded():
    rng = np.random.default_rng(11)
    for _ in range(12):
        log = _random_log(rng, int(rng.integers(3, 11)),
                          int(rng.integers(1, 51)))
        check_window_advance_equals_explicit_batch(
            log, window=int(rng.integers(1, 13)),
            stride=int(rng.integers(1, 7)),
            j=int(rng.integers(0, 4)), k=int(rng.integers(1, 5)))


def test_count_window_rejects_fractional_stride():
    """A count-mode stride < 1 would truncate to 0 and never advance —
    must be rejected up front, not loop forever (fractional strides are
    legal in time mode, where they are real time spans)."""
    log = _random_log(np.random.default_rng(0), 5, 20)
    with pytest.raises(ValueError):
        WindowedKCoreEngine(log, 10, 0.5)
    with pytest.raises(ValueError):
        WindowedKCoreEngine(log, 0.5, 2)
    # floats >= 1 are fine (the CLI passes floats): truncated to events
    weng = WindowedKCoreEngine(log, 10.0, 2.9)
    assert (weng.window, weng.stride) == (10, 2)
    with pytest.raises(ValueError):
        WindowedKCoreEngine(log, 10, -1)
    with pytest.raises(ValueError):
        WindowedKCoreEngine(log, 10, 1, by="nope")


def test_time_window_matches_bz_seeded():
    rng = np.random.default_rng(12)
    for _ in range(6):
        log = _random_log(rng, int(rng.integers(3, 11)),
                          int(rng.integers(1, 51)))
        weng = WindowedKCoreEngine(log, window=float(rng.uniform(0.5, 8)),
                                   stride=float(rng.uniform(0.25, 4)),
                                   by="time")
        steps = 0
        while not weng.done and steps < 12:
            ws = weng.advance()
            lo, hi = weng.bounds
            assert (ws.lo, ws.hi) == (lo, hi)
            assert (weng.window_edges == log.edges_between(lo, hi)).all()
            assert (ws.core == bz_core_numbers(weng.window_graph())).all()
            steps += 1


# ---------------------------------------------------------------------- #
# Replay driver + CSR health surfacing
# ---------------------------------------------------------------------- #

def test_replay_trajectory_records_and_oracle():
    log = temporal_barabasi_albert(120, 3, seed=0, remove_frac=0.15)
    traj = replay(log, window=150, stride=60, oracle_every=2, track=4)
    assert len(traj.records) > 2
    assert traj.core_series.shape == (len(traj.records), traj.tracked.size)
    checked = [r.oracle_ok for r in traj.records]
    assert checked[0] is True                  # step 0 always checked
    assert checked[-1] is True                 # final step always checked
    assert any(ok is None for ok in checked)   # but not every step
    s = traj.summary()
    assert s["steps"] == len(traj.records)
    assert s["total_messages"] == traj.series("messages").sum()
    # core evolution is actually recorded: max core grows from 0
    assert traj.records[0].core_max <= s["max_core_seen"]
    # window deltas happened in both directions
    assert traj.series("inserted").sum() > 0
    assert traj.series("deleted").sum() > 0


def test_batch_result_exposes_csr_health():
    g = gen.barabasi_albert(80, 3, seed=0)
    eng = StreamingKCoreEngine(g, StreamingConfig(slack=0.0, min_slack=1))
    edges = np.stack([g.src[g.src < g.dst], g.dst[g.src < g.dst]], axis=1)
    res = eng.apply_batch(EdgeBatch.make(delete=edges[:20]))
    assert res.csr_dead_frac > 0               # deletions leave holes
    assert 0 < res.csr_occupancy <= 1
    assert res.csr_compactions == eng.csr.compactions
    # hammer one row so a compaction must fire and the counter moves
    res2 = eng.apply_batch(EdgeBatch.make(
        insert=[(0, t) for t in range(1, 41)]))
    assert res2.csr_compactions > res.csr_compactions
    assert res2.csr_dead_frac <= res.csr_dead_frac  # compaction drops holes


# ---------------------------------------------------------------------- #
# As-of serving
# ---------------------------------------------------------------------- #

def test_checkpoint_ring_asof_and_eviction():
    ring = CoreCheckpointRing(capacity=3)
    with pytest.raises(KeyError):
        ring.asof(0.0)
    for t in (1.0, 2.0, 3.0, 4.0):             # 1.0 evicted by capacity
        ring.push(t, np.full(4, int(t)))
    assert ring.times.tolist() == [2.0, 3.0, 4.0]
    bt, core = ring.asof(3.7)
    assert bt == 3.0 and (core == 3).all()
    assert ring.asof(4.0)[0] == 4.0            # boundary hit is inclusive
    assert ring.asof(99.0)[0] == 4.0
    with pytest.raises(KeyError):
        ring.asof(1.5)                          # predates retained window
    with pytest.raises(ValueError):
        ring.push(2.0, np.zeros(4))             # time must not go backwards
    # snapshots are read-only: retained history cannot be corrupted
    # through the reference asof hands out
    with pytest.raises(ValueError):
        core[0] = 99


def test_checkpoint_ring_edge_cases():
    with pytest.raises(ValueError):
        CoreCheckpointRing(capacity=0)

    # capacity=1: every push evicts the previous snapshot
    ring = CoreCheckpointRing(capacity=1)
    ring.push(1.0, np.full(3, 1))
    ring.push(2.0, np.full(3, 2))
    assert len(ring) == 1 and ring.times.tolist() == [2.0]
    assert ring.asof(2.0)[0] == 2.0            # exact-boundary hit
    with pytest.raises(KeyError):
        ring.asof(1.0)                          # evicted boundary

    # equal timestamps are legal (non-decreasing); asof answers the LATEST
    # snapshot at that time (searchsorted side="right")
    ring2 = CoreCheckpointRing(capacity=4)
    ring2.push(5.0, np.full(2, 1))
    ring2.push(5.0, np.full(2, 2))
    bt, core = ring2.asof(5.0)
    assert bt == 5.0 and (core == 2).all()

    # many wraparounds: the window of retained boundaries keeps sliding
    ring3 = CoreCheckpointRing(capacity=3)
    for t in range(10):
        ring3.push(float(t), np.full(2, t))
    assert ring3.times.tolist() == [7.0, 8.0, 9.0]
    bt, core = ring3.asof(8.5)
    assert bt == 8.0 and (core == 8).all()
    with pytest.raises(KeyError):
        ring3.asof(6.999)                       # just below oldest retained
    bt, core = ring3.asof(7.0)                  # oldest retained, exact hit
    assert bt == 7.0 and (core == 7).all()


def test_server_windowed_replay_and_asof_queries():
    log = temporal_snap_analogue("FC", scale=0.03, seed=0, remove_frac=0.2)
    weng = WindowedKCoreEngine(log, window=300, stride=120)
    srv = KCoreServer(windowed=weng, asof_capacity=4)
    snaps = []
    for _ in range(5):
        ws = srv.advance_window()
        snaps.append((ws.t_hi, ws.result.core.copy()))
    # exact at the head, and each retained boundary replays its snapshot
    assert (srv.core == bz_core_numbers(weng.window_graph())).all()
    assert len(srv.asof_ring) == 4              # capacity evicted snap 0
    for t, core in snaps[1:]:
        bt, got = srv.core_asof(t)
        assert bt == t and (got == core).all()
    # as-of BETWEEN boundaries answers from the earlier one
    t_mid = 0.5 * (snaps[2][0] + snaps[3][0])
    bt, got = srv.core_asof(t_mid, vertices=[0, 1, 2])
    assert bt == snaps[2][0] and (got == snaps[2][1][:3]).all()
    with pytest.raises(KeyError):
        srv.core_asof(snaps[0][0])              # evicted
    # the Request op round-trips through serve()
    out = srv.serve([Request(op="core_asof", t=snaps[3][0],
                             vertices=np.arange(5))])
    assert out[0].payload[0] == snaps[3][0]
    assert (out[0].payload[1] == snaps[3][1][:5]).all()
    assert srv.stats()["asof_boundaries"] == 4
    with pytest.raises(ValueError):             # static server: no window
        KCoreServer(gen.cycle(8)).advance_window()
    with pytest.raises(ValueError):             # exactly one of g/windowed
        KCoreServer(gen.cycle(8), windowed=weng)
    with pytest.raises(ValueError):             # engine knobs belong to the
        KCoreServer(windowed=weng,              # WindowedKCoreEngine
                    config=StreamingConfig(frontier="compact"))
    # direct updates would desync the window's edge-set bookkeeping
    with pytest.raises(ValueError):
        srv.update(EdgeBatch.make(insert=[(0, 1)]))
    # through the request loop the same misuse comes back as a structured
    # error Response (front ends must never die on a bad request)
    [resp] = srv.serve([Request(op="update",
                                batch=EdgeBatch.make(insert=[(0, 1)]))])
    assert not resp.ok and "advance_window" in resp.error


# ---------------------------------------------------------------------- #
# Acceptance: 10k-vertex temporal SNAP analogue, all frontier modes
# ---------------------------------------------------------------------- #

def test_windowed_replay_10k_snap_analogue_all_modes():
    """ISSUE 3/4 acceptance: windowed replay over a 10k-vertex temporal
    SNAP analogue maintains exact core numbers at every window boundary in
    dense, compact, sharded, and fused frontier modes — BZ-verified on the
    dense leg, and the other modes must match its cores AND per-round
    message bills exactly."""
    entry = gen.SNAP_BY_ABBREV["EEN"]
    log = temporal_snap_analogue("EEN", scale=10_000 / entry.n, seed=0,
                                 remove_frac=0.15)
    assert log.n >= 10_000
    stride = len(log) // 5
    window = 2 * stride

    engines = {mode: WindowedKCoreEngine(log, window, stride,
                                         config=StreamingConfig(
                                             frontier=mode))
               for mode in ("dense", "compact", "sharded", "fused")}
    steps = 0
    while not engines["dense"].done and steps < 4:
        ws = {mode: e.advance() for mode, e in engines.items()}
        ref = ws["dense"]
        # sliding (not just growing) windows must be exercised
        wg = engines["dense"].window_graph()
        assert (ref.result.core == bz_core_numbers(wg)).all(), (
            f"step {steps}: dense cores diverged from the BZ oracle")
        for mode in ("compact", "sharded", "fused"):
            got = ws[mode]
            assert (got.result.core == ref.result.core).all(), (
                f"step {steps}: {mode} cores diverged from dense")
            assert (got.result.stats.messages_per_round
                    == ref.result.stats.messages_per_round).all(), (
                f"step {steps}: {mode} message bill diverged from dense")
        steps += 1
    assert steps == 4
    # the tail expired events: windows actually slid
    lo, hi = engines["dense"].bounds
    assert lo > 0
