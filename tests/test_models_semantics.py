"""Model-semantics tests beyond smoke: equivariance, SWA, MoE math."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import MoEConfig


def _rot_matrix(key):
    """Random rotation via QR."""
    A = jax.random.normal(key, (3, 3))
    Q, R = jnp.linalg.qr(A)
    return Q * jnp.sign(jnp.diag(R))[None, :]


@pytest.mark.parametrize("arch", ["mace", "egnn", "schnet"])
def test_geometric_invariance(arch):
    """Rotating + translating all positions must not change the (scalar)
    node embeddings — the equivariance contract of the geometric GNNs."""
    from repro.models.gnn import steps as gsteps
    from repro.models.gnn.common import batch_molecules
    cfg = get_smoke(arch)
    batch = batch_molecules(4, 8, 14, 4, seed=0)
    params = gsteps.init_params(cfg, jax.random.key(0))
    mod = gsteps.model_module(cfg)
    h0 = mod.node_embeddings(params, cfg, batch)
    R = _rot_matrix(jax.random.key(5))
    batch2 = dict(batch)
    batch2["positions"] = np.asarray(batch["positions"] @ np.asarray(R).T
                                     + 1.7)
    h1 = mod.node_embeddings(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(h0, np.float32),
                               np.asarray(h1, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_swa_masks_far_context():
    """With window w, tokens farther than w in the past cannot influence
    the output: perturb an early token, outputs beyond the window match."""
    from repro.models.transformer import model as M
    cfg = get_smoke("mixtral-8x22b")       # window 32
    params = M.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 96), 0, cfg.vocab)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    h1, _ = M.forward_hidden(params, cfg, toks)
    h2, _ = M.forward_hidden(params, cfg, toks2)
    # effective receptive field after L=2 layers = L*w = 64: beyond that,
    # position 0 cannot reach the output
    diff = np.abs(np.asarray(h1 - h2, np.float32)).max(axis=-1)[0]
    assert diff[80:].max() < 1e-3
    assert diff[:16].max() > 1e-3           # but it does change nearby


@pytest.mark.xfail(
    jax.__version__.startswith("0.4."),
    reason="known pre-seed numeric drift in the MoE virtual-split path on "
           "jax 0.4.37 (ROADMAP.md); exact on jax >= 0.5. Observed on "
           "0.4.37: max |h1-h2| = 3.125e-2 (vs atol 3e-2) in the bf16 "
           "forward, max ~2.95e4 bf16 ulp at near-zero activations, mean "
           "7.7 ulp. strict: an accidental fix or a worsening regression "
           "must surface, not pass silently",
    strict=True)
def test_moe_virtual_split_is_exact():
    """split-2 virtual experts must equal the unsplit computation when the
    params are tied accordingly."""
    from repro.models.transformer import model as M
    base = get_smoke("mixtral-8x22b")
    cfg1 = dataclasses.replace(
        base, moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                            virtual_split=1))
    cfg2 = dataclasses.replace(
        base, moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                            virtual_split=2))
    p1 = M.init_params(cfg1, jax.random.key(0))
    # build split params from p1: expert e -> (e*2, e*2+1) halves along f
    p2 = jax.tree.map(lambda x: x, p1)
    moe1 = p1["layers"]["moe"]
    L, E, d, f = moe1["w_up"].shape

    def split_up(w):      # (L, E, d, f) -> (L, 2E, d, f/2)
        return w.reshape(L, E, d, 2, f // 2).transpose(0, 1, 3, 2, 4) \
                .reshape(L, 2 * E, d, f // 2)

    def split_down(w):    # (L, E, f, d) -> (L, 2E, f/2, d)
        return w.reshape(L, E, 2, f // 2, d).reshape(L, 2 * E, f // 2, d)

    p2["layers"]["moe"] = dict(moe1)
    p2["layers"]["moe"]["w_up"] = split_up(moe1["w_up"])
    p2["layers"]["moe"]["w_gate"] = split_up(moe1["w_gate"])
    p2["layers"]["moe"]["w_down"] = split_down(moe1["w_down"])

    toks = jax.random.randint(jax.random.key(3), (2, 32), 0, base.vocab)
    h1, _ = M.forward_hidden(p1, cfg1, toks)
    h2, _ = M.forward_hidden(p2, cfg2, toks)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=3e-2)


def test_moe_pad_experts_never_selected():
    from repro.models.transformer import model as M
    cfg = get_smoke("qwen2-moe-a2.7b")   # 8 experts padded to 10
    params = M.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
    h, aux = M.forward_hidden(params, cfg, toks)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    # dummy-expert weights receive zero gradient
    g = jax.grad(lambda p: M.lm_loss(p, cfg, toks,
                                     jnp.roll(toks, -1, 1)))(params)
    gu = np.asarray(g["layers"]["moe"]["w_up"])  # (L, E_eff, d, f)
    assert np.abs(gu[:, cfg.moe.n_experts:, :, :]).max() == 0.0


def test_lm_loss_decreases_with_training():
    """End-to-end: 30 steps on the smoke config actually learn."""
    from repro.data import synth_lm_batch
    from repro.models.transformer import model as M
    from repro.models.transformer.steps import make_train_step
    from repro.optim import AdamWConfig, adamw_init
    cfg = get_smoke("qwen1.5-0.5b")
    params = M.init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, None,
                                   AdamWConfig(lr=3e-3, weight_decay=0.0),
                                   total_steps=30))
    losses = []
    for i in range(30):
        t, l = synth_lm_batch(cfg.vocab, 8, 64, seed=0, step=i)
        params, opt, m = step(params, opt, jnp.asarray(t), jnp.asarray(l))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_embedding_bag_modes():
    from repro.models.recsys.embedding_bag import (embedding_bag,
                                                   ragged_embedding_bag)
    table = jnp.arange(12.0).reshape(4, 3)
    idx = jnp.array([[0, 1, -1], [2, -1, -1]])
    s = embedding_bag(table, idx, mode="sum")
    np.testing.assert_allclose(np.asarray(s[0]), np.asarray(table[0] +
                                                            table[1]))
    m = embedding_bag(table, idx, mode="mean")
    np.testing.assert_allclose(np.asarray(m[1]), np.asarray(table[2]))
    r = ragged_embedding_bag(table, jnp.array([0, 1, 2]),
                             jnp.array([0, 0, 1]), 2)
    np.testing.assert_allclose(np.asarray(r[0]),
                               np.asarray(table[0] + table[1]))
