"""Live observability endpoint: /metrics, /healthz, /debug/flight.

Spins a real ``ObsHTTPServer`` on an ephemeral port and scrapes it with
urllib — the same path a Prometheus poller or the CI obs lane takes.
"""

from __future__ import annotations

import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import kcore_decompose
from repro.graph import generators as gen
from repro.obs import flight, health, metrics
from repro.obs.http import ObsHTTPServer, start_server
from repro.obs.metrics import MetricsRegistry


@pytest.fixture()
def server():
    srv = start_server(port=0)
    yield srv
    srv.stop()


def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=5) as resp:
            return resp.status, resp.headers.get("Content-Type"), resp.read()
    except urllib.error.HTTPError as err:           # 4xx/5xx still carry a body
        return err.code, err.headers.get("Content-Type"), err.read()


def test_ephemeral_port_and_index(server):
    assert server.port > 0
    assert server.url == f"http://127.0.0.1:{server.port}"
    code, ctype, body = _get(server.url + "/")
    assert code == 200
    assert b"/metrics" in body and b"/healthz" in body


def test_metrics_endpoint_serves_prometheus_text(server):
    metrics.counter("obs_http_test_total", probe="a").inc(3)
    code, ctype, body = _get(server.url + "/metrics")
    assert code == 200
    assert ctype.startswith("text/plain")
    assert "version=0.0.4" in ctype
    text = body.decode()
    assert '# TYPE obs_http_test_total counter' in text
    assert 'obs_http_test_total{probe="a"} 3.0' in text


def test_added_registry_is_rendered(server):
    reg = MetricsRegistry()
    reg.counter("side_registry_total", op="core").inc()
    server.add_registry(reg)
    server.add_registry(reg)                        # dedup: no double render
    text = _get(server.url + "/metrics")[2].decode()
    assert text.count('side_registry_total{op="core"} 1.0') == 1


def test_concurrent_scrapes_while_registries_are_added(server):
    """/metrics scrapes run on per-connection threads; mounting registries
    from the main thread mid-scrape must never produce an error or a torn
    render (the registry list is copied under the server lock)."""
    import threading

    stop = threading.Event()
    errs: list[Exception] = []

    def scrape():
        try:
            while not stop.is_set():
                code, _, body = _get(server.url + "/metrics")
                assert code == 200 and body is not None
        except Exception as exc:             # pragma: no cover - failure
            errs.append(exc)

    threads = [threading.Thread(target=scrape, daemon=True)
               for _ in range(3)]
    for th in threads:
        th.start()
    for i in range(20):
        reg = MetricsRegistry()
        reg.counter(f"late_registry_{i}_total").inc()
        server.add_registry(reg)
    stop.set()
    for th in threads:
        th.join(timeout=10)
    assert not errs
    text = _get(server.url + "/metrics")[2].decode()
    assert "late_registry_19_total 1.0" in text


def test_healthz_ok_then_503_on_anomaly(server):
    health.reset()
    try:
        code, ctype, body = _get(server.url + "/healthz")
        assert code == 200 and ctype == "application/json"
        v = json.loads(body)
        assert v["status"] == "ok" and v["anomalies"] == 0

        # feed the default monitor a rising estimate — the endpoint flips
        rec = flight.FlightRecorder()
        health.install(rec)
        rec.start_run("static", "host")
        rec.record_round(4, 10, 1, est=np.asarray([5, 9]),
                         prev_est=np.asarray([5, 5]))
        code, _, body = _get(server.url + "/healthz")
        assert code == 503
        v = json.loads(body)
        assert v["status"] == "anomalous"
        assert v["kinds"]["non_monotone_estimate"] >= 1
    finally:
        health.reset()


def test_debug_flight_serves_recent_records(server):
    flight.enable()
    flight.reset()
    try:
        kcore_decompose(gen.barabasi_albert(150, 3, seed=6))
        code, ctype, body = _get(server.url + "/debug/flight")
        assert code == 200 and ctype == "application/json"
        payload = json.loads(body)
        assert payload["enabled"] is True
        assert payload["runs"] == 1
        assert payload["rounds_recorded"] == len(payload["records"]) > 2
        rounds = [r["round"] for r in payload["records"]]
        assert rounds == list(range(len(rounds)))

        limited = json.loads(_get(server.url + "/debug/flight?n=2")[2])
        assert len(limited["records"]) == 2
        assert limited["records"] == payload["records"][-2:]
    finally:
        flight.disable()
        flight.reset()


def test_debug_flight_when_disabled(server):
    flight.disable()
    flight.reset()
    payload = json.loads(_get(server.url + "/debug/flight")[2])
    assert payload["enabled"] is False
    assert payload["records"] == []


def test_unknown_route_is_404(server):
    code, _, _ = _get(server.url + "/nope")
    assert code == 404


def test_stop_closes_the_socket():
    srv = ObsHTTPServer(port=0).start()
    url = srv.url
    assert _get(url + "/")[0] == 200
    srv.stop()
    with pytest.raises(Exception):
        urllib.request.urlopen(url + "/", timeout=1)
