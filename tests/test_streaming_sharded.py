"""Mesh-parallel streaming maintenance: the sharded and fused_sharded
frontier modes must be exact-equal (cores AND per-round message counts) to
the single-device engine, in-process on a 1-device mesh and in a
subprocess on forced multi-device host meshes."""

import json
import subprocess
import sys

import numpy as np
import pytest

from repro.core import bz_core_numbers
from repro.distribution.compat import make_mesh
from repro.graph import generators as gen
from repro.streaming import (EdgeBatch, StreamingConfig,
                             StreamingKCoreEngine, canonical_edges,
                             random_churn_batch)


def _batches(g, rng):
    """One insert-only, one delete-only, one mixed batch."""
    edges = canonical_edges(g)
    return {
        "insert": EdgeBatch.make(insert=rng.integers(0, g.n, size=(15, 2))),
        "delete": EdgeBatch.make(
            delete=edges[rng.choice(edges.shape[0], 15, replace=False)]),
        "mixed": random_churn_batch(g, 12, 12, rng),
    }


@pytest.mark.parametrize("kind", ["insert", "delete", "mixed"])
def test_sharded_apply_batch_matches_dense_1dev(kind):
    """In-process (1-device mesh): sharded apply_batch == dense apply_batch
    in cores, per-round messages, actives, and the BZ oracle."""
    g = gen.barabasi_albert(250, 4, seed=5)
    mesh = make_mesh((1,), ("data",))
    dense = StreamingKCoreEngine(g, StreamingConfig(frontier="dense"))
    shard = StreamingKCoreEngine(g, StreamingConfig(frontier="sharded"),
                                 mesh=mesh)
    assert (shard.init_result.stats.total_messages
            == dense.init_result.stats.total_messages)
    rng = np.random.default_rng(6)
    batch = _batches(g, rng)[kind]
    r1, r2 = dense.apply_batch(batch), shard.apply_batch(batch)
    assert r2.mode == "sharded"
    assert (r1.core == r2.core).all()
    assert (r1.stats.messages_per_round
            == r2.stats.messages_per_round).all()
    assert (r1.stats.active_per_round == r2.stats.active_per_round).all()
    assert (r1.core == bz_core_numbers(dense.graph)).all()


def test_auto_mode_picks_and_stays_exact():
    """auto picks compact below the frontier threshold and the fused mesh
    mode above it; every choice stays BZ-exact."""
    g = gen.barabasi_albert(300, 4, seed=8)
    mesh = make_mesh((1,), ("data",))
    eng = StreamingKCoreEngine(
        g, StreamingConfig(frontier="auto", compact_threshold=0.02),
        mesh=mesh)
    rng = np.random.default_rng(9)
    seen = set()
    # a tiny batch localizes the frontier -> compact; heavy churn -> the
    # device-resident fused loop on the mesh
    for batch in (EdgeBatch.make(delete=canonical_edges(eng.graph)[:1]),
                  random_churn_batch(eng.graph, 60, 60, rng)):
        res = eng.apply_batch(batch)
        seen.add(res.mode)
        assert (res.core == bz_core_numbers(eng.graph)).all()
    assert "compact" in seen and "fused_sharded" in seen


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import json
import numpy as np
from repro.core import bz_core_numbers
from repro.distribution.compat import make_mesh
from repro.graph import generators as gen
from repro.streaming import (EdgeBatch, StreamingConfig,
                             StreamingKCoreEngine, canonical_edges,
                             random_churn_batch)

mesh = make_mesh({mesh_shape}, {axes})
g = gen.barabasi_albert(400, 4, seed=2)
dense = StreamingKCoreEngine(g, StreamingConfig(frontier="dense"))
shard = StreamingKCoreEngine(g, StreamingConfig(frontier="sharded"),
                             mesh=mesh, axis_names={axes})
fused = StreamingKCoreEngine(g, StreamingConfig(frontier="fused"),
                             mesh=mesh, axis_names={axes})
rng = np.random.default_rng(0)
edges = canonical_edges(g)
batches = [
    EdgeBatch.make(insert=rng.integers(0, g.n, size=(15, 2))),
    EdgeBatch.make(delete=edges[rng.choice(edges.shape[0], 15,
                                           replace=False)]),
    random_churn_batch(g, 12, 12, rng),
]
rounds = []
for b in batches:
    r1, r2 = dense.apply_batch(b), shard.apply_batch(b)
    r3 = fused.apply_batch(b)
    assert r3.mode == "fused_sharded", r3.mode
    assert (r1.core == r2.core).all(), "core mismatch"
    assert (r1.stats.messages_per_round
            == r2.stats.messages_per_round).all(), "msg mismatch"
    assert (r1.core == r3.core).all(), "fused core mismatch"
    assert (r1.stats.messages_per_round
            == r3.stats.messages_per_round).all(), "fused msg mismatch"
    assert r1.rounds == r3.rounds, "fused round mismatch"
    assert (r1.core == bz_core_numbers(dense.graph)).all(), "oracle"
    rounds.append(r2.rounds)
print(json.dumps({{"rounds": rounds}}))
"""


@pytest.mark.parametrize("ndev,mesh_shape,axes", [
    (4, (4,), ("data",)),
    (4, (2, 2), ("data", "model")),
])
def test_sharded_streaming_multidevice(ndev, mesh_shape, axes):
    """Subprocess (forced host devices): insert-only / delete-only / mixed
    batches give identical cores and message bills on real multi-device
    meshes, for both the per-round sharded mode and the fused while_loop
    (ISSUE 4 acceptance: fused exact on 1- and 2-axis meshes)."""
    import jax

    if jax.device_count() >= 4:
        pytest.skip("in-process multi-device lane covers this")
    script = _SCRIPT.format(ndev=ndev, mesh_shape=mesh_shape,
                            axes=tuple(axes))
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # keep jax off accelerator probing (the TPU plugin's GCP
             # metadata retries burn minutes in a hermetic env)
             "JAX_PLATFORMS": "cpu"}, cwd="/root/repo", timeout=500)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert len(out["rounds"]) == 3


@pytest.mark.parametrize("mesh_shape,axes", [
    ((4,), ("data",)),
    ((2, 2), ("data", "model")),
])
def test_sharded_streaming_multidevice_inprocess(mesh_shape, axes):
    """The subprocess parity sweep run IN-PROCESS on the forced-multi-device
    lane (conftest applied REPRO_HOST_DEVICES before backend init)."""
    import jax

    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices (REPRO_HOST_DEVICES lane)")
    g = gen.barabasi_albert(400, 4, seed=2)
    mesh = make_mesh(mesh_shape, axes)
    dense = StreamingKCoreEngine(g, StreamingConfig(frontier="dense"))
    shard = StreamingKCoreEngine(g, StreamingConfig(frontier="sharded"),
                                 mesh=mesh, axis_names=axes)
    fused = StreamingKCoreEngine(g, StreamingConfig(frontier="fused"),
                                 mesh=mesh, axis_names=axes)
    rng = np.random.default_rng(0)
    edges = canonical_edges(g)
    batches = [
        EdgeBatch.make(insert=rng.integers(0, g.n, size=(15, 2))),
        EdgeBatch.make(delete=edges[rng.choice(edges.shape[0], 15,
                                               replace=False)]),
        random_churn_batch(g, 12, 12, rng),
    ]
    for b in batches:
        r1, r2 = dense.apply_batch(b), shard.apply_batch(b)
        r3 = fused.apply_batch(b)
        assert r3.mode == "fused_sharded", r3.mode
        assert (r1.core == r2.core).all()
        assert (r1.stats.messages_per_round
                == r2.stats.messages_per_round).all()
        assert (r1.core == r3.core).all()
        assert (r1.stats.messages_per_round
                == r3.stats.messages_per_round).all()
        assert r1.rounds == r3.rounds
        assert (r1.core == bz_core_numbers(dense.graph)).all()
