"""Per-architecture smoke tests (deliverable f): every assigned arch runs a
reduced-config forward/train step on CPU with shape + finiteness asserts."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.configs.base import ShapeSpec
from repro.optim import adamw_init

LM_ARCHS = [a for a in ARCH_IDS
            if get_smoke(a).family == "lm"]
GNN_ARCHS = [a for a in ARCH_IDS if get_smoke(a).family == "gnn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models.transformer import model as M
    from repro.models.transformer.steps import make_train_step
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.key(0))
    B, S = 2, 64
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    step = jax.jit(make_train_step(cfg, None))
    p2, o2, metrics = step(params, adamw_init(params), tokens, labels)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 20
    # params actually changed
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode_consistency(arch):
    from repro.models.transformer import model as M
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.key(0))
    B, S = 2, 64
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits_pf, cache = M.prefill(params, cfg, tokens)
    assert logits_pf.shape == (B, cfg.vocab)
    ck = jnp.concatenate([cache["k"][:, :, :, :-1],
                          jnp.zeros_like(cache["k"][:, :, :, :1])], axis=3)
    cv = jnp.concatenate([cache["v"][:, :, :, :-1],
                          jnp.zeros_like(cache["v"][:, :, :, :1])], axis=3)
    logits_dec, _ = M.decode_step(params, cfg, tokens[:, -1:],
                                  {"k": ck, "v": cv}, jnp.int32(S - 1))
    tol = 0.05 if cfg.moe else 1e-3   # capacity-drop artifact for MoE
    assert float(jnp.max(jnp.abs(logits_pf - logits_dec))) <= tol


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("kind", ["full_graph", "molecule"])
def test_gnn_smoke(arch, kind):
    from repro.graph import generators as gen
    from repro.models.gnn import steps as gsteps
    from repro.models.gnn.common import batch_from_graph, batch_molecules
    cfg = get_smoke(arch)
    if kind == "full_graph":
        g = gen.erdos_renyi(100, 350, seed=0)
        shape = ShapeSpec("t", "full_graph",
                          {"n_nodes": g.n, "n_edges": g.m, "d_feat": 12,
                           "n_classes": 5})
        batch = batch_from_graph(g, 12, 5, seed=1)
        params = gsteps.init_params(cfg, jax.random.key(0), d_in=12,
                                    n_classes=5)
    else:
        shape = ShapeSpec("m", "molecule",
                          {"n_nodes": 10, "n_edges": 20, "batch": 6})
        batch = batch_molecules(6, 10, 20, 4, seed=2)
        params = gsteps.init_params(cfg, jax.random.key(0))
    step = jax.jit(gsteps.make_train_step(cfg, shape))
    p2, o2, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))


def test_gnn_minibatch_smoke():
    from repro.graph import generators as gen
    from repro.graph.sampler import sample_subgraph
    from repro.models.gnn import steps as gsteps
    from repro.models.gnn.common import batch_from_sampled
    cfg = get_smoke("graphcast")
    g = gen.barabasi_albert(500, 4, seed=0)
    sub = sample_subgraph(g, np.arange(16), (5, 3), seed=1)
    batch = batch_from_sampled(g, sub, d_feat=12, n_classes=5)
    shape = ShapeSpec("mb", "minibatch",
                      {"batch_nodes": 16, "fanout": (5, 3), "d_feat": 12,
                       "n_classes": 5})
    params = gsteps.init_params(cfg, jax.random.key(0), d_in=12, n_classes=5)
    step = jax.jit(gsteps.make_train_step(cfg, shape))
    p2, o2, metrics = step(params, adamw_init(params),
                           {k: v for k, v in batch.items() if k != "n_seeds"})
    assert np.isfinite(float(metrics["loss"]))


def test_din_smoke_all_kinds():
    from repro.models.recsys import din, steps as rsteps
    cfg = get_smoke("din")
    params = din.init_params(cfg, jax.random.key(0))
    tr = rsteps.synth_batch(cfg, ShapeSpec("t", "train", {"batch": 16}))
    p2, o2, m = jax.jit(rsteps.make_train_step(cfg))(
        params, adamw_init(params), tr)
    assert np.isfinite(float(m["loss"]))
    sv = rsteps.synth_batch(cfg, ShapeSpec("s", "serve", {"batch": 8}))
    probs = jax.jit(rsteps.make_serve_step(cfg))(params, sv)
    assert probs.shape == (8,) and bool(jnp.isfinite(probs).all())
    rt = rsteps.synth_batch(cfg, ShapeSpec("r", "retrieval",
                                           {"batch": 1, "n_candidates": 512}))
    vals, idx = jax.jit(rsteps.make_retrieval_step(cfg, top_k=10))(params, rt)
    assert vals.shape == (10,) and idx.shape == (10,)


@pytest.mark.parametrize("arch", list(ARCH_IDS))
def test_full_configs_match_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    from repro.configs import get_config
    cfg = get_config(arch)
    expect = {
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                                n_kv_heads=16, d_ff=1408, vocab=151936),
        "mixtral-8x22b": dict(n_layers=56, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=32768),
        "yi-34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                       d_ff=20480, vocab=64000),
        "granite-34b": dict(n_layers=88, d_model=6144, n_heads=48,
                            n_kv_heads=1, d_ff=24576, vocab=49152),
        "qwen1.5-0.5b": dict(n_layers=24, d_model=1024, n_heads=16,
                             n_kv_heads=16, d_ff=2816, vocab=151936),
        "mace": dict(n_layers=2, d_hidden=128),
        "graphcast": dict(n_layers=16, d_hidden=512),
        "schnet": dict(n_layers=3, d_hidden=64),
        "egnn": dict(n_layers=4, d_hidden=64),
        "din": dict(embed_dim=18, seq_len=100, attn_mlp=(80, 40),
                    mlp=(200, 80)),
    }[arch]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    if arch == "qwen2-moe-a2.7b":
        assert cfg.moe.n_experts == 60 and cfg.moe.top_k == 4 \
            and cfg.moe.n_shared == 4
    if arch == "mixtral-8x22b":
        assert cfg.moe.n_experts == 8 and cfg.moe.top_k == 2
        assert cfg.swa_window is not None
    if arch == "mace":
        assert cfg.params["l_max"] == 2 and cfg.params["correlation"] == 3 \
            and cfg.params["n_rbf"] == 8


def test_param_counts_plausible():
    from repro.configs import get_config
    sizes = {"mixtral-8x22b": (130e9, 150e9), "yi-34b": (32e9, 37e9),
             "granite-34b": (30e9, 38e9), "qwen1.5-0.5b": (0.4e9, 0.55e9),
             "qwen2-moe-a2.7b": (13e9, 16e9)}
    for arch, (lo, hi) in sizes.items():
        n = get_config(arch).n_params
        assert lo < n < hi, (arch, n)
    a = get_config("qwen2-moe-a2.7b").n_active_params
    assert 2e9 < a < 3.5e9, a
