"""Platform-config layer (repro.platform): dispatch-mode vocabulary, env
plumbing, roofline peaks, and the forced-host-device-count lane (the env
mutation is backend-init-order sensitive, so the device-count assertions
run in subprocesses)."""

import subprocess
import sys

import pytest

from repro import platform

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}


# ----------------------- dispatch-mode vocabulary ---------------------- #

@pytest.mark.parametrize("raw,want", [
    ("auto", "auto"), ("pallas", "pallas"), ("xla", "xla"),
    ("on", "pallas"), ("1", "pallas"), ("true", "pallas"),
    ("off", "xla"), ("0", "xla"), ("false", "xla"),
    ("  ON ", "pallas"), ("Off", "xla"),
])
def test_normalize_dispatch(raw, want):
    assert platform.normalize_dispatch(raw) == want


def test_normalize_dispatch_unknown_warns_and_defaults():
    with pytest.warns(RuntimeWarning, match="unknown dispatch mode"):
        assert platform.normalize_dispatch("vulkan") == "auto"


def test_dispatch_mode_priority(monkeypatch):
    """Override beats env beats the auto default."""
    monkeypatch.delenv(platform.ENV_DISPATCH, raising=False)
    platform.set_dispatch_mode(None)
    assert platform.dispatch_mode() == "auto"
    monkeypatch.setenv(platform.ENV_DISPATCH, "on")
    assert platform.dispatch_mode() == "pallas"
    platform.set_dispatch_mode("off")
    try:
        assert platform.dispatch_mode() == "xla"
    finally:
        platform.set_dispatch_mode(None)
    assert platform.dispatch_mode() == "pallas"


def test_set_platform_rejects_unknown():
    with pytest.raises(ValueError, match="platform must be one of"):
        platform.set_platform("abacus")


# ----------------------------- peaks ----------------------------------- #

def test_peaks_defaults_and_env_override(monkeypatch):
    monkeypatch.delenv(platform.ENV_PEAK_GFLOPS, raising=False)
    monkeypatch.delenv(platform.ENV_PEAK_GBS, raising=False)
    flops, bw = platform.peaks("tpu")
    assert flops == 197e12 and bw == 819e9   # matches launch.hlo_analysis
    monkeypatch.setenv(platform.ENV_PEAK_GFLOPS, "123")
    monkeypatch.setenv(platform.ENV_PEAK_GBS, "45")
    flops, bw = platform.peaks("cpu")
    assert flops == 123e9 and bw == 45e9


def test_summary_reports_resolved_state():
    s = platform.summary()
    assert s["backend"] in ("cpu", "gpu", "tpu")
    assert s["device_count"] >= 1
    assert s["dispatch_mode"] in ("auto", "pallas", "xla")
    assert s["peak_gflops"] > 0 and s["peak_gbs"] > 0


# ----------------------- forced host device count ---------------------- #

def test_force_host_device_count_rewrites_flag(monkeypatch):
    monkeypatch.setenv(
        "XLA_FLAGS",
        "--xla_force_host_platform_device_count=2 --xla_foo=bar")
    import os

    import warnings
    with warnings.catch_warnings():
        # jax backends are already live in this test process — the warning
        # about late configuration is expected and not under test here
        warnings.simplefilter("ignore", RuntimeWarning)
        platform.force_host_device_count(8)
    flags = os.environ["XLA_FLAGS"].split()
    assert "--xla_force_host_platform_device_count=8" in flags
    assert "--xla_foo=bar" in flags
    assert sum(f.startswith("--xla_force_host_platform_device_count")
               for f in flags) == 1


def test_force_host_device_count_rejects_nonpositive():
    with pytest.raises(ValueError):
        platform.force_host_device_count(0)


def test_configure_from_env_forces_devices_subprocess():
    """REPRO_HOST_DEVICES=4 + configure_from_env() before backend init →
    jax sees 4 host devices (the CI forced-multi-device lane mechanism)."""
    script = (
        "import repro.platform as p\n"
        "applied = p.configure_from_env()\n"
        "assert applied == {'host_devices': 4}, applied\n"
        "import jax\n"
        "assert jax.device_count() == 4, jax.device_count()\n"
        "print('OK')\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={**_ENV, "REPRO_HOST_DEVICES": "4"}, cwd="/root/repo",
        timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert proc.stdout.strip().endswith("OK")


def test_configure_from_env_noop_without_vars(monkeypatch):
    for var in (platform.ENV_PLATFORM, platform.ENV_HOST_DEVICES,
                platform.ENV_X64):
        monkeypatch.delenv(var, raising=False)
    assert platform.configure_from_env() == {}
