"""Multi-process (jax.distributed) mesh: the fused sharded runtime spans
processes through distribution/compat — two coordinated ranks, each with two
forced host devices, decompose on the 4-device GLOBAL mesh and must match
the single-process host loop and the BZ oracle bit for bit.

Subprocess-driven like tests/test_distributed.py: each rank is its own
interpreter (its own jax runtime), rendezvousing on a localhost coordinator
port. Skips where the CPU backend has no cross-process collectives.
"""

import json
import socket
import subprocess
import sys

import pytest

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        # keep jax off accelerator probing (the TPU plugin's GCP metadata
        # retries burn minutes in a hermetic env)
        "JAX_PLATFORMS": "cpu"}

_RANK_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
from repro.distribution import compat

rank, nproc, port = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
compat.init_multiprocess(f"localhost:{port}", nproc, rank)
import jax
assert jax.process_count() == nproc, jax.process_count()

from repro.core import bz_core_numbers, kcore_decompose, \
    kcore_decompose_sharded
from repro.graph import generators as gen

mesh = compat.global_mesh("shard")
assert compat.is_multiprocess_mesh(mesh)
g = gen.barabasi_albert(300, 3, seed=7)

# the per-round host loop cannot span processes — loud error, not a hang
try:
    kcore_decompose_sharded(g, mesh, ("shard",))
    raise SystemExit("expected ValueError for non-fused multiprocess")
except ValueError:
    pass

res = kcore_decompose_sharded(g, mesh, ("shard",), fused=True)
ref = kcore_decompose(g)          # process-local single-device reference
assert (res.core == ref.core).all(), "core mismatch"
assert (res.core == bz_core_numbers(g)).all(), "bz mismatch"
assert (res.stats.messages_per_round
        == ref.stats.messages_per_round).all(), "msg bill mismatch"
assert (res.stats.active_per_round
        == ref.stats.active_per_round).all(), "active mismatch"
assert (res.stats.changed_per_round
        == ref.stats.changed_per_round).all(), "changed mismatch"
assert res.rounds == ref.rounds
print(json.dumps({"rank": rank, "devices": jax.device_count(),
                  "local_devices": jax.local_device_count(),
                  "rounds": res.rounds,
                  "messages": int(res.stats.total_messages)}))
"""

_NO_COLLECTIVES = ("Multiprocess computations aren't implemented",
                   "collectives", "UNIMPLEMENTED")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def test_fused_sharded_spans_two_processes():
    nproc, port = 2, _free_port()
    procs = [subprocess.Popen(
        [sys.executable, "-c", _RANK_SCRIPT, str(r), str(nproc), str(port)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=_ENV, cwd="/root/repo") for r in range(nproc)]
    outs = [p.communicate(timeout=500) for p in procs]
    for p, (out, err) in zip(procs, outs):
        if p.returncode != 0 and any(s in err for s in _NO_COLLECTIVES):
            pytest.skip("no CPU cross-process collectives in this jax")
        assert p.returncode == 0, err[-2000:]
    reports = [json.loads(out.strip().splitlines()[-1]) for out, _ in outs]
    # every rank saw the GLOBAL topology and the same exact result
    for rep in reports:
        assert rep["devices"] == 4
        assert rep["local_devices"] == 2
    assert reports[0]["rounds"] == reports[1]["rounds"] > 0
    assert reports[0]["messages"] == reports[1]["messages"] > 0


def test_multiprocess_helpers_single_process():
    """The compat helpers degrade cleanly on an ordinary single process."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.distribution import compat

    assert not compat.is_multiprocess()
    mesh = compat.global_mesh("shard")
    assert not compat.is_multiprocess_mesh(mesh)
    n_dev = len(mesh.devices.flat)
    arr = np.arange(n_dev * 3, dtype=np.int32).reshape(n_dev, 3)
    staged = compat.stage_to_mesh(arr, mesh, P("shard"))
    np.testing.assert_array_equal(compat.fetch_replicated(staged, mesh), arr)
    # hint is safe to call repeatedly even after backend init
    compat.cpu_collectives_hint()
