"""Snapshot-isolated concurrent serving: isolation, drain, warm restart.

The contracts under test (streaming/concurrent.py + server state_dict):

  * old-or-new-never-torn: readers hammering during flips — including a
    flip artificially held open mid-publication — always see ONE complete
    published fixpoint, bit-equal to the registered snapshot of the
    version they report;
  * structured errors through the pool: a malformed read comes back as an
    error Response, never an exception, and the pool stays alive;
  * drain → checkpoint → restore: a drained server's checkpoint loads
    into a fresh process-equivalent server which continues the replay in
    LOCKSTEP — bit-equal cores and message bills to an uninterrupted run
    (the warm-restart acceptance);
  * /metrics scrapes and /query reads stay coherent while flips and
    updates run concurrently (obs/http.py thread safety).
"""

from __future__ import annotations

import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import bz_core_numbers
from repro.graph import generators as gen
from repro.streaming import (ConcurrentKCoreServer, KCoreServer, Request,
                             SnapshotBox, StreamingConfig,
                             random_churn_batch)
from repro.streaming.concurrent import CoreSnapshot
from repro.temporal import WindowedKCoreEngine, temporal_barabasi_albert


def _static_front(n=200, seed=0, workers=4, **kw):
    g = gen.barabasi_albert(n, 3, seed=seed)
    return ConcurrentKCoreServer(KCoreServer(g), read_workers=workers, **kw)


def _windowed_server(n=250, seed=1, ticks=8):
    log = temporal_barabasi_albert(n, 3, seed=seed, remove_frac=0.1)
    stride = max(len(log) // (ticks + 2), 1)
    weng = WindowedKCoreEngine(log, 3 * stride, stride, by="count")
    return KCoreServer(windowed=weng, asof_capacity=ticks + 2)


# ---------------------------------------------------------------------- #
# seqlock / snapshot isolation
# ---------------------------------------------------------------------- #

class _SlowBox(SnapshotBox):
    """A SnapshotBox whose publication window is held open: version goes
    odd, then the snapshot swap waits, then even. Readers entering during
    the window MUST spin — returning would hand them a torn flip."""

    hold_s = 0.02

    def publish(self, snap):
        with self._write_lock:
            self._version += 1
            time.sleep(self.hold_s)          # flip held open mid-publication
            self._snap = snap
            time.sleep(self.hold_s)
            self._version += 1
            self.flips += 1


def test_seqlock_readers_never_see_mid_flip_state():
    box = _SlowBox()
    core0 = np.arange(5, dtype=np.int32)
    snaps = [CoreSnapshot(version=i, core=core0 + i, n=5, m=0, max_k=0,
                          asof=None, batches_applied=i, t_hi=None,
                          published_at=time.perf_counter())
             for i in range(1, 4)]
    box.publish(snaps[0])

    stop = threading.Event()
    seen, errs = [], []

    def reader():
        try:
            while not stop.is_set():
                s = box.read()
                # a complete snapshot is self-consistent: core == core0 + v
                assert (s.core == core0 + s.version).all()
                seen.append(s.version)
        except AssertionError as exc:        # pragma: no cover - failure
            errs.append(exc)

    threads = [threading.Thread(target=reader, daemon=True)
               for _ in range(4)]
    for th in threads:
        th.start()
    for s in snaps[1:]:
        box.publish(s)                       # each flip held open ~40ms
    stop.set()
    for th in threads:
        th.join(timeout=10)
    assert not errs
    assert set(seen) <= {1, 2, 3} and len(seen) > 0
    # readers overlapped the held-open flips, so every version was observed
    assert max(seen) == 3


def test_hammer_reads_during_updates_are_bit_equal_to_a_fixpoint():
    front = _static_front(n=300, seed=2)
    registry = {front.snapshot.version: front.snapshot}
    rng = np.random.default_rng(0)
    stop = threading.Event()
    checked, errs = [0], []

    def reader(seed):
        r = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                v = r.integers(0, 300, size=16)
                resp = front.read(Request(op="core", vertices=v))
                assert resp.ok
                snap = registry[resp.version]
                assert (resp.payload == snap.core[v]).all(), "torn read"
                checked[0] += 1
        except Exception as exc:             # pragma: no cover - failure
            errs.append(exc)

    threads = [threading.Thread(target=reader, args=(10 + i,), daemon=True)
               for i in range(4)]
    for th in threads:
        th.start()
    for _ in range(6):
        b = random_churn_batch(front.server.engine.graph, 10, 10, rng)
        front.update(b)
        snap = front.snapshot
        registry[snap.version] = snap
        # every published fixpoint is the oracle's (reads are BZ-anchored)
        ref = bz_core_numbers(front.server.engine.graph)
        assert (snap.core == ref).all()
    stop.set()
    for th in threads:
        th.join(timeout=10)
    assert not errs and checked[0] > 0
    assert front.box.flips == 7


def test_snapshot_survives_engine_churn():
    front = _static_front(n=150, seed=3)
    snap = front.snapshot
    before = snap.core.copy()
    rng = np.random.default_rng(1)
    for _ in range(3):
        front.update(random_churn_batch(front.server.engine.graph,
                                        15, 15, rng))
    assert (snap.core == before).all()       # old snapshot is immutable
    assert not snap.core.flags.writeable
    assert front.snapshot.version == snap.version + 3


# ---------------------------------------------------------------------- #
# structured errors + drain through the worker pool
# ---------------------------------------------------------------------- #

def test_pool_reads_return_structured_errors_and_stay_alive():
    front = _static_front(n=50, seed=4, workers=2)
    out = front.serve_concurrent([
        Request(op="core", vertices=[0, 1]),
        Request(op="core", vertices=[999]),          # bad id
        Request(op="in_kcore", vertices=[0]),        # missing k
        Request(op="nope"),                          # unknown op
        Request(op="update"),                        # write via read path
        Request(op="core", vertices=[2]),            # pool still serving
    ])
    assert out[0].ok and out[5].ok
    assert [not r.ok for r in out[1:5]] == [True] * 4
    assert "out of range" in out[1].error
    assert "requires k" in out[2].error
    assert "not a read" in out[4].error
    # errors are rejected before snapshot acquisition: no version tag
    assert all(r.version is None for r in out[1:5])
    assert all(r.version == front.snapshot.version for r in (out[0], out[5]))


def test_drain_refuses_new_reads_and_is_idempotent(tmp_path):
    front = _static_front(n=60, seed=5,
                          checkpoint_dir=str(tmp_path / "ck"))
    assert front.read(Request(op="max_k")).ok
    path = front.drain(save=True, step=7)
    assert path and path.endswith("step_000000007")
    with pytest.raises(RuntimeError, match="draining"):
        front.submit_read(Request(op="max_k"))
    assert front.drain(save=True, step=7)            # idempotent


# ---------------------------------------------------------------------- #
# warm restart: drain -> checkpoint -> restore -> lockstep continuation
# ---------------------------------------------------------------------- #

def _advance_bills(server, ticks):
    """Advance a windowed server; return the exact per-tick evidence."""
    rows = []
    for _ in range(ticks):
        ws = server.advance_window()
        rows.append((ws.m, int(ws.result.total_messages),
                     int(ws.result.rounds), ws.result.core.tobytes()))
    return rows


def test_windowed_drain_checkpoint_resumes_in_lockstep(tmp_path):
    from repro.checkpoint import restore_checkpoint

    # uninterrupted reference: 6 window advances
    ref = _advance_bills(_windowed_server(), 6)

    # interrupted run: 3 advances under concurrent read load, then drain
    srv_a = _windowed_server()
    front = ConcurrentKCoreServer(srv_a, read_workers=2,
                                  checkpoint_dir=str(tmp_path))
    first = []
    for _ in range(3):
        ws = front.advance_window()
        front.serve_concurrent([Request(op="max_k"),
                                Request(op="core", vertices=[0, 1, 2])])
        first.append((ws.m, int(ws.result.total_messages),
                      int(ws.result.rounds), ws.result.core.tobytes()))
    path = front.drain(save=True, step=3)
    assert path

    # fresh server (new engine, new log replayed from the same spec)
    srv_b = _windowed_server()
    state, step = restore_checkpoint(tmp_path, like=srv_b.state_dict())
    assert step == 3
    srv_b.load_state_dict(state)
    assert (srv_b.core == srv_a.core).all()
    assert len(srv_b.asof_ring) == len(srv_a.asof_ring)
    rest = _advance_bills(srv_b, 3)

    # bit-equal continuation: cores AND message bills match the
    # uninterrupted run tick for tick
    assert first + rest == ref


def test_static_state_dict_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    g = gen.barabasi_albert(120, 3, seed=6)
    srv_a = KCoreServer(g, StreamingConfig(frontier="compact"))
    rng = np.random.default_rng(7)
    srv_a.update(random_churn_batch(srv_a.engine.graph, 20, 10, rng))
    srv_a.asof_ring.push(1.0, srv_a.core)
    srv_a.asof_ring.push(2.0, srv_a.core)
    save_checkpoint(tmp_path, 1, srv_a.state_dict())

    srv_b = KCoreServer(g, StreamingConfig(frontier="compact"))
    state, _ = restore_checkpoint(tmp_path, like=srv_b.state_dict())
    srv_b.load_state_dict(state)
    assert (srv_b.core == srv_a.core).all()
    assert srv_b.asof_ring.times.tolist() == [1.0, 2.0]
    bt, core = srv_b.core_asof(1.5)
    assert bt == 1.0 and (core == srv_a.asof_ring.asof(1.5)[1]).all()

    # identical continuation from the restored CSR
    batch = random_churn_batch(srv_a.engine.graph, 10, 10,
                               np.random.default_rng(8))
    ra, rb = srv_a.update(batch), srv_b.update(batch)
    assert (ra.core == rb.core).all()
    assert ra.total_messages == rb.total_messages


def test_mode_mismatch_checkpoints_are_rejected():
    static = KCoreServer(gen.cycle(10))
    windowed = _windowed_server()
    with pytest.raises(ValueError, match="windowed"):
        static.load_state_dict(windowed.state_dict())
    with pytest.raises(ValueError, match="static"):
        windowed.load_state_dict(static.state_dict())


# ---------------------------------------------------------------------- #
# obs/http.py under concurrent serving
# ---------------------------------------------------------------------- #

def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=5) as resp:
            return resp.status, resp.read()
    except urllib.error.HTTPError as err:
        return err.code, err.read()


def test_metrics_scrapes_and_queries_during_flips():
    from repro.obs.http import start_server

    front = _static_front(n=200, seed=9)
    httpd = start_server(port=0)
    try:
        httpd.add_registry(front.server.metrics)
        httpd.attach_query_backend(front)
        stop = threading.Event()
        errs = []

        def scraper():
            try:
                while not stop.is_set():
                    code, body = _get(httpd.url + "/metrics")
                    assert code == 200
                    assert b"kcore_snapshot_flips_total" in body
                    code, body = _get(httpd.url + "/query/core?v=0,1,2")
                    assert code == 200
                    out = json.loads(body)
                    assert out["ok"] and len(out["payload"]) == 3
            except Exception as exc:         # pragma: no cover - failure
                errs.append(exc)

        threads = [threading.Thread(target=scraper, daemon=True)
                   for _ in range(3)]
        for th in threads:
            th.start()
        rng = np.random.default_rng(2)
        for _ in range(5):
            front.update(random_churn_batch(front.server.engine.graph,
                                            10, 10, rng))
        stop.set()
        for th in threads:
            th.join(timeout=10)
        assert not errs

        # structured HTTP errors from the same routes
        code, body = _get(httpd.url + "/query/core?v=99999")
        assert code == 400 and b"out of range" in body
        code, _ = _get(httpd.url + "/query/nope")
        assert code == 400
        code, body = _get(httpd.url + "/query/stats")
        assert code == 200
        assert json.loads(body)["snapshot_flips"] == 6

        front.drain(save=False)
        code, body = _get(httpd.url + "/query/max_k")
        assert code == 503 and b"draining" in body
    finally:
        httpd.stop()


def test_query_routes_404_without_backend():
    from repro.obs.http import start_server

    httpd = start_server(port=0)
    try:
        code, body = _get(httpd.url + "/query/max_k")
        assert code == 404 and b"no query backend" in body
    finally:
        httpd.stop()


def test_flight_records_snapshot_flip_events():
    from repro.obs import flight

    flight.enable()
    flight.reset()
    try:
        front = _static_front(n=80, seed=11)
        front.update(random_churn_batch(front.server.engine.graph, 5, 5,
                                        np.random.default_rng(3)))
        evs = flight.get_recorder().events()
        flips = [e for e in evs if e["kind"] == "snapshot_flip"]
        assert [e["version"] for e in flips] == [1, 2]
        assert flips[-1]["max_k"] == front.snapshot.max_k
        payload = flight.to_json()
        assert payload["events"][-1]["kind"] == "snapshot_flip"
    finally:
        flight.disable()
        flight.reset()
