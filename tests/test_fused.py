"""Fused device-resident convergence (ISSUE 4): the ``fused`` frontier mode
— one jitted lax.while_loop per batch — must be EXACT-equal to the
host-loop ``dense`` mode in cores AND per-round message accounting
(messages / active / changed per round, round count, convergence flag),
single-device and through the nested-shard_map ``fused_sharded`` variant,
and BZ-oracle-correct after every batch."""

import numpy as np

from repro.core import bz_core_numbers
from repro.distribution.compat import make_mesh
from repro.graph import generators as gen
from repro.graph.structs import Graph
from repro.streaming import (EdgeBatch, StreamingConfig,
                             StreamingKCoreEngine, canonical_edges,
                             random_churn_batch)


def assert_exact_equal(ref, got):
    """Full BatchResult accounting equality (not just the cores)."""
    assert (ref.core == got.core).all()
    assert (ref.stats.messages_per_round
            == got.stats.messages_per_round).all()
    assert (ref.stats.active_per_round == got.stats.active_per_round).all()
    assert (ref.stats.changed_per_round
            == got.stats.changed_per_round).all()
    assert ref.rounds == got.rounds
    assert ref.converged == got.converged


def test_fused_equals_dense_random_churn():
    g = gen.barabasi_albert(200, 4, seed=9)
    dense = StreamingKCoreEngine(g, StreamingConfig(frontier="dense"))
    fused = StreamingKCoreEngine(g, StreamingConfig(frontier="fused"))
    rng = np.random.default_rng(4)
    for _ in range(4):
        batch = random_churn_batch(dense.graph, 10, 10, rng)
        r1, r2 = dense.apply_batch(batch), fused.apply_batch(batch)
        assert r2.mode == "fused"
        assert_exact_equal(r1, r2)
        assert (r2.core == bz_core_numbers(dense.graph)).all()


def test_fused_sharded_equals_dense_1dev():
    g = gen.barabasi_albert(180, 4, seed=5)
    mesh = make_mesh((1,), ("data",))
    dense = StreamingKCoreEngine(g, StreamingConfig(frontier="dense"))
    fsh = StreamingKCoreEngine(g, StreamingConfig(frontier="fused"),
                               mesh=mesh)
    rng = np.random.default_rng(6)
    for _ in range(3):
        batch = random_churn_batch(dense.graph, 10, 10, rng)
        r1, r2 = dense.apply_batch(batch), fsh.apply_batch(batch)
        assert r2.mode == "fused_sharded"
        assert_exact_equal(r1, r2)
        assert (r2.core == bz_core_numbers(dense.graph)).all()


def test_fused_cascades_deletes_and_empty_batch():
    """The fused while_loop must handle the extremes the host loop does:
    a multi-pass cascade (K8 from empty: every core 0 -> 7), delete-all,
    and the empty batch (zero messages, zero rounds, loop never entered)."""
    eng = StreamingKCoreEngine(Graph.from_edges(np.zeros((0, 2)), n=8),
                               StreamingConfig(frontier="fused"))
    iu = np.triu_indices(8, k=1)
    res = eng.apply_batch(EdgeBatch.make(insert=np.stack(iu, axis=1)))
    assert (res.core == 7).all() and res.converged

    empty = eng.apply_batch(EdgeBatch.make())
    assert empty.total_messages == 0 and empty.rounds == 0
    assert (empty.core == 7).all()

    res = eng.apply_batch(EdgeBatch.make(delete=canonical_edges(eng.graph)))
    assert (res.core == 0).all() and res.converged


def test_fused_respects_max_rounds_cap():
    """A tight round cap must stop the while_loop exactly where the host
    loop stops — same partial estimate, same accounting, converged=False."""
    g = gen.cycle(40)
    cfg = dict(max_rounds=1)
    dense = StreamingKCoreEngine(g, StreamingConfig(frontier="dense", **cfg))
    fused = StreamingKCoreEngine(g, StreamingConfig(frontier="fused", **cfg))
    # deleting one edge unravels the 2-core cycle one step per round — far
    # more rounds than the cap allows
    batch = EdgeBatch.make(delete=canonical_edges(g)[:1])
    r1, r2 = dense.apply_batch(batch), fused.apply_batch(batch)
    assert not r1.converged
    assert_exact_equal(r1, r2)


def test_auto_prefers_fused_above_compact_threshold():
    g = gen.barabasi_albert(300, 4, seed=8)
    eng = StreamingKCoreEngine(
        g, StreamingConfig(frontier="auto", compact_threshold=0.02))
    rng = np.random.default_rng(9)
    seen = set()
    for batch in (EdgeBatch.make(delete=canonical_edges(eng.graph)[:1]),
                  random_churn_batch(eng.graph, 60, 60, rng)):
        res = eng.apply_batch(batch)
        seen.add(res.mode)
        assert (res.core == bz_core_numbers(eng.graph)).all()
    assert seen == {"compact", "fused"}
