"""Distributed integration tests: run the sharded engines on multiple
forced host devices.

Two delivery mechanisms, mutually exclusive per process:
* single-device process (the default dev/test environment): SUBPROCESS
  tests export the force flag themselves, so the main process keeps its
  single real device (the dryrun-only flag contract);
* forced-multi-device process (CI's ``REPRO_HOST_DEVICES=4`` lane, applied
  by conftest via repro.platform before backend init): the IN-PROCESS mesh
  tests run directly and the subprocess ones skip — same coverage, no
  interpreter-per-case overhead.
"""

import json
import subprocess
import sys

import pytest


def _device_count() -> int:
    import jax

    return jax.device_count()


def _skip_unless_multidevice(need: int = 4):
    if _device_count() < need:
        pytest.skip(f"needs >= {need} devices (REPRO_HOST_DEVICES lane)")


def _skip_if_multidevice():
    if _device_count() >= 4:
        pytest.skip("in-process multi-device lane covers this")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import json
import jax
import numpy as np
from repro.graph import generators as gen
from repro.core import bz_core_numbers, kcore_decompose, kcore_decompose_sharded
from repro.distribution.compat import make_mesh

mesh = make_mesh({mesh_shape}, {axes})
g = gen.barabasi_albert(400, 4, seed=2)
res = kcore_decompose_sharded(g, mesh, {axes})
ref = kcore_decompose(g)
assert (res.core == bz_core_numbers(g)).all(), "core mismatch"
assert res.stats.total_messages == ref.stats.total_messages, "msg mismatch"
fus = kcore_decompose_sharded(g, mesh, {axes}, fused=True)
assert (fus.core == ref.core).all(), "fused core mismatch"
assert (fus.stats.messages_per_round
        == ref.stats.messages_per_round).all(), "fused msg mismatch"
assert (fus.stats.active_per_round
        == ref.stats.active_per_round).all(), "fused active mismatch"
assert fus.rounds == ref.rounds, "fused rounds mismatch"
print(json.dumps({{"rounds": res.rounds,
                   "messages": int(res.stats.total_messages)}}))
"""


@pytest.mark.parametrize("ndev,mesh_shape,axes", [
    (4, (4,), ("data",)),
    (8, (2, 4), ("data", "model")),
    (8, (2, 2, 2), ("pod", "data", "model")),
])
def test_sharded_kcore_multidevice(ndev, mesh_shape, axes):
    """Sharded engine (host loop AND static fused while_loop): identical
    cores and message accounting to the single-device run, on 1-, 2- and
    3-axis meshes."""
    _skip_if_multidevice()
    script = _SCRIPT.format(ndev=ndev, mesh_shape=mesh_shape,
                            axes=tuple(axes), naxes=len(axes))
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # keep jax off accelerator probing (the TPU plugin's GCP
             # metadata retries burn minutes in a hermetic env)
             "JAX_PLATFORMS": "cpu"}, cwd="/root/repo", timeout=500)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["rounds"] > 0


@pytest.mark.parametrize("mesh_shape,axes", [
    ((4,), ("data",)),
    ((2, 2), ("data", "model")),
])
def test_sharded_kcore_multidevice_inprocess(mesh_shape, axes):
    """The same mesh parity as the subprocess test, but IN-PROCESS on the
    forced-multi-device lane (conftest applied REPRO_HOST_DEVICES before
    backend init): sharded host loop and fused while_loop are bit-equal to
    the single-device run and the BZ oracle on a real 4-device mesh."""
    _skip_unless_multidevice(4)
    from repro.core import (bz_core_numbers, kcore_decompose,
                            kcore_decompose_sharded)
    from repro.distribution.compat import make_mesh
    from repro.graph import generators as gen

    mesh = make_mesh(mesh_shape, axes)
    g = gen.barabasi_albert(400, 4, seed=2)
    res = kcore_decompose_sharded(g, mesh, axes)
    ref = kcore_decompose(g)
    assert (res.core == bz_core_numbers(g)).all()
    assert res.stats.total_messages == ref.stats.total_messages
    fus = kcore_decompose_sharded(g, mesh, axes, fused=True)
    assert (fus.core == ref.core).all()
    assert (fus.stats.messages_per_round
            == ref.stats.messages_per_round).all()
    assert (fus.stats.active_per_round == ref.stats.active_per_round).all()
    assert fus.rounds == ref.rounds


def test_lm_train_step_2x2_mesh():
    """Smoke LM train step sharded over a 2x2 mesh in a subprocess."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_smoke
from repro.models.transformer import steps as S, model as M
from repro.configs.base import ShapeSpec
from repro.optim import adamw_init
from repro.distribution.compat import make_mesh
cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2)
mesh = make_mesh((2, 2), ("data", "model"))
shape = ShapeSpec("t", "train", {"seq_len": 64, "global_batch": 4})
step, specs, in_sh, out_sh = S.build_step(cfg, shape, mesh)
params = M.init_params(cfg, jax.random.key(0))
opt = adamw_init(params)
tokens = jax.random.randint(jax.random.key(1), (4, 64), 0, cfg.vocab)
jit = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
p2, o2, m = jit(params, opt, tokens, jnp.roll(tokens, -1, 1))
loss_sharded = float(m["loss"])
# single-device reference
p2r, o2r, mr = jax.jit(S.make_train_step(cfg, None))(
    params, opt, tokens, jnp.roll(tokens, -1, 1))
assert abs(loss_sharded - float(mr["loss"])) < 0.05, \
    (loss_sharded, float(mr["loss"]))
print("OK", loss_sharded)
"""
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # keep jax off accelerator probing (the TPU plugin's GCP
             # metadata retries burn minutes in a hermetic env)
             "JAX_PLATFORMS": "cpu"}, cwd="/root/repo", timeout=500)
    assert proc.returncode == 0, proc.stderr[-2000:]


def test_elastic_checkpoint_restore():
    """Checkpoint on 1 device, restore on 4 (elastic resharding)."""
    script = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.distribution.compat import make_mesh
d = tempfile.mkdtemp()
state = {"w": jnp.arange(16.0).reshape(4, 4)}
save_checkpoint(d, 5, state)
mesh = make_mesh((4,), ("data",))
sh = {"w": NamedSharding(mesh, P("data", None))}
restored, step = restore_checkpoint(d, state, shardings=sh)
assert step == 5
assert len(restored["w"].sharding.device_set) == 4
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.asarray(state["w"]))
print("OK")
"""
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # keep jax off accelerator probing (the TPU plugin's GCP
             # metadata retries burn minutes in a hermetic env)
             "JAX_PLATFORMS": "cpu"}, cwd="/root/repo", timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
