"""Graph IO: dataCleanse parsing rules and round-trips."""


from repro.graph.io import (parse_edge_list, parse_json_adjacency,
                            to_json_adjacency)
from repro.graph.structs import Graph


def test_json_adjacency_n_covers_neighbor_values():
    """Regression: {"0": [5]} must build a 6-vertex graph, not a 1-vertex
    graph with out-of-range neighbor ids."""
    g = parse_json_adjacency('{"0": [5]}')
    assert g.n == 6
    assert g.m == 1
    g.validate()
    assert (g.dst < g.n).all()
    assert list(g.neighbors(0)) == [5]
    assert list(g.neighbors(5)) == [0]


def test_json_adjacency_roundtrip():
    g = Graph.from_edges([(0, 1), (1, 2), (2, 0), (2, 3)], n=5)
    g2 = parse_json_adjacency(to_json_adjacency(g))
    # isolated vertex 4 survives (its key counts toward n), as do all edges
    assert g2.n == g.n
    assert g2.m == g.m
    assert (g2.src == g.src).all() and (g2.dst == g.dst).all()


def test_json_adjacency_empty():
    g = parse_json_adjacency("{}")
    assert g.n == 0 and g.m == 0


def test_json_adjacency_one_sided_lists():
    """Neighbor lists need not be symmetric in the input; dataCleanse
    symmetrizes and dedupes."""
    g = parse_json_adjacency('{"0": [1, 1, 2], "1": [0], "3": []}')
    assert g.n == 4
    assert g.m == 2
    assert g.deg[3] == 0


def test_edge_list_comments_and_separators():
    g = parse_edge_list("# header\n0 1\n1,2\n% alt comment\n2\t0\n")
    assert g.n == 3 and g.m == 3


def test_parse_edge_list_ragged_columns():
    """Mixed column counts (e.g. a temporal u v t row) keep columns 0-1."""
    g = parse_edge_list("0 1 999\n1 2\n2 0 7 8\n")
    assert g.n == 3 and g.m == 3


def test_load_edge_list_streams_chunks(tmp_path):
    """Chunked loading is bit-identical to the slurped parse, even with a
    chunk size small enough to split the file many times."""
    import numpy as np

    from repro.graph.io import iter_edge_chunks, load_edge_list

    rng = np.random.default_rng(0)
    e = rng.integers(0, 500, size=(3000, 2))
    lines = ["# snap header", "% alt comment"]
    lines += [f"{u}\t{v}" for u, v in e]
    p = tmp_path / "edges.txt"
    p.write_text("\n".join(lines) + "\n")

    ref = parse_edge_list(p.read_text())
    for chunk_bytes in (1 << 24, 4096, 64):
        g = load_edge_list(str(p), chunk_bytes=chunk_bytes)
        assert g.n == ref.n and g.m == ref.m
        assert (g.src == ref.src).all() and (g.dst == ref.dst).all()
    # every chunk is a well-formed (k, 2) block and they cover the file
    total = sum(len(c) for c in iter_edge_chunks(str(p), 4096))
    assert total == len(e)


def test_load_edge_list_uniform_three_columns(tmp_path):
    """A uniformly 3-column (temporal SNAP) file takes the vectorized fast
    path and still keeps only (src, dst)."""
    p = tmp_path / "t.txt"
    p.write_text("0 1 100\n1 2 101\n2 0 102\n")
    from repro.graph.io import load_edge_list
    g = load_edge_list(str(p))
    assert g.n == 3 and g.m == 3


def test_load_edge_list_empty_and_comments_only(tmp_path):
    from repro.graph.io import load_edge_list
    p = tmp_path / "empty.txt"
    p.write_text("# nothing here\n%\n\n")
    g = load_edge_list(str(p))
    assert g.n == 0 and g.m == 0
