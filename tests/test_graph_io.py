"""Graph IO: dataCleanse parsing rules and round-trips."""


from repro.graph.io import (parse_edge_list, parse_json_adjacency,
                            to_json_adjacency)
from repro.graph.structs import Graph


def test_json_adjacency_n_covers_neighbor_values():
    """Regression: {"0": [5]} must build a 6-vertex graph, not a 1-vertex
    graph with out-of-range neighbor ids."""
    g = parse_json_adjacency('{"0": [5]}')
    assert g.n == 6
    assert g.m == 1
    g.validate()
    assert (g.dst < g.n).all()
    assert list(g.neighbors(0)) == [5]
    assert list(g.neighbors(5)) == [0]


def test_json_adjacency_roundtrip():
    g = Graph.from_edges([(0, 1), (1, 2), (2, 0), (2, 3)], n=5)
    g2 = parse_json_adjacency(to_json_adjacency(g))
    # isolated vertex 4 survives (its key counts toward n), as do all edges
    assert g2.n == g.n
    assert g2.m == g.m
    assert (g2.src == g.src).all() and (g2.dst == g.dst).all()


def test_json_adjacency_empty():
    g = parse_json_adjacency("{}")
    assert g.n == 0 and g.m == 0


def test_json_adjacency_one_sided_lists():
    """Neighbor lists need not be symmetric in the input; dataCleanse
    symmetrizes and dedupes."""
    g = parse_json_adjacency('{"0": [1, 1, 2], "1": [0], "3": []}')
    assert g.n == 4
    assert g.m == 2
    assert g.deg[3] == 0


def test_edge_list_comments_and_separators():
    g = parse_edge_list("# header\n0 1\n1,2\n% alt comment\n2\t0\n")
    assert g.n == 3 and g.m == 3
