"""Substrate tests: graph structs/IO/partition/sampler, optimizer,
compression, checkpoint + fault-tolerant driver, data pipeline."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.graph import generators as gen, io
from repro.graph.partition import balance_report, shard_graph
from repro.graph.sampler import sample_subgraph
from repro.graph.structs import Graph, build_ell, pad_graph_for_shards


# ------------------------------ graph -------------------------------- #

def test_datacleanse_rules():
    """Paper §IV.B: no self-loops, no multi-edges, symmetrized."""
    g = Graph.from_edges([(0, 1), (1, 0), (0, 0), (0, 1), (2, 1)], n=3)
    assert g.m == 2                       # {0,1}, {1,2}
    assert (g.deg == np.array([1, 2, 1])).all()
    g.validate()


def test_json_roundtrip():
    g = gen.barabasi_albert(50, 3, seed=0)
    g2 = io.parse_json_adjacency(io.to_json_adjacency(g))
    assert g2.n == g.n and g2.m == g.m
    assert (g2.src == g.src).all() and (g2.dst == g.dst).all()


def test_edge_list_parse():
    g = io.parse_edge_list("# comment\n0\t1\n1 2\n2,0\n")
    assert g.n == 3 and g.m == 3


def test_shard_graph_covers_all_arcs():
    g = gen.barabasi_albert(200, 4, seed=0)
    for shards in [1, 3, 8]:
        sg = shard_graph(g, shards)
        assert sg.arc_mask.sum() == g.num_arcs
        rep = balance_report(sg)
        assert rep["arcs_per_shard_max"] <= sg.arcs_per_shard
        # every real arc's global src matches
        for d in range(shards):
            sel = sg.arc_mask[d]
            glob_src = sg.src[d][sel] + d * sg.verts_per_shard
            assert (np.sort(glob_src) == np.sort(glob_src)).all()


def test_ell_buckets_cover_all_vertices():
    g = gen.barabasi_albert(300, 5, seed=1)
    ell = build_ell(g)
    ids = np.concatenate([b.ids[: b.rows_real] for b in ell.buckets])
    assert sorted(ids.tolist()) == sorted(np.where(g.deg > 0)[0].tolist())
    for b in ell.buckets:
        real = b.nbrs[: b.rows_real] != g.n
        assert (real.sum(1) == g.deg[b.ids[: b.rows_real]]).all()


def test_pad_graph():
    g = gen.erdos_renyi(100, 300, seed=0)
    pg = pad_graph_for_shards(g, 16)
    assert pg.n_pad % 16 == 0 and pg.num_arcs_pad % 16 == 0
    assert pg.arc_mask.sum() == g.num_arcs


def test_sampler_shapes_and_validity():
    g = gen.barabasi_albert(500, 4, seed=0)
    sub = sample_subgraph(g, np.arange(32), (5, 3), seed=0)
    assert sub.layer_nodes[0].shape == (32,)
    assert sub.layer_nodes[1].shape == (160,)
    assert sub.layer_nodes[2].shape == (480,)
    for h, blk in enumerate(sub.blocks):
        # sampled neighbors are real neighbors
        src_nodes = sub.layer_nodes[h + 1][blk.src_index[blk.mask]]
        dst_nodes = sub.layer_nodes[h][blk.dst_index[blk.mask]]
        for s, d in list(zip(src_nodes, dst_nodes))[:50]:
            assert s in g.neighbors(d)


# --------------------------- optimizer ------------------------------- #

def test_adamw_decreases_quadratic():
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_grad_clip():
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    _, _, m = adamw_update(params, {"w": jnp.full(3, 1e6)}, state,
                           AdamWConfig())
    assert float(m["grad_norm"]) > 1e5   # norm measured pre-clip


def test_compression_error_feedback():
    from repro.optim import int8_compress_decompress, \
        topk_compress_decompress
    g = jnp.asarray(np.random.default_rng(0).normal(size=256).astype(
        np.float32))
    kept, err = topk_compress_decompress(g, 0.1)
    assert float(jnp.abs(kept).max()) == float(jnp.abs(g).max())
    np.testing.assert_allclose(np.asarray(kept + err), np.asarray(g),
                               rtol=1e-6)
    deq, err2 = int8_compress_decompress(g)
    assert float(jnp.abs(deq - g).max()) < float(jnp.abs(g).max()) / 100


# ------------------------ checkpoint / driver ------------------------ #

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    state = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3))},
             "count": jnp.int32(7)}
    save_checkpoint(tmp_path, 3, state)
    restored, step = restore_checkpoint(tmp_path, state)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert int(restored["count"]) == 7


def test_checkpoint_atomicity(tmp_path):
    from repro.checkpoint import latest_step, save_checkpoint
    save_checkpoint(tmp_path, 1, {"x": jnp.zeros(2)})
    # a stale .tmp dir from a crash must be ignored
    (tmp_path / "step_000000099.tmp").mkdir()
    assert latest_step(tmp_path) == 1


def test_driver_failure_restart_bitexact(tmp_path):
    """Train 30 steps; crash at 17; restart; final params equal an
    uninterrupted run (deterministic data + checkpointing)."""
    from repro.runtime import TrainDriver, TrainDriverConfig
    from repro.runtime.driver import HostFailure, make_failure_injector

    def make(fail_at=None, ckdir=None):
        params = jnp.float32(1.0)

        def step_fn(state, batch):
            return state * 0.9 + batch, {"loss": state}

        def batch_fn(i):
            return jnp.float32(i % 5) * 0.01

        cfg = TrainDriverConfig(total_steps=30, checkpoint_every=5,
                                checkpoint_dir=str(ckdir), log_every=100)
        inj = make_failure_injector(fail_at) if fail_at else None
        return TrainDriver(step_fn, params, batch_fn, cfg,
                           failure_injector=inj)

    ref_dir = tmp_path / "ref"
    ref = make(ckdir=ref_dir)
    ref.run()

    f_dir = tmp_path / "fail"
    d1 = make(fail_at=17, ckdir=f_dir)
    with pytest.raises(HostFailure):
        d1.run()
    d2 = make(ckdir=f_dir)      # relaunch: restores from step 15
    d2.run()
    assert float(d2.state) == pytest.approx(float(ref.state), rel=1e-6)


def test_data_determinism():
    from repro.data import synth_lm_batch
    a1 = synth_lm_batch(1000, 4, 32, seed=1, step=7)
    a2 = synth_lm_batch(1000, 4, 32, seed=1, step=7)
    b = synth_lm_batch(1000, 4, 32, seed=1, step=8)
    np.testing.assert_array_equal(a1[0], a2[0])
    assert not np.array_equal(a1[0], b[0])


# --------------------- termination / cost model ---------------------- #

def test_termination_models():
    from repro.core import kcore_decompose
    from repro.core.termination import (HeartbeatModel, bsp_termination_cost,
                                        dijkstra_scholten_estimate)
    res = kcore_decompose(gen.barabasi_albert(200, 3, seed=0))
    hb = HeartbeatModel().overhead(res.stats, round_time_s=1.0)
    bsp = bsp_termination_cost(res.stats, n_devices=256)
    ds = dijkstra_scholten_estimate(res.stats)
    assert hb["total_heartbeats"] > 0
    assert bsp["allreduces"] == res.rounds
    assert ds["signal_messages"] == res.stats.total_messages


def test_cost_model_regimes():
    from repro.core import kcore_decompose
    from repro.core.cost_model import DATACENTER, INTERNET, simulate_runtime
    res = kcore_decompose(gen.barabasi_albert(200, 3, seed=0))
    t_net = simulate_runtime(res.stats, INTERNET)
    t_dc = simulate_runtime(res.stats, DATACENTER)
    assert t_net["total_s"] > t_dc["total_s"]
