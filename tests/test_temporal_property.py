"""Hypothesis property tests for the sliding-window semantics (ISSUE 3):
advancing a window by k steps must yield exactly the same core numbers and
graph as applying the equivalent explicit EdgeBatch to a
StreamingKCoreEngine directly — over random event logs where duplicate
add/remove of the same edge within a window and re-insertion after expiry
are the common case."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see "
                    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import bz_core_numbers
from repro.temporal import EventLog, WindowedKCoreEngine
# tests/ is not a package; pytest puts it on sys.path (prepend import mode)
from test_temporal import check_window_advance_equals_explicit_batch


@st.composite
def random_logs(draw):
    """Small vertex pool + many events => duplicate add/remove of the same
    edge within a window and re-insertion after expiry are the common case,
    not the corner case. Zero inter-arrival gaps produce equal timestamps
    (same-instant events must still apply in log order)."""
    n = draw(st.integers(3, 10))
    n_events = draw(st.integers(1, 50))
    u = draw(st.lists(st.integers(0, n - 1), min_size=n_events,
                      max_size=n_events))
    v = draw(st.lists(st.integers(0, n - 1), min_size=n_events,
                      max_size=n_events))
    kind = draw(st.lists(st.sampled_from([1, -1]), min_size=n_events,
                         max_size=n_events))
    dts = draw(st.lists(st.integers(0, 3), min_size=n_events,
                        max_size=n_events))
    time = np.cumsum(np.asarray(dts, np.float64))
    return EventLog.make(time, u, v, kind, n=n)


@settings(max_examples=30, deadline=None)
@given(random_logs(), st.integers(1, 12), st.integers(1, 6),
       st.integers(0, 3), st.integers(1, 4))
def test_window_advance_equals_explicit_batch(log, window, stride, j, k):
    check_window_advance_equals_explicit_batch(log, window, stride, j, k)


@settings(max_examples=15, deadline=None)
@given(random_logs(), st.floats(0.5, 8.0), st.floats(0.25, 4.0))
def test_time_window_matches_bz(log, window, stride):
    """Time-based windows: exact BZ cores at every boundary, and the
    engine's edge set always equals edges_between of the index bounds."""
    weng = WindowedKCoreEngine(log, window, stride, by="time")
    steps = 0
    while not weng.done and steps < 12:
        ws = weng.advance()
        lo, hi = weng.bounds
        assert (ws.lo, ws.hi) == (lo, hi)
        assert (weng.window_edges == log.edges_between(lo, hi)).all()
        assert (ws.core == bz_core_numbers(weng.window_graph())).all()
        steps += 1
