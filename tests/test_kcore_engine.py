"""Correctness of the paper's core: distributed k-core vs the BZ oracle,
message accounting invariants, and the paper's own claims."""

import numpy as np
import pytest

from repro.core import (KCoreConfig, bz_core_numbers, kcore_decompose,
                        work_bound)
from repro.graph import generators as gen


def test_fig1_example():
    """The paper's Fig. 1 graph: cores (A,B,E,F)=3, (G,H)=2, (C,D)=1."""
    g, expect = gen.fig1_example()
    assert (bz_core_numbers(g) == expect).all()
    res = kcore_decompose(g)
    assert (res.core == expect).all()
    assert res.converged


@pytest.mark.parametrize("family,kw", [
    ("erdos_renyi", dict(n=300, m=1200)),
    ("barabasi_albert", dict(n=400, m_attach=3)),
    ("community", dict(n=300, n_blocks=5, deg_in=6, deg_out=1)),
    ("rmat", dict(scale=8, edge_factor=4)),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_engine_matches_bz(family, kw, seed):
    g = getattr(gen, family)(**kw, seed=seed)
    res = kcore_decompose(g)
    assert res.converged
    assert (res.core == bz_core_numbers(g)).all()


@pytest.mark.parametrize("mode,backend", [
    ("jacobi", "segment"), ("jacobi", "ell"), ("jacobi", "ell_pallas"),
    ("block_gs", "segment"),
])
def test_all_backends_agree(mode, backend):
    g = gen.barabasi_albert(300, 4, seed=3)
    res = kcore_decompose(g, KCoreConfig(mode=mode, backend=backend))
    assert (res.core == bz_core_numbers(g)).all(), (mode, backend)


def test_structured_graphs():
    assert (kcore_decompose(gen.complete(12)).core == 11).all()
    assert (kcore_decompose(gen.cycle(20)).core == 2).all()
    assert (kcore_decompose(gen.star(15)).core == 1).all()


def test_chain_depth():
    """Paper §II.B: the chain graph is the worst case — Θ(n) rounds (the
    estimate wave propagates one hop per round from each end)."""
    n = 120
    res = kcore_decompose(gen.chain(n))
    assert (res.core == 1).all()
    assert res.rounds >= n // 2 - 2          # depth ~ n/2 (two ends)


def test_social_graphs_converge_in_few_rounds():
    """Paper §II.B: 'normally, it takes only several rounds, such as 1 to
    10, to converge' on real (social-like) graphs — allow some slack for
    synthetic analogues."""
    g = gen.snap_analogue("FC", scale=0.3, seed=0)
    res = kcore_decompose(g)
    assert res.rounds <= 40, res.rounds


def test_message_accounting_invariants():
    g = gen.barabasi_albert(500, 4, seed=1)
    res = kcore_decompose(g)
    st = res.stats
    # round 0 = degree broadcast of every vertex = 2m messages
    assert st.messages_per_round[0] == 2 * g.m
    # messages only come from changed vertices: bounded by 2m each round
    assert (st.messages_per_round <= 2 * g.m).all()
    # total messages within the paper's work bound W
    assert st.total_messages <= work_bound(g, res.core)
    # active counts monotone-ish: first round everyone is active
    assert st.active_per_round[0] == g.n


def test_block_gs_never_worse():
    """Beyond-paper mode: Gauss-Seidel sweeps use fresher estimates, so
    total messages can only drop (monotone operator)."""
    g = gen.barabasi_albert(400, 4, seed=5)
    jac = kcore_decompose(g)
    gs = kcore_decompose(g, KCoreConfig(mode="block_gs", n_blocks=8))
    assert (gs.core == jac.core).all()
    assert gs.stats.total_messages <= jac.stats.total_messages
    assert gs.rounds <= jac.rounds


def test_empty_and_tiny():
    from repro.graph.structs import Graph
    g = Graph.from_edges(np.zeros((0, 2)), n=0)
    res = kcore_decompose(g)
    assert res.rounds == 0 and res.converged
    g1 = Graph.from_edges([(0, 1)], n=2)
    assert (kcore_decompose(g1).core == np.array([1, 1])).all()
