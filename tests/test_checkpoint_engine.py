"""Checkpoint <-> streaming engine round trip: the seed's repro/checkpoint
module persists engine state (cores + PatchableCSR slot arrays) and a
restored engine continues the churn stream exactly — groundwork for
warm restarts (ROADMAP item 4)."""

import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.bz import bz_core_numbers
from repro.graph import generators as gen
from repro.streaming import (StreamingConfig, StreamingKCoreEngine,
                             random_churn_batch)
from repro.streaming.delta import PatchableCSR


def test_csr_state_round_trip():
    """PatchableCSR.state_dict -> from_state is bit-identical storage."""
    g = gen.barabasi_albert(150, 3, seed=0)
    csr = PatchableCSR(g, slack=0.5, min_slack=2)
    restored = PatchableCSR.from_state(csr.state_dict(), slack=0.5,
                                       min_slack=2)
    assert restored.n == csr.n and restored.m == csr.m
    for f in ("row_off", "src", "dst", "live", "hole", "deg"):
        np.testing.assert_array_equal(getattr(restored, f), getattr(csr, f))
    assert restored.dead == csr.dead
    assert restored.compactions == csr.compactions
    g2 = restored.to_graph()
    np.testing.assert_array_equal(g2.src, csr.to_graph().src)


def test_engine_checkpoint_round_trip(tmp_path):
    """Checkpoint mid-stream, restore, and both engines must agree batch by
    batch — same cores (BZ-exact), same CSR slots, no re-decomposition."""
    rng = np.random.default_rng(1)
    g = gen.barabasi_albert(200, 3, seed=1)
    eng = StreamingKCoreEngine(g, StreamingConfig(frontier="fused"))
    for _ in range(3):
        eng.apply_batch(random_churn_batch(eng.graph, 8, 8, rng))

    save_checkpoint(tmp_path, eng.batches_applied, eng.state_dict())

    like = eng.state_dict()
    restored_state, step = restore_checkpoint(tmp_path, like)
    assert step == 3
    eng2 = StreamingKCoreEngine.from_state_dict(
        restored_state, StreamingConfig(frontier="fused"))
    assert eng2.init_result is None  # warm restart: no decomposition ran
    assert eng2.batches_applied == eng.batches_applied
    np.testing.assert_array_equal(eng2.core, eng.core)
    for f in ("row_off", "src", "dst", "live", "hole", "deg"):
        np.testing.assert_array_equal(getattr(eng2.csr, f),
                                      getattr(eng.csr, f))

    # the restored engine continues the stream in lockstep with the
    # original — identical cores AND identical message bills per batch
    rng_a, rng_b = (np.random.default_rng(7), np.random.default_rng(7))
    for _ in range(3):
        ba = random_churn_batch(eng.graph, 6, 6, rng_a)
        bb = random_churn_batch(eng2.graph, 6, 6, rng_b)
        ra = eng.apply_batch(ba)
        rb = eng2.apply_batch(bb)
        np.testing.assert_array_equal(eng.core, eng2.core)
        np.testing.assert_array_equal(ra.stats.messages_per_round,
                                      rb.stats.messages_per_round)
        np.testing.assert_array_equal(eng2.core,
                                      bz_core_numbers(eng2.graph))


def test_restore_across_frontier_modes(tmp_path):
    """A checkpoint is mode-agnostic: state captured under one frontier
    restores under another (all modes are exact-equal)."""
    g = gen.erdos_renyi(n=150, m=600, seed=2)
    eng = StreamingKCoreEngine(g, StreamingConfig(frontier="dense"))
    rng = np.random.default_rng(3)
    eng.apply_batch(random_churn_batch(eng.graph, 5, 5, rng))
    save_checkpoint(tmp_path, eng.batches_applied, eng.state_dict())
    state, _ = restore_checkpoint(tmp_path, eng.state_dict())
    eng2 = StreamingKCoreEngine.from_state_dict(
        state, StreamingConfig(frontier="compact"))
    np.testing.assert_array_equal(eng2.core, eng.core)
    eng2.apply_batch(random_churn_batch(eng2.graph, 5, 5,
                                        np.random.default_rng(4)))
    np.testing.assert_array_equal(eng2.core, bz_core_numbers(eng2.graph))
