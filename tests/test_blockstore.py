"""Spill-to-disk block store: layout parity with shard_arc_arrays, mmap
round-trips, LRU budget semantics, and block-count planning."""

import numpy as np
import pytest

from repro.graph import generators as gen
from repro.graph.blockstore import (ARC_SLOT_BYTES, BlockCache, BlockStore,
                                    plan_blocks)
from repro.graph.partition import shard_arc_arrays, shard_layout


@pytest.mark.parametrize("n_blocks", [1, 2, 4, 8])
def test_block_rows_match_shard_arc_arrays(tmp_path, n_blocks):
    """A materialized block must be bit-identical to the shard the mesh
    engines would have staged — same local src, global dst, mask, padding
    sentinels included."""
    g = gen.barabasi_albert(123, 3, seed=5)
    sg = shard_arc_arrays(g.n, g.src, g.dst, np.ones(g.num_arcs, bool),
                          g.deg, n_blocks)
    store = BlockStore.create(tmp_path / "s", g, n_blocks=n_blocks)
    assert store.V == sg.verts_per_shard
    assert store.A == sg.arcs_per_shard
    for b in range(n_blocks):
        blk = store.block(b)
        np.testing.assert_array_equal(blk.src, sg.src[b])
        np.testing.assert_array_equal(blk.dst, sg.dst[b])
        np.testing.assert_array_equal(blk.mask, sg.arc_mask[b])


def test_open_round_trip(tmp_path):
    g = gen.erdos_renyi(n=200, m=800, seed=1)
    created = BlockStore.create(tmp_path / "s", g, n_blocks=4)
    reopened = BlockStore.open(tmp_path / "s")
    assert (reopened.n, reopened.V, reopened.A) == \
        (created.n, created.V, created.A)
    for b in range(4):
        np.testing.assert_array_equal(reopened.block(b).dst,
                                      created.block(b).dst)
    # raw access is real-length (unpadded) and mmap-backed
    raw_src, _, raw_mask = reopened.block_raw(0)
    assert raw_src.shape[0] == reopened.arcs_per_block[0]
    assert isinstance(raw_src, np.memmap)
    assert raw_mask.dtype == bool


def test_create_overwrite_guard(tmp_path):
    g = gen.star(10)
    BlockStore.create(tmp_path / "s", g, n_blocks=2)
    with pytest.raises(FileExistsError):
        BlockStore.create(tmp_path / "s", g, n_blocks=2)
    BlockStore.create(tmp_path / "s", g, n_blocks=2, overwrite=True)


def test_open_rejects_unknown_version(tmp_path):
    g = gen.star(10)
    store = BlockStore.create(tmp_path / "s", g, n_blocks=2)
    manifest = (store.path / "manifest.json")
    manifest.write_text(manifest.read_text().replace('"version": 1',
                                                     '"version": 99'))
    with pytest.raises(ValueError, match="version"):
        BlockStore.open(tmp_path / "s")


def test_byte_accounting(tmp_path):
    g = gen.barabasi_albert(100, 2, seed=0)
    store = BlockStore.create(tmp_path / "s", g, n_blocks=4)
    assert store.total_arc_bytes == g.num_arcs * ARC_SLOT_BYTES
    assert store.block_arc_bytes == store.A * ARC_SLOT_BYTES
    blk = store.block(0)
    assert blk.nbytes == store.block_arc_bytes
    assert int(store.live_per_block.sum()) == g.num_arcs


def test_balance_matches_partition_report(tmp_path):
    from repro.graph.partition import balance_report, shard_graph
    g = gen.barabasi_albert(150, 3, seed=2)
    store = BlockStore.create(tmp_path / "s", g, n_blocks=4)
    assert store.balance() == balance_report(shard_graph(g, 4))


def test_lru_eviction_and_budget(tmp_path):
    g = gen.barabasi_albert(200, 3, seed=3)
    store = BlockStore.create(tmp_path / "s", g, n_blocks=8)
    # budget for exactly two resident blocks
    cache = BlockCache(store, budget_bytes=2 * store.block_arc_bytes)
    assert not cache.over_budget
    for b in range(8):
        cache.get(b)
    assert cache.loads == 8
    assert cache.evictions == 6
    assert cache.bytes <= cache.budget_bytes
    assert cache.peak_bytes <= cache.budget_bytes + store.block_arc_bytes
    # blocks 6, 7 are resident → hits; block 0 was evicted → reload
    cache.get(7)
    cache.get(6)
    assert cache.hits == 2
    cache.get(0)
    assert cache.loads == 9
    s = cache.stats()
    assert s["resident_blocks"] == 2
    assert s["evictions"] == 7


def test_lru_recency_order(tmp_path):
    g = gen.barabasi_albert(200, 3, seed=4)
    store = BlockStore.create(tmp_path / "s", g, n_blocks=4)
    cache = BlockCache(store, budget_bytes=2 * store.block_arc_bytes)
    cache.get(0)
    cache.get(1)
    cache.get(0)          # touch 0 → 1 is now LRU
    cache.get(2)          # evicts 1, not 0
    assert cache.hits == 1
    cache.get(0)
    assert cache.hits == 2


def test_cache_retains_block_over_impossible_budget(tmp_path):
    g = gen.barabasi_albert(100, 3, seed=5)
    store = BlockStore.create(tmp_path / "s", g, n_blocks=2)
    cache = BlockCache(store, budget_bytes=1)  # less than one block
    assert cache.over_budget
    blk = cache.get(0)  # still served: can't compute on less than a block
    assert blk.bid == 0
    assert cache.stats()["resident_blocks"] == 1


def test_unbounded_cache_never_evicts(tmp_path):
    g = gen.barabasi_albert(100, 3, seed=6)
    store = BlockStore.create(tmp_path / "s", g, n_blocks=8)
    cache = BlockCache(store, budget_bytes=None)
    for b in range(8):
        cache.get(b)
    assert cache.evictions == 0
    assert cache.stats()["resident_blocks"] == 8


def test_plan_blocks_fits_budget():
    g = gen.barabasi_albert(2000, 4, seed=7)
    budget = 64 * 1024
    nb = plan_blocks(g.n, g.src, budget)
    _V, A, _ = shard_layout(g.n, g.src, nb)
    assert 2 * A * ARC_SLOT_BYTES <= budget
    # generous budget → one block suffices
    assert plan_blocks(g.n, g.src, 10**9) == 1
    assert plan_blocks(g.n, g.src, None) == 8


def test_plan_blocks_caps_out():
    g = gen.star(50)
    # absurd budget: planner caps at max_blocks instead of looping forever
    nb = plan_blocks(g.n, g.src, 1, max_blocks=64)
    assert nb <= 64


def test_create_from_raw_arrays_with_dead_slots(tmp_path):
    """Masked (dead) arcs persist through the store — the streaming CSR's
    slack slots must not resurrect."""
    src = np.array([0, 0, 1, 2, 2, 3], np.int32)
    dst = np.array([1, 2, 0, 0, 3, 2], np.int32)
    mask = np.array([True, True, True, True, False, False])
    store = BlockStore.create(tmp_path / "s", n=4, src=src, dst=dst,
                              arc_mask=mask, n_blocks=2)
    got = np.concatenate([store.block(b).mask[store.block(b).src >= 0]
                          for b in range(2)])
    assert int(store.live_per_block.sum()) == 4
    assert got.sum() == 4
