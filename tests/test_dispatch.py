"""Kernel-dispatch layer (repro.core.dispatch): plan resolution, program
caching, the no-Pallas fallback, and — the load-bearing claim — BIT-equal
cores and per-round message bills between the Pallas-dispatched and the
XLA-segment-op supersteps across host-loop, fused, and streaming modes."""

import subprocess
import sys

import numpy as np
import pytest

from repro import platform
from repro.core import bz_core_numbers, dispatch as dmod
from repro.core.kcore import KCoreConfig, kcore_decompose
from repro.graph import generators as gen

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}

# everything but the fallback test needs a Pallas-capable jax build
requires_pallas = pytest.mark.skipif(
    not dmod.pallas_supported(),
    reason="jax build without Pallas (fallback covered separately)")


# --------------------------- plan resolution --------------------------- #

@requires_pallas
def test_resolve_plan_explicit_modes():
    assert dmod.resolve_plan("xla").kind == "xla"
    assert dmod.resolve_plan("pallas").kind == "pallas"
    assert dmod.resolve_plan("on").kind == "pallas"
    assert dmod.resolve_plan("off").kind == "xla"


@requires_pallas
def test_resolve_plan_auto_is_xla_off_tpu():
    """auto picks Pallas only where the kernels compile natively; in the
    CPU test environment it must stay on the XLA segment ops."""
    import jax

    plan = dmod.resolve_plan("auto")
    if jax.default_backend() == "tpu":
        assert plan.kind == "pallas" and not plan.interpret
    else:
        assert plan.kind == "xla" and plan.interpret


@requires_pallas
def test_resolve_plan_env_and_override(monkeypatch):
    monkeypatch.setenv(platform.ENV_DISPATCH, "on")
    platform.set_dispatch_mode(None)
    assert dmod.resolve_plan().kind == "pallas"
    platform.set_dispatch_mode("off")
    try:
        assert dmod.resolve_plan().kind == "xla"
    finally:
        platform.set_dispatch_mode(None)


# --------------------------- program caching --------------------------- #

@requires_pallas
def test_program_cache_hits_on_same_arcs():
    g = gen.barabasi_albert(120, 3, seed=0)
    plan = dmod.resolve_plan("pallas")
    from repro.core.kcore import _bs_iters

    it = _bs_iters(g.max_deg)
    p1 = dmod.masked_round_program(g.n, it, plan, g.src, g.dst)
    p2 = dmod.masked_round_program(g.n, it, plan, g.src, g.dst)
    assert p1 is p2
    g2 = gen.barabasi_albert(120, 3, seed=1)
    p3 = dmod.masked_round_program(g2.n, _bs_iters(g2.max_deg), plan,
                                   g2.src, g2.dst)
    assert p3 is not p1


# ------------------------ bit-equality parity -------------------------- #

_FAMILIES = [
    ("ba", lambda: gen.barabasi_albert(300, 3, seed=1)),
    ("er", lambda: gen.erdos_renyi(250, 700, seed=3)),
    ("star+isolated", lambda: gen.star(40)),
]


def _assert_bit_equal(rx, rp):
    assert rx.dispatch == "xla" and rp.dispatch == "pallas"
    assert np.array_equal(rx.core, rp.core)
    assert rx.rounds == rp.rounds and rx.converged == rp.converged
    for f in ("messages_per_round", "active_per_round", "changed_per_round"):
        np.testing.assert_array_equal(getattr(rx.stats, f),
                                      getattr(rp.stats, f))


@requires_pallas
@pytest.mark.parametrize("name,make", _FAMILIES, ids=[f[0] for f in _FAMILIES])
@pytest.mark.parametrize("fused", [False, True], ids=["host-loop", "fused"])
def test_decompose_parity_pallas_vs_xla(name, make, fused):
    """kcore_decompose: forced Pallas dispatch (ELL h-index + blocked
    segment sum, interpret mode on CPU) is bit-equal to the XLA path and
    the BZ oracle, in both the host round loop and the fused while_loop."""
    g = make()
    rx = kcore_decompose(g, KCoreConfig(fused=fused, dispatch="xla"))
    rp = kcore_decompose(g, KCoreConfig(fused=fused, dispatch="pallas"))
    _assert_bit_equal(rx, rp)
    assert np.array_equal(rp.core, bz_core_numbers(g))


@requires_pallas
def test_streaming_parity_pallas_vs_xla():
    """Streaming engine (dense per-round AND fused batch re-convergence):
    REPRO_PALLAS routing gives the identical bill per churn batch."""
    from repro.streaming import (StreamingConfig, StreamingKCoreEngine,
                                 random_churn_batch)

    def run(mode, frontier):
        platform.set_dispatch_mode(mode)
        try:
            g = gen.barabasi_albert(200, 3, seed=2)
            eng = StreamingKCoreEngine(g, StreamingConfig(frontier=frontier))
            rng = np.random.default_rng(7)
            out = []
            for _ in range(3):
                res = eng.apply_batch(random_churn_batch(eng.graph, 10, 10,
                                                         rng))
                out.append((res.stats.messages_per_round.tolist(),
                            res.stats.active_per_round.tolist(),
                            eng.core.tolist()))
            assert np.array_equal(eng.core, bz_core_numbers(eng.graph))
            return out
        finally:
            platform.set_dispatch_mode(None)

    for frontier in ("dense", "fused"):
        assert run("xla", frontier) == run("pallas", frontier), frontier


@requires_pallas
def test_fused_outcome_records_dispatch():
    g = gen.barabasi_albert(150, 3, seed=4)
    from repro.core.runtime import fused_converge_dense

    out = fused_converge_dense(
        g.deg, np.ones(g.n, bool), g.src, g.dst,
        np.ones(g.num_arcs, bool), g.deg,
        n=g.n, n_iters=8, max_rounds=g.n + 1, dispatch="pallas")
    assert out.dispatch == "pallas" and out.converged


# --------------------------- no-Pallas fallback ------------------------ #

def test_import_and_fallback_without_pallas_subprocess():
    """On a jax build without Pallas: ``import repro.core`` works (lazy
    kernels imports), forced Pallas dispatch warns and falls back to XLA,
    and the decomposition still converges to the oracle."""
    script = r"""
import sys
class _Block:
    def find_module(self, name, path=None):
        return self if name.startswith("jax.experimental.pallas") else None
    def load_module(self, name):
        raise ImportError("blocked: " + name)
sys.meta_path.insert(0, _Block())
import warnings
import numpy as np
import repro.core
from repro.core import bz_core_numbers, resolve_plan
from repro.core.kcore import KCoreConfig, kcore_decompose
from repro.graph.generators import barabasi_albert
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    assert resolve_plan("pallas").kind == "xla"
    assert any("falling back to XLA" in str(x.message) for x in w)
g = barabasi_albert(100, 3, seed=0)
r = kcore_decompose(g, KCoreConfig(fused=True, dispatch="pallas"))
assert r.dispatch == "xla" and r.converged
assert np.array_equal(r.core, bz_core_numbers(g))
print("OK")
"""
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=_ENV, cwd="/root/repo", timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert proc.stdout.strip().endswith("OK")
