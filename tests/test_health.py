"""Invariant monitor: anomaly detection over the flight stream.

The ISSUE 8 acceptance case lives here: an artificially injected
non-monotone estimate MUST be flagged. The rest covers progress and
mode-invariance checks, emission into the tracer/metrics registries, and
the clean verdict on a real convergence.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import kcore_decompose
from repro.graph import generators as gen
from repro.obs import flight, trace
from repro.obs.flight import FlightRecorder
from repro.obs.health import InvariantMonitor
from repro.obs.metrics import MetricsRegistry


@pytest.fixture()
def wired():
    """A private recorder with a private-registry monitor attached."""
    rec = FlightRecorder()
    reg = MetricsRegistry()
    mon = InvariantMonitor(registry=reg)
    rec.add_observer(mon)
    return rec, mon, reg


def test_injected_non_monotone_estimate_is_flagged(wired):
    rec, mon, reg = wired
    rec.start_run("static", "host")
    prev = np.asarray([5, 5, 5, 5])
    rec.record_round(4, 10, 2, est=np.asarray([4, 4, 5, 5]), prev_est=prev)
    assert mon.ok
    # inject the violation: vertex 2's estimate RISES 5 -> 7
    rec.record_round(4, 10, 1, est=np.asarray([4, 4, 7, 5]),
                     prev_est=np.asarray([4, 4, 5, 5]))
    assert not mon.ok
    v = mon.verdict()
    assert v["status"] == "anomalous"
    assert v["kinds"]["non_monotone_estimate"] >= 1
    assert v["last"]["kind"] == "non_monotone_estimate"
    # gauge flipped, per-kind counter incremented
    assert reg.gauge("obs_health_status").value == 0.0
    c = reg.counter("obs_health_anomalies_total",
                    kind="non_monotone_estimate")
    assert c.value >= 1


def test_est_sum_rise_without_vector_is_flagged(wired):
    rec, mon, _ = wired
    rec.start_run("static", "fused")
    rec.record_round(4, 10, 2, est=np.asarray([3, 3, 3, 3]))
    rec.record_round(3, 8, 1, est=np.asarray([3, 3, 3, 4]))  # sum rose
    assert not mon.ok
    assert mon.kinds.get("non_monotone_estimate", 0) >= 1


def test_messages_without_change_is_flagged(wired):
    rec, mon, _ = wired
    rec.start_run("static", "host")
    rec.record_round(10, 100, 0)          # round 0: exempt (broadcast)
    assert mon.ok
    rec.record_round(10, 100, 0)          # round 1: messages, no senders
    assert not mon.ok
    assert "messages_without_change" in mon.kinds


def test_changed_exceeds_frontier_is_flagged(wired):
    rec, mon, _ = wired
    rec.start_run("static", "host")
    rec.record_round(10, 100, 10)
    rec.record_round(frontier=3, messages=50, changed=7)
    assert "changed_exceeds_frontier" in mon.kinds


def test_frontier_stall_emits_once(wired):
    rec, mon, _ = wired
    mon.stall_rounds = 5
    rec.start_run("static", "host")
    rec.record_round(10, 10, 10)
    for _ in range(12):                   # frontier pinned: no new minimum
        rec.record_round(8, 8, 4)
    assert mon.kinds.get("frontier_stall") == 1


def test_unconverged_run_is_flagged(wired):
    rec, mon, _ = wired
    rec.start_run("static", "host")
    rec.record_round(10, 10, 10)
    rec.end_run(converged=False)
    assert "unconverged_run" in mon.kinds


def test_observe_bill_mode_invariance(wired):
    _, mon, _ = wired
    mon.observe_bill(("EEN", 0), "dense", 1234)
    mon.observe_bill(("EEN", 0), "sharded", 1234)
    assert mon.ok
    mon.observe_bill(("EEN", 1), "dense", 1000)
    mon.observe_bill(("EEN", 1), "fused", 999)
    assert not mon.ok
    assert mon.kinds["mode_bill_mismatch"] == 1
    assert mon.verdict()["last"]["other_total"] == 1000


def test_anomalies_land_in_the_tracer(wired):
    rec, mon, _ = wired
    tracer = trace.get_tracer()
    tracer.reset()
    tracer.enable()
    try:
        rec.start_run("static", "host")
        rec.record_round(10, 10, 10)
        rec.record_round(3, 50, 7)        # changed > frontier
        names = [e["name"] for e in tracer.events()]
        assert "health.anomaly" in names
        ev = next(e for e in tracer.events()
                  if e["name"] == "health.anomaly")
        assert ev["args"]["kind"] == "changed_exceeds_frontier"
    finally:
        tracer.disable()
        tracer.reset()


def test_real_decomposition_is_healthy():
    flight.enable()
    flight.reset()
    rec = flight.get_recorder()
    reg = MetricsRegistry()
    mon = InvariantMonitor(registry=reg)
    rec.add_observer(mon)
    try:
        g = gen.barabasi_albert(300, 3, seed=4)
        kcore_decompose(g)                 # host loop
        kcore_decompose(g, fused=True)     # fused reconstruction
        assert mon.ok
        v = mon.verdict()
        assert v["status"] == "ok" and v["runs_seen"] == 2
        assert reg.gauge("obs_health_status").value == 1.0
    finally:
        rec.remove_observer(mon)
        flight.disable()
        flight.reset()


def test_monitor_reset_restores_ok(wired):
    rec, mon, reg = wired
    rec.start_run("static", "host")
    rec.record_round(10, 10, 10)
    rec.record_round(3, 50, 7)
    assert not mon.ok
    mon.reset()
    assert mon.ok and mon.verdict()["status"] == "ok"
    assert reg.gauge("obs_health_status").value == 1.0
