"""The shared padding policy (graph/padding): one round_up, one next_pow2,
no private copies — graph/structs, graph/partition, and streaming/engine
must all resolve to these."""

import pytest

from repro.graph.padding import next_pow2, round_up


@pytest.mark.parametrize("x,mult,want", [
    (0, 4, 0), (1, 4, 4), (4, 4, 4), (5, 4, 8),
    (17, 8, 24), (7, 1, 7), (9, 0, 9), (3, -2, 3),
])
def test_round_up(x, mult, want):
    assert round_up(x, mult) == want


@pytest.mark.parametrize("x,want", [
    (0, 1), (1, 1), (2, 2), (3, 4), (4, 4), (5, 8),
    (1023, 1024), (1024, 1024), (1025, 2048),
])
def test_next_pow2(x, want):
    assert next_pow2(x) == want


def test_next_pow2_is_monotone_cover():
    prev = 0
    for x in range(1, 300):
        p = next_pow2(x)
        assert p >= x and p >= prev         # covering and monotone
        assert p & (p - 1) == 0             # a power of two
        prev = p


def test_consumers_share_one_copy():
    """The deduped helpers: every former private copy must BE the shared
    function, not a drifted clone."""
    from repro.graph import partition, structs
    from repro.streaming import engine

    assert partition._next_pow2 is next_pow2
    assert partition._round_up is round_up
    assert structs._round_up is round_up
    assert engine._next_pow2 is next_pow2
