"""Flight recorder: cross-mode bit-equality, ring wraparound, watchlists.

The load-bearing property (ISSUE 8): host-loop, fused, and sharded
execution modes must produce IDENTICAL per-round (frontier, messages,
changed) flight series on the same graph — the recorder reads the
accounting arrays, and those are mode-invariant by the repo's bit-equality
contract. BZ-verified so the series being compared describe exact cores.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import KCoreConfig, bz_core_numbers, kcore_decompose, kcore_decompose_sharded
from repro.distribution.compat import make_mesh
from repro.graph import generators as gen
from repro.obs import flight
from repro.obs.flight import NULL_RECORDER, FlightRecorder, drop_histogram
from repro.streaming import StreamingConfig, StreamingKCoreEngine, random_churn_batch


@pytest.fixture()
def recorder():
    """Enable the process recorder for one test, clean up after."""
    flight.enable()
    flight.reset()
    yield flight.get_recorder()
    flight.disable()
    flight.reset()
    flight.get_recorder()._timelines.clear()
    flight.get_recorder()._watch = np.zeros(0, np.int64)


def _series():
    return [(r.round, r.frontier, r.messages, r.changed)
            for r in flight.records()]


# ---------------------------------------------------------------------- #
# NULL recorder / disabled path
# ---------------------------------------------------------------------- #

def test_disabled_recorder_is_shared_noop():
    flight.disable()
    rec = flight.recorder()
    assert rec is NULL_RECORDER
    assert rec.active is False
    # the full protocol is a no-op — nothing lands in the default ring
    rec.set_context(engine="x")
    assert rec.start_run("static", "host") == -1
    rec.record_round(1, 2, 3)
    rec.record_fused_rounds([1], [1], [1], frontier1=1)
    rec.end_run()
    assert flight.records() == []
    assert flight.get_recorder().rounds_recorded == 0


def test_null_recorder_has_no_per_instance_state():
    assert not hasattr(NULL_RECORDER, "__dict__")  # __slots__ = ()


def test_runs_decomposition_records_nothing_when_disabled():
    flight.disable()
    g = gen.barabasi_albert(100, 3, seed=0)
    kcore_decompose(g)
    kcore_decompose(g, fused=True)
    assert flight.records() == []


# ---------------------------------------------------------------------- #
# Ring buffer
# ---------------------------------------------------------------------- #

def test_ring_wraparound_keeps_recent_and_monotone_seq():
    rec = FlightRecorder(capacity=8)
    rec.start_run("static", "host")
    for i in range(20):
        rec.record_round(frontier=100 - i, messages=10 * i, changed=i)
    recs = rec.records()
    assert len(recs) == 8                       # bounded
    assert [r.seq for r in recs] == list(range(12, 20))   # survivors
    assert [r.round for r in recs] == list(range(12, 20))
    assert rec.rounds_recorded == 20
    assert rec.to_json()["dropped"] == 12
    assert rec.records(last=3) == recs[-3:]


def test_capacity_validation():
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


# ---------------------------------------------------------------------- #
# Cross-mode bit-equality (the tentpole property)
# ---------------------------------------------------------------------- #

def test_static_modes_produce_identical_flight_series(recorder):
    g = gen.barabasi_albert(300, 3, seed=1)
    ref = bz_core_numbers(g)

    res = kcore_decompose(g, KCoreConfig())
    assert (res.core == ref).all()
    host = _series()
    flight.reset()

    res = kcore_decompose(g, KCoreConfig(), fused=True)
    assert (res.core == ref).all()
    fused = _series()
    flight.reset()

    mesh = make_mesh((1,), ("data",))
    res = kcore_decompose_sharded(g, mesh, ("data",))
    assert (res.core == ref).all()
    sharded = _series()
    flight.reset()

    res = kcore_decompose_sharded(g, mesh, ("data",), fused=True)
    assert (res.core == ref).all()
    fused_sharded = _series()

    assert len(host) > 2
    assert host == fused == sharded == fused_sharded


def test_flight_series_matches_accounting_arrays(recorder):
    g = gen.erdos_renyi(200, 600, seed=3)
    res = kcore_decompose(g)
    recs = flight.records()
    stats = res.stats
    # one record per accounting round, same arrays
    assert [r.messages for r in recs] == stats.messages_per_round.tolist()
    assert [r.changed for r in recs] == stats.changed_per_round.tolist()
    assert [r.frontier for r in recs] == stats.active_per_round.tolist()
    assert [r.round for r in recs] == list(range(len(recs)))
    # host loop attaches exact per-round drop histograms past round 0
    for r in recs[1:]:
        assert r.drop_hist is not None
        assert sum(r.drop_hist) == r.changed
        assert r.est_rises == 0


def test_streaming_modes_produce_identical_flight_series(recorder):
    g = gen.barabasi_albert(400, 3, seed=2)

    def run(frontier):
        flight.reset()
        eng = StreamingKCoreEngine(g, StreamingConfig(frontier=frontier))
        rng = np.random.default_rng(7)
        for _ in range(3):
            eng.apply_batch(random_churn_batch(eng.graph, 10, 10, rng))
        assert (eng.core == bz_core_numbers(eng.graph)).all()
        return [(r.engine, r.run, r.batch, r.round, r.frontier, r.messages,
                 r.changed) for r in flight.records()]

    dense = run("dense")
    fused = run("fused")
    assert len(dense) >= 3                     # at least round 0 per batch
    assert dense == fused
    # run 0 is the engine's bootstrap decomposition; every batch after it
    # opened its own streaming run with the batch id attached
    pairs = sorted({(r[1], r[2]) for r in dense if r[0] == "streaming"})
    assert pairs == [(1, 0), (2, 1), (3, 2)]


# ---------------------------------------------------------------------- #
# Watchlist / per-vertex trajectories
# ---------------------------------------------------------------------- #

def test_watchlist_captures_monotone_trajectories(recorder):
    g = gen.barabasi_albert(200, 3, seed=5)
    flight.watch([0, 7, 150])
    kcore_decompose(g)           # host loop: every round has host est
    tl = flight.get_recorder().timelines()
    assert set(tl) == {0, 7, 150}
    for v, entries in tl.items():
        assert len(entries) >= 2
        ests = [e["est"] for e in entries]
        # round 0 samples the degree seed; the series never rises
        assert ests[0] == int(g.deg[v])
        assert all(a >= b for a, b in zip(ests, ests[1:]))
        assert [e["round"] for e in entries] == list(range(len(entries)))
    # the timeline replays as a message timeline: changed flags mark sends
    ch = [e["changed"] for e in tl[0]]
    assert ch[0] is False


def test_trajectory_accessor_and_out_of_range_ids(recorder):
    rec = flight.get_recorder()
    flight.watch([2, 999])
    rec.start_run("static", "host")
    rec.record_round(3, 3, 3, est=np.asarray([5, 5, 5]))
    assert len(rec.trajectory(2)) == 1         # id 999 out of range: skipped
    assert rec.trajectory(999) == []
    assert rec.trajectory(123) == []


# ---------------------------------------------------------------------- #
# Histogram helper / fused reconstruction details
# ---------------------------------------------------------------------- #

def test_drop_histogram_buckets():
    prev = np.asarray([10, 10, 10, 10, 10, 10, 3])
    est = np.asarray([9, 8, 7, 4, 1, 10, 3])   # drops: 1, 2, 3, 6, 9
    assert drop_histogram(prev, est) == (1, 1, 1, 1, 1)
    assert drop_histogram(est, est) == (0, 0, 0, 0, 0)


def test_fused_records_carry_amortized_device_wall_and_seed_drop(recorder):
    g = gen.barabasi_albert(300, 3, seed=1)
    res = kcore_decompose(g, fused=True)
    recs = flight.records()
    assert len(recs) == len(res.stats.messages_per_round)
    # device wall amortized uniformly over rounds 1..k
    devs = [r.device_s for r in recs[1:]]
    assert all(d == pytest.approx(devs[0]) for d in devs)
    # the aggregate seed-vs-final drop histogram rides the LAST round
    last = recs[-1]
    assert last.drop_hist is not None
    dropped = int((res.core < g.deg).sum())
    assert sum(last.drop_hist) == dropped
    assert all(r.drop_hist is None for r in recs[1:-1])


def test_set_context_labels_next_run(recorder):
    rec = flight.get_recorder()
    rec.set_context(engine="temporal", step=4)
    rec.start_run("streaming", "fused", batch=0)
    rec.record_round(1, 1, 1)
    r = flight.records()[0]
    assert r.engine == "temporal" and r.batch == 4
    # context was consumed: the next run reverts to the caller's labels
    rec.end_run()
    rec.start_run("streaming", "fused", batch=1)
    rec.record_round(1, 1, 1)
    assert flight.records()[1].engine == "streaming"
    assert flight.records()[1].batch == 1


def test_dump_and_to_json_roundtrip(tmp_path, recorder):
    g = gen.chain(50)
    kcore_decompose(g)
    path = str(tmp_path / "flight.json")
    flight.dump(path)
    import json
    with open(path) as f:
        payload = json.load(f)
    assert payload["runs"] == 1
    assert payload["rounds_recorded"] == len(payload["records"])
    assert payload["records"][0]["engine"] == "static"
    assert {"seq", "run", "round", "frontier", "messages",
            "changed"} <= set(payload["records"][0])
