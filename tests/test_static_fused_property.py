"""Hypothesis property test (ISSUE 5 acceptance): on random graphs —
duplicate edges, self-loops, isolated vertices included — the static fused
runtime produces cores AND per-round MessageStats bit-equal to the host
round loop, and both equal the BZ oracle; every few examples also through
the sharded fused variant."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see "
                    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import bz_core_numbers, kcore_decompose, \
    kcore_decompose_sharded
from repro.distribution.compat import make_mesh
from repro.graph.structs import Graph
# tests/ is not a package; pytest puts it on sys.path (prepend import mode)
from test_static_fused import assert_result_equal


@st.composite
def random_graph(case):
    n = case(st.integers(2, 14))
    edges = case(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        max_size=40))
    return n, edges


@settings(max_examples=25, deadline=None)
@given(random_graph(), st.booleans())
def test_static_fused_exact_property(case, sharded):
    n, edges = case
    g = Graph.from_edges(np.asarray(edges, np.int64).reshape(-1, 2), n=n)
    ref = kcore_decompose(g)
    fus = kcore_decompose(g, fused=True)
    assert_result_equal(ref, fus)
    assert (fus.core == bz_core_numbers(g)).all()
    if sharded:
        mesh = make_mesh((1,), ("data",))
        fsh = kcore_decompose_sharded(g, mesh, ("data",), fused=True)
        assert_result_equal(ref, fsh)
