"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; same entry points target real TPUs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see "
                    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.embedding_bag.ops import embedding_bag_fused
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kcore_hindex.ops import hindex_rows
from repro.kernels.kcore_hindex.ref import hindex_rows_ref
from repro.kernels.segment_sum.ops import blocked_layout, segment_sum_blocked
from repro.kernels.segment_sum.ref import segment_sum_ref


# ------------------------- kcore_hindex ------------------------------ #

@pytest.mark.parametrize("rows,width", [(8, 8), (64, 32), (130, 17), (5, 600)])
def test_hindex_shapes(rows, width, rng):
    nbr = rng.integers(0, 50, (rows, width)).astype(np.int32)
    est = rng.integers(0, 50, rows).astype(np.int32)
    out = hindex_rows(jnp.asarray(nbr), jnp.asarray(est), n_iters=7)
    ref = hindex_rows_ref(jnp.asarray(nbr), jnp.asarray(est))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(0, 1000))
def test_hindex_property(rows, width, seed):
    """Kernel (binary search) vs oracle (sort identity) — independent
    algorithms must agree exactly."""
    r = np.random.default_rng(seed)
    nbr = r.integers(0, 64, (rows, width)).astype(np.int32)
    est = r.integers(0, 64, rows).astype(np.int32)
    out = hindex_rows(jnp.asarray(nbr), jnp.asarray(est), n_iters=8)
    ref = hindex_rows_ref(jnp.asarray(nbr), jnp.asarray(est))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ------------------------- flash attention --------------------------- #

@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D", [
    (2, 128, 128, 4, 2, 32),
    (1, 256, 256, 8, 1, 64),     # MQA
    (2, 64, 64, 4, 4, 16),       # MHA
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Sk, Hq, Hkv, D, causal, window, dtype):
    key = jax.random.key(42)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, Hq, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    ref = attention_ref(qf, kf, vf, causal=causal, window=window) \
        .reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                 ref.astype(jnp.float32)))) < tol


# ------------------------- segment sum -------------------------------- #

@pytest.mark.parametrize("E,n,F", [(1000, 300, 8), (4096, 64, 16),
                                   (37, 10, 4), (513, 513, 1)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_segment_sum_sweep(E, n, F, dtype, rng):
    seg = rng.integers(0, n, E)
    vals = rng.normal(size=(E, F)).astype(dtype)
    lo = blocked_layout(seg, n, R=32, be=64)
    out = segment_sum_blocked(jnp.asarray(vals), lo, n)
    ref = segment_sum_ref(jnp.asarray(vals), jnp.asarray(seg), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 500), st.integers(1, 100), st.integers(0, 100))
def test_segment_sum_property(E, n, seed):
    r = np.random.default_rng(seed)
    seg = r.integers(0, n, E)
    vals = r.normal(size=(E, 4)).astype(np.float32)
    lo = blocked_layout(seg, n, R=16, be=32)
    out = segment_sum_blocked(jnp.asarray(vals), lo, n)
    ref = segment_sum_ref(jnp.asarray(vals), jnp.asarray(seg), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


# ------------------------- embedding bag ------------------------------ #

@pytest.mark.parametrize("V,D,B,L", [(100, 8, 4, 5), (500, 24, 13, 7),
                                     (1000, 32, 32, 20)])
def test_embedding_bag_sweep(V, D, B, L, rng):
    table = jax.random.normal(jax.random.key(0), (V, D))
    idx = rng.integers(-1, V, (B, L)).astype(np.int32)
    out = embedding_bag_fused(table, jnp.asarray(idx))
    ref = embedding_bag_ref(table, jnp.asarray(idx))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
