"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; same entry points target real TPUs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see "
                    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.embedding_bag.ops import embedding_bag_fused
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kcore_hindex.ops import hindex_rows
from repro.kernels.kcore_hindex.ref import hindex_rows_ref
from repro.kernels.segment_sum.ops import blocked_layout, segment_sum_blocked
from repro.kernels.segment_sum.ref import segment_sum_ref


# ------------------------- kcore_hindex ------------------------------ #

@pytest.mark.parametrize("rows,width", [(8, 8), (64, 32), (130, 17), (5, 600)])
def test_hindex_shapes(rows, width, rng):
    nbr = rng.integers(0, 50, (rows, width)).astype(np.int32)
    est = rng.integers(0, 50, rows).astype(np.int32)
    out = hindex_rows(jnp.asarray(nbr), jnp.asarray(est), n_iters=7)
    ref = hindex_rows_ref(jnp.asarray(nbr), jnp.asarray(est))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(0, 1000))
def test_hindex_property(rows, width, seed):
    """Kernel (binary search) vs oracle (sort identity) — independent
    algorithms must agree exactly."""
    r = np.random.default_rng(seed)
    nbr = r.integers(0, 64, (rows, width)).astype(np.int32)
    est = r.integers(0, 64, rows).astype(np.int32)
    out = hindex_rows(jnp.asarray(nbr), jnp.asarray(est), n_iters=8)
    ref = hindex_rows_ref(jnp.asarray(nbr), jnp.asarray(est))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ----------------- ELL layout: Pallas vs ref vs segment ops ----------- #
#
# The dispatch layer (repro.core.dispatch) claims the Pallas ELL h-index
# route is bit-equal to the XLA segment-op binary search on any static
# fully-live adjacency whose degree-0 vertices carry estimate 0. These
# property tests check that claim on ragged degree-bucketed layouts —
# including empty (sentinel-padded) rows, empty buckets, and degrees
# landing exactly on the pow2 bucket-width boundary.

def _ell_round_all(g, est, n_iters, hindex_fn):
    """One full h-index round over every bucket of g's ELL layout."""
    from repro.graph.structs import build_ell

    ell = build_ell(g, widths=(2, 4, 8, 32))
    est_ext = np.concatenate([est, np.zeros(1, np.int32)]).astype(np.int32)
    new_ext = est_ext.copy()
    for b in ell.buckets:
        h = hindex_fn(jnp.asarray(est_ext[b.nbrs]),
                      jnp.asarray(est_ext[b.ids]), n_iters)
        new_ext[b.ids] = np.asarray(h, np.int32)
    return new_ext[: g.n]


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 48), st.integers(0, 120), st.integers(0, 1000))
def test_ell_hindex_pallas_vs_ref_vs_segment(n, e, seed):
    """Pallas ELL kernel == sort-identity oracle == XLA segment-op binary
    search, on random ragged graphs with arbitrary (deg-0-zeroed) ests."""
    from repro.core.kcore import _bs_iters, _hindex_by_bsearch
    from repro.graph.structs import Graph

    r = np.random.default_rng(seed)
    edges = r.integers(0, n, (e, 2))
    g = Graph.from_edges(edges, n=n)
    hi = max(g.max_deg, 1) * 2 + 1
    est = r.integers(0, hi, n).astype(np.int32)
    est[g.deg == 0] = 0          # the ELL-route exactness precondition
    n_iters = _bs_iters(hi)

    got_pallas = _ell_round_all(
        g, est, n_iters,
        lambda nbr, eu, it: hindex_rows(nbr, eu, n_iters=it))
    got_ref = _ell_round_all(
        g, est, n_iters, lambda nbr, eu, it: hindex_rows_ref(nbr, eu, it))
    est_j = jnp.asarray(est)
    seg = np.asarray(_hindex_by_bsearch(
        est_j, est_j[jnp.asarray(g.dst)], jnp.asarray(g.src), g.n, n_iters))
    np.testing.assert_array_equal(got_pallas, got_ref)
    np.testing.assert_array_equal(got_pallas, seg)


def test_ell_hindex_pow2_boundary_and_empty_rows():
    """Deterministic edge cases: a star whose hub degree sits exactly ON a
    pow2 bucket width (8), leaf count NOT a row_multiple multiple (so the
    leaf bucket carries sentinel-padded rows), plus isolated vertices."""
    from repro.core.kcore import _bs_iters, _hindex_by_bsearch
    from repro.graph.structs import Graph, build_ell

    # hub 0 -- leaves 1..8 (deg 8 == bucket width), 9..11 isolated
    edges = [(0, i) for i in range(1, 9)]
    g = Graph.from_edges(edges, n=12)
    ell = build_ell(g, widths=(2, 4, 8, 32))
    assert any(b.width == 8 and b.rows_real == 1 for b in ell.buckets)
    assert any(b.ids.shape[0] > b.rows_real for b in ell.buckets)

    est = g.deg.astype(np.int32)
    n_iters = _bs_iters(g.max_deg)
    got = _ell_round_all(
        g, est, n_iters,
        lambda nbr, eu, it: hindex_rows(nbr, eu, n_iters=it))
    est_j = jnp.asarray(est)
    seg = np.asarray(_hindex_by_bsearch(
        est_j, est_j[jnp.asarray(g.dst)], jnp.asarray(g.src), g.n, n_iters))
    np.testing.assert_array_equal(got, seg)
    assert (got[9:] == 0).all()          # isolated vertices stay 0


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 400), st.integers(1, 80), st.integers(0, 100))
def test_segment_sum_int32_bit_exact(E, n, seed):
    """int32 blocked segment sum is BIT-equal to jax.ops.segment_sum — the
    exactness the dispatched superstep's message accounting rests on."""
    r = np.random.default_rng(seed)
    seg = np.sort(r.integers(0, n, E))    # sorted-COO like arc sources
    vals = r.integers(0, 2**20, E).astype(np.int32)
    lo = blocked_layout(seg, n, R=16, be=32)
    out = np.asarray(segment_sum_blocked(jnp.asarray(vals), lo, n)[:, 0])
    ref = np.asarray(jax.ops.segment_sum(jnp.asarray(vals),
                                         jnp.asarray(seg), num_segments=n))
    np.testing.assert_array_equal(out, ref)


# ------------------------- flash attention --------------------------- #

@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D", [
    (2, 128, 128, 4, 2, 32),
    (1, 256, 256, 8, 1, 64),     # MQA
    (2, 64, 64, 4, 4, 16),       # MHA
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Sk, Hq, Hkv, D, causal, window, dtype):
    key = jax.random.key(42)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, Hq, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    ref = attention_ref(qf, kf, vf, causal=causal, window=window) \
        .reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                 ref.astype(jnp.float32)))) < tol


# ------------------------- segment sum -------------------------------- #

@pytest.mark.parametrize("E,n,F", [(1000, 300, 8), (4096, 64, 16),
                                   (37, 10, 4), (513, 513, 1)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_segment_sum_sweep(E, n, F, dtype, rng):
    seg = rng.integers(0, n, E)
    vals = rng.normal(size=(E, F)).astype(dtype)
    lo = blocked_layout(seg, n, R=32, be=64)
    out = segment_sum_blocked(jnp.asarray(vals), lo, n)
    ref = segment_sum_ref(jnp.asarray(vals), jnp.asarray(seg), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 500), st.integers(1, 100), st.integers(0, 100))
def test_segment_sum_property(E, n, seed):
    r = np.random.default_rng(seed)
    seg = r.integers(0, n, E)
    vals = r.normal(size=(E, 4)).astype(np.float32)
    lo = blocked_layout(seg, n, R=16, be=32)
    out = segment_sum_blocked(jnp.asarray(vals), lo, n)
    ref = segment_sum_ref(jnp.asarray(vals), jnp.asarray(seg), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


# ------------------------- embedding bag ------------------------------ #

@pytest.mark.parametrize("V,D,B,L", [(100, 8, 4, 5), (500, 24, 13, 7),
                                     (1000, 32, 32, 20)])
def test_embedding_bag_sweep(V, D, B, L, rng):
    table = jax.random.normal(jax.random.key(0), (V, D))
    idx = rng.integers(-1, V, (B, L)).astype(np.int32)
    out = embedding_bag_fused(table, jnp.asarray(idx))
    ref = embedding_bag_ref(table, jnp.asarray(idx))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
