"""Direct edge-case coverage for the shard layout contract
(partition.shard_layout / shard_arc_arrays / shard_graph) — previously only
exercised indirectly through the mesh tests."""

import numpy as np
import pytest

from repro.core.bz import bz_core_numbers
from repro.core.kcore import kcore_decompose
from repro.graph import generators as gen
from repro.graph.partition import (balance_from_counts, balance_report,
                                   shard_arc_arrays, shard_graph,
                                   shard_layout)
from repro.graph.structs import Graph


def _unshard_arcs(sg):
    """Recover the global (src, dst) pairs of all real arcs from a shard."""
    src, dst = [], []
    for d in range(sg.n_shards):
        m = sg.arc_mask[d]
        src.append(sg.src[d][m] + d * sg.verts_per_shard)
        dst.append(sg.dst[d][m])
    return np.concatenate(src), np.concatenate(dst)


@pytest.mark.parametrize("n_shards", [1, 2, 3, 4, 7, 8])
def test_round_trip_preserves_arcs(n_shards):
    g = gen.barabasi_albert(97, 3, seed=0)  # prime n: never divides evenly
    sg = shard_graph(g, n_shards)
    s, d = _unshard_arcs(sg)
    np.testing.assert_array_equal(s, g.src)
    np.testing.assert_array_equal(d, g.dst)
    # vertex bookkeeping covers exactly the real vertices
    assert int(sg.vert_mask.sum()) == g.n
    np.testing.assert_array_equal(sg.deg.reshape(-1)[: g.n][
        sg.vert_mask.reshape(-1)[: g.n]], g.deg)


def test_empty_shard():
    """More shards than occupied vertex ranges: trailing shards hold only
    padding, and the engines still decompose exactly."""
    g = gen.star(5)  # hub + 4 leaves
    assert g.n == 5
    sg = shard_graph(g, 8)
    assert sg.verts_per_shard == 1
    live = sg.arc_mask.sum(axis=1)
    assert live[0] == 4           # the hub owns every outgoing arc
    assert (live[5:] == 0).all()  # shards 5..7 are pure padding
    assert not sg.vert_mask[5:].any()
    # padding arcs carry in-range sentinels (mask False keeps them inert)
    assert (sg.dst < sg.n_pad).all()
    assert (sg.src < sg.verts_per_shard).all()


def test_isolated_vertices():
    """Vertices with no arcs shard cleanly (zero-length arc runs)."""
    g = Graph.from_edges([(0, 1)], n=10)  # vertices 2..9 isolated
    sg = shard_graph(g, 4)
    s, d = _unshard_arcs(sg)
    np.testing.assert_array_equal(s, g.src)
    np.testing.assert_array_equal(d, g.dst)
    assert int(sg.deg.sum()) == 2


def test_single_arc_graph():
    g = Graph.from_edges([(0, 1)], n=2)
    for n_shards in (1, 2, 4):
        sg = shard_graph(g, n_shards)
        s, d = _unshard_arcs(sg)
        np.testing.assert_array_equal(s, [0, 1])
        np.testing.assert_array_equal(d, [1, 0])


def test_empty_graph():
    g = Graph.from_edges(np.zeros((0, 2), np.int64), n=0)
    sg = shard_graph(g, 4)
    assert sg.n_real == 0
    assert not sg.arc_mask.any()
    assert not sg.vert_mask.any()


@pytest.mark.parametrize("n", [1, 5, 97, 100])
@pytest.mark.parametrize("n_shards", [1, 3, 4])
def test_layout_geometry(n, n_shards):
    """shard_layout invariants on non-pow2 sizes: full cover, ordered
    bounds, A covers the longest run."""
    rng = np.random.default_rng(n * 31 + n_shards)
    deg = rng.integers(0, 5, n)
    src = np.repeat(np.arange(n, dtype=np.int32), deg)
    V, A, bounds = shard_layout(n, src, n_shards)
    assert V * n_shards >= n
    assert bounds.shape == (n_shards + 1,)
    assert bounds[0] == 0 and bounds[-1] == len(src)
    assert (np.diff(bounds) >= 0).all()
    assert A >= int(np.diff(bounds).max() if n_shards else 1)
    assert A % 8 == 0
    # the floor knob never shrinks A
    _, A_floor, _ = shard_layout(n, src, n_shards, min_arcs_per_shard=A + 8)
    assert A_floor == A + 8


def test_shard_layout_matches_shard_arc_arrays():
    g = gen.erdos_renyi(n=120, m=480, seed=1)
    V, A, _ = shard_layout(g.n, g.src, 4)
    sg = shard_graph(g, 4)
    assert (V, A) == (sg.verts_per_shard, sg.arcs_per_shard)


def test_sharded_decomposition_on_awkward_shapes():
    """Non-pow2 n with empty shards still decomposes to BZ-exact cores."""
    from repro.distribution.compat import make_mesh
    from repro.core.kcore import kcore_decompose_sharded
    g = gen.barabasi_albert(101, 2, seed=3)
    mesh = make_mesh((1,), ("d",))
    res = kcore_decompose_sharded(g, mesh, ("d",))
    np.testing.assert_array_equal(res.core, bz_core_numbers(g))
    np.testing.assert_array_equal(res.core, kcore_decompose(g).core)


def test_balance_from_counts():
    rep = balance_from_counts(np.array([10, 20, 30]), padded_A=32)
    assert rep["arcs_per_shard_max"] == 30
    assert rep["arcs_per_shard_min"] == 10
    assert rep["arcs_per_shard_mean"] == 20.0
    assert rep["imbalance"] == pytest.approx(1.5)
    assert rep["padded_A"] == 32
    empty = balance_from_counts(np.zeros(0), padded_A=8)
    assert empty["arcs_per_shard_max"] == 0
    g = gen.barabasi_albert(100, 3, seed=4)
    sg = shard_graph(g, 4)
    assert balance_report(sg) == balance_from_counts(
        sg.arc_mask.sum(axis=1), sg.arcs_per_shard)


def test_dead_slots_shard_without_resort():
    """src-sorted arrays with dead slots (the streaming CSR) shard by slot
    position; dead slots stay dead."""
    src = np.array([0, 0, 1, 1, 2, 3], np.int32)
    dst = np.array([1, 3, 0, 2, 1, 0], np.int32)
    mask = np.array([True, False, True, True, False, True])
    deg = np.array([1, 2, 0, 1], np.int32)
    sg = shard_arc_arrays(4, src, dst, mask, deg, 2)
    assert int(sg.arc_mask.sum()) == 4
    s, d = _unshard_arcs(sg)
    np.testing.assert_array_equal(s, src[mask])
    np.testing.assert_array_equal(d, dst[mask])
