"""Out-of-core block-cycling driver: bit-equal cores AND message bills vs
the in-memory modes (BZ-oracle-verified), frontier block skipping, and
bounded-cache cycling."""

import numpy as np
import pytest

from repro.core.bz import bz_core_numbers
from repro.core.kcore import kcore_decompose
from repro.core.outofcore import OutOfCoreStats, outofcore_decompose
from repro.graph import generators as gen
from repro.graph.blockstore import BlockStore


def _assert_bill_equal(a, b):
    np.testing.assert_array_equal(a.stats.messages_per_round,
                                  b.stats.messages_per_round)
    np.testing.assert_array_equal(a.stats.active_per_round,
                                  b.stats.active_per_round)
    np.testing.assert_array_equal(a.stats.changed_per_round,
                                  b.stats.changed_per_round)
    assert a.rounds == b.rounds


@pytest.mark.parametrize("family,kw", [
    ("erdos_renyi", dict(n=300, m=1200)),
    ("barabasi_albert", dict(n=400, m_attach=3)),
    ("community", dict(n=300, n_blocks=5, deg_in=6, deg_out=1)),
    ("rmat", dict(scale=8, edge_factor=4)),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_bit_equal_vs_host_and_bz(family, kw, seed):
    g = getattr(gen, family)(**kw, seed=seed)
    ref = kcore_decompose(g)
    ooc = outofcore_decompose(g, mem_budget=8192)
    assert ooc.converged
    np.testing.assert_array_equal(ooc.core, bz_core_numbers(g))
    np.testing.assert_array_equal(ooc.core, ref.core)
    _assert_bill_equal(ooc, ref)


def test_bit_equal_vs_fused():
    g = gen.barabasi_albert(500, 3, seed=2)
    fused = kcore_decompose(g, fused=True)
    ooc = outofcore_decompose(g, n_blocks=8)
    np.testing.assert_array_equal(ooc.core, fused.core)
    _assert_bill_equal(ooc, fused)


def test_forced_budget_cycles_blocks():
    """The acceptance gate: a budget far below the arc arrays forces the
    LRU to actually cycle (≥1 eviction) while staying exact."""
    g = gen.barabasi_albert(600, 4, seed=3)
    ooc = outofcore_decompose(g, mem_budget=4096)
    bs = ooc.block_stats
    assert bs.n_blocks > 1
    assert bs.evictions >= 1
    assert bs.device_block_bytes < bs.total_arc_bytes
    assert bs.mem_budget == 4096
    np.testing.assert_array_equal(ooc.core, bz_core_numbers(g))


def test_frontier_skips_blocks():
    """As the frontier collapses, whole blocks go quiet and are skipped
    without loading. A community graph localizes late-round activity."""
    g = gen.community(n=400, n_blocks=8, deg_in=8, deg_out=1, seed=4)
    ooc = outofcore_decompose(g, n_blocks=16)
    bs = ooc.block_stats
    assert bs.blocks_skipped >= 1
    assert 0.0 < bs.skip_rate < 1.0
    # skipped + executed block-rounds account for every (round, block) pair
    # after round 1 plus round 1 itself
    assert bs.block_rounds + bs.blocks_skipped == bs.rounds * bs.n_blocks
    np.testing.assert_array_equal(ooc.core, bz_core_numbers(g))


def test_store_path_input(tmp_path):
    """Decompose straight from a store directory — degrees reconstructed
    from the blocks on a streaming pass."""
    g = gen.barabasi_albert(300, 3, seed=5)
    BlockStore.create(tmp_path / "s", g, n_blocks=4)
    ref = kcore_decompose(g)
    ooc = outofcore_decompose(str(tmp_path / "s"))
    np.testing.assert_array_equal(ooc.core, ref.core)
    _assert_bill_equal(ooc, ref)


def test_open_store_input(tmp_path):
    g = gen.erdos_renyi(n=250, m=1000, seed=6)
    store = BlockStore.create(tmp_path / "s", g, n_blocks=4)
    ooc = outofcore_decompose(store, deg=g.deg)
    np.testing.assert_array_equal(ooc.core, bz_core_numbers(g))
    # caller-owned store survives the decomposition
    assert (tmp_path / "s" / "manifest.json").exists()


def test_structured_graphs():
    assert (outofcore_decompose(gen.complete(12), n_blocks=3).core == 11).all()
    assert (outofcore_decompose(gen.cycle(20), n_blocks=4).core == 2).all()
    assert (outofcore_decompose(gen.star(15), n_blocks=2).core == 1).all()


def test_isolated_vertices_and_empty():
    g = gen.erdos_renyi(n=60, m=40, seed=7)  # sparse → isolated vertices
    ref = kcore_decompose(g)
    ooc = outofcore_decompose(g, n_blocks=4)
    np.testing.assert_array_equal(ooc.core, ref.core)
    _assert_bill_equal(ooc, ref)
    from repro.graph.structs import Graph
    empty = outofcore_decompose(Graph.from_edges(np.zeros((0, 2), np.int64)))
    assert empty.core.shape == (0,)
    assert empty.converged


def test_stats_json_round_trip():
    g = gen.barabasi_albert(200, 3, seed=8)
    bs = outofcore_decompose(g, mem_budget=4096).block_stats
    d = bs.to_json()
    assert d["device_block_bytes"] < d["total_arc_bytes"]
    assert d["skip_rate"] == round(bs.skip_rate, 4)
    assert set(d) >= {"n_blocks", "rounds", "blocks_loaded", "blocks_skipped",
                      "evictions", "peak_rss_bytes", "ms_per_round",
                      "imbalance"}
    assert isinstance(bs, OutOfCoreStats)


def test_flight_recorder_sees_out_of_core_run():
    """One flight run per decomposition, mode="out_of_core", with the
    block-cycling attrs on the run_end event and a bit-equal round series
    vs the host loop's recording."""
    from repro.obs import flight
    flight.enable()
    flight.reset()
    ends = []
    rec = flight.get_recorder()
    rec.add_observer(lambda ev: ends.append(ev)
                     if ev["kind"] == "run_end" else None)
    try:
        g = gen.barabasi_albert(150, 3, seed=9)
        ref = kcore_decompose(g)
        ref_series = [(r.round, r.frontier, r.messages, r.changed)
                      for r in flight.records()]
        flight.reset()
        ooc = outofcore_decompose(g, mem_budget=4096)
        ooc_series = [(r.round, r.frontier, r.messages, r.changed)
                      for r in flight.records()]
        assert ooc_series == ref_series
        end = ends[-1]
        assert end["mode"] == "out_of_core"
        assert end["converged"]
        assert end["blocks_loaded"] == ooc.block_stats.blocks_loaded
        assert end["blocks_skipped"] == ooc.block_stats.blocks_skipped
        assert end["device_block_bytes"] > 0
        assert end["peak_rss_bytes"] > 0
        assert rec.last_run_rounds == ooc.rounds
        np.testing.assert_array_equal(ooc.core, ref.core)
    finally:
        rec._observers.clear()
        flight.disable()
        flight.reset()


def test_metrics_published():
    from repro.obs import metrics
    g = gen.barabasi_albert(150, 3, seed=10)
    before = metrics.counter("kcore_ooc_blocks_loaded_total").value
    ooc = outofcore_decompose(g, mem_budget=4096)
    after = metrics.counter("kcore_ooc_blocks_loaded_total").value
    assert after - before == ooc.block_stats.blocks_loaded
    assert metrics.gauge("kcore_ooc_device_block_bytes").value == \
        ooc.block_stats.device_block_bytes
    assert metrics.gauge("kcore_block_imbalance").value >= 1.0
