"""Streaming k-core maintenance: delta layer (rebuild + in-place CSR patch),
incremental engine vs the BZ oracle under random churn, frontier modes, and
the query server."""

import numpy as np
import pytest

from repro.core import bz_core_numbers, kcore_decompose
from repro.graph import generators as gen
from repro.graph.structs import Graph
from repro.streaming import (EdgeBatch, KCoreServer, PatchableCSR, Request,
                             StreamingConfig, StreamingKCoreEngine,
                             apply_batch, canonical_edges,
                             random_churn_batch, warm_start_seed)


# ---------------------------------------------------------------------- #
# Delta layer
# ---------------------------------------------------------------------- #

def test_delta_matches_rebuild_from_edge_set():
    rng = np.random.default_rng(0)
    g = gen.erdos_renyi(60, 150, seed=0)
    edges = {tuple(e) for e in canonical_edges(g).tolist()}
    for _ in range(10):
        batch = random_churn_batch(g, 8, 8, rng)
        res = apply_batch(g, batch)
        # reference: plain python set simulation, deletes then inserts
        for u, v in batch.delete.tolist():
            edges.discard((min(u, v), max(u, v)))
        for u, v in batch.insert.tolist():
            if u != v:
                edges.add((min(u, v), max(u, v)))
        ref = Graph.from_edges(np.asarray(sorted(edges), np.int64),
                               n=res.graph.n)
        assert res.graph.m == ref.m
        assert (res.graph.src == ref.src).all()
        assert (res.graph.dst == ref.dst).all()
        g = res.graph
        edges = {tuple(e) for e in canonical_edges(g).tolist()}


def test_delta_noops_and_cleanse():
    g = Graph.from_edges([(0, 1), (1, 2)], n=4)
    # insert existing edge, a self-loop, and a duplicate pair; delete a
    # non-existent edge and one referencing an unknown vertex
    res = apply_batch(g, EdgeBatch.make(
        insert=[(1, 0), (2, 2), (3, 0), (0, 3)],
        delete=[(0, 2), (7, 9)]))
    assert res.graph.m == 3
    assert res.inserted.shape[0] == 1           # only (0, 3) was new
    assert res.deleted.shape[0] == 0
    assert res.touched.tolist() == [0, 3]


def test_delta_grows_vertex_set():
    g = Graph.from_edges([(0, 1)], n=2)
    res = apply_batch(g, EdgeBatch.make(insert=[(1, 5)]))
    assert res.graph.n == 6
    assert res.graph.m == 2
    res.graph.validate()


# ---------------------------------------------------------------------- #
# In-place CSR patching
# ---------------------------------------------------------------------- #

def test_patched_csr_equals_rebuilt_csr_under_random_churn():
    """Property: after every random churn batch the in-place patched CSR
    materializes to the exact same Graph (src/dst/offsets/deg) as the
    rebuild path, reports the identical effective delta, and its raw slot
    arrays hold the same live arc multiset — across deletions creating
    holes, inserts filling them, vertex growth, no-op churn, and forced
    compactions (tight slack)."""
    rng = np.random.default_rng(2)
    g = gen.erdos_renyi(80, 220, seed=0)
    patcher = PatchableCSR(g, slack=0.15, min_slack=2, compact_dead_frac=0.2)
    for t in range(25):
        batch = random_churn_batch(g, 10, 10, rng)
        if t % 6 == 0:   # growth + duplicate + self-loop + unknown delete
            batch = EdgeBatch.make(
                insert=np.concatenate(
                    [batch.insert, [[g.n + 1, 0], [3, 3], [1, 2], [2, 1]]]),
                delete=np.concatenate([batch.delete, [[900, 901]]]))
        ref = apply_batch(g, batch)
        got = patcher.apply_batch(batch)
        assert (got.inserted == ref.inserted).all()
        assert (got.deleted == ref.deleted).all()
        assert (got.touched == ref.touched).all()
        # raw slot arrays: live arc multiset == the rebuilt arc set
        live_arcs = np.stack([patcher.src[patcher.live],
                              patcher.dst[patcher.live]], axis=1)
        order = np.lexsort((live_arcs[:, 1], live_arcs[:, 0]))
        assert (live_arcs[order, 0] == ref.graph.src).all()
        assert (live_arcs[order, 1] == ref.graph.dst).all()
        # materialized Graph: exact equality, valid CSR
        mat = patcher.to_graph()
        mat.validate()
        assert mat.n == ref.graph.n and mat.m == ref.graph.m
        assert (mat.src == ref.graph.src).all()
        assert (mat.dst == ref.graph.dst).all()
        assert (mat.offsets == ref.graph.offsets).all()
        assert (mat.deg == ref.graph.deg).all()
        g = ref.graph
    assert patcher.compactions > 0   # the tight slack must have forced some


def test_patched_csr_row_overflow_compacts():
    """Inserting many edges at one vertex overflows its slack row and must
    trigger a compaction, not corruption."""
    g = Graph.from_edges([(0, 1)], n=6)
    p = PatchableCSR(g, slack=0.0, min_slack=1)
    res = p.apply_batch(EdgeBatch.make(insert=[(0, 2), (0, 3), (0, 4),
                                               (0, 5)]))
    assert res.compacted
    assert p.m == 5
    assert (p.to_graph().deg == np.array([5, 1, 1, 1, 1, 1])).all()


# ---------------------------------------------------------------------- #
# Warm-start seeding
# ---------------------------------------------------------------------- #

def test_vectorized_insertion_bound_matches_unionfind_reference():
    """The jitted segment-op insertion upper bound must equal the host-side
    union-find reference exactly (same passes, same peel fixpoints)."""
    from repro.streaming.engine import (_insertion_upper_bound,
                                        _insertion_upper_bound_unionfind)
    rng = np.random.default_rng(7)
    for g in (gen.erdos_renyi(100, 300, seed=2),
              gen.barabasi_albert(120, 3, seed=2)):
        core = bz_core_numbers(g).astype(np.int64)
        for _ in range(4):
            batch = random_churn_batch(g, 15, 10, rng)
            d = apply_batch(g, batch)
            oce = np.zeros(d.graph.n, np.int64)
            oce[: g.n] = core
            U_vec = _insertion_upper_bound(d.graph, oce, d.inserted)
            U_ref = _insertion_upper_bound_unionfind(d.graph, oce,
                                                     d.inserted)
            assert (U_vec == U_ref).all()
            g, core = d.graph, bz_core_numbers(d.graph).astype(np.int64)

def test_seed_is_upper_bound_on_new_cores():
    """The locality theorem needs seed >= exact new cores pointwise; check
    on random churn over several families."""
    rng = np.random.default_rng(3)
    for g in (gen.erdos_renyi(120, 400, seed=1),
              gen.barabasi_albert(150, 3, seed=1),
              gen.rmat(7, 3, seed=1)):
        core = bz_core_numbers(g)
        for _ in range(5):
            batch = random_churn_batch(g, 12, 12, rng)
            delta = apply_batch(g, batch)
            seed, region = warm_start_seed(delta.graph, core, delta)
            new_core = bz_core_numbers(delta.graph)
            assert (seed >= new_core).all()
            # every vertex whose core increased must be in the region
            inc = new_core > np.pad(core, (0, delta.graph.n - g.n))
            assert (~inc | region).all()
            g, core = delta.graph, new_core


# ---------------------------------------------------------------------- #
# Incremental engine vs the BZ oracle
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("family,kw", [
    ("erdos_renyi", dict(n=250, m=1000)),
    ("barabasi_albert", dict(n=300, m_attach=3)),
    ("rmat", dict(scale=8, edge_factor=4)),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_incremental_matches_bz_over_batches(family, kw, seed):
    """Property-style: after EVERY random insert/delete batch the incremental
    cores equal a from-scratch BZ recompute, and the incremental message
    bill never exceeds the from-scratch total."""
    g = getattr(gen, family)(**kw, seed=seed)
    eng = StreamingKCoreEngine(g)
    rng = np.random.default_rng(seed + 10)
    for _ in range(6):
        batch = random_churn_batch(eng.graph, 12, 12, rng)
        res = eng.apply_batch(batch)
        assert res.converged
        assert (res.core == bz_core_numbers(eng.graph)).all()
        scratch = kcore_decompose(eng.graph)
        assert res.total_messages <= scratch.stats.total_messages


def test_insertion_raises_core_without_incident_edge():
    """Path u-w-v plus inserted (u, v): w's core rises 1 -> 2 although no
    inserted edge touches w — the insertion region must reach it."""
    g = Graph.from_edges([(2, 0), (2, 1)], n=3)
    eng = StreamingKCoreEngine(g)
    res = eng.apply_batch(EdgeBatch.make(insert=[(0, 1)]))
    assert (res.core == np.array([2, 2, 2])).all()
    assert res.region_size >= 3


def test_batch_cascade_clique_from_empty():
    """Inserting all edges of K8 at once: every core jumps 0 -> 7, far more
    than +1 — exercises the multi-pass cascade in the region computation."""
    eng = StreamingKCoreEngine(Graph.from_edges(np.zeros((0, 2)), n=8))
    iu = np.triu_indices(8, k=1)
    res = eng.apply_batch(EdgeBatch.make(insert=np.stack(iu, axis=1)))
    assert (res.core == 7).all()


def test_delete_all_edges():
    g = gen.cycle(12)
    eng = StreamingKCoreEngine(g)
    res = eng.apply_batch(EdgeBatch.make(delete=canonical_edges(g)))
    assert (res.core == 0).all()
    assert eng.graph.m == 0


def test_empty_batch_is_free():
    eng = StreamingKCoreEngine(gen.barabasi_albert(100, 3, seed=0))
    res = eng.apply_batch(EdgeBatch.make())
    assert res.total_messages == 0
    assert res.rounds == 0
    assert (res.core == eng.init_result.core).all()


def test_compact_frontier_equals_dense():
    g = gen.barabasi_albert(200, 4, seed=9)
    dense = StreamingKCoreEngine(g, StreamingConfig(frontier="dense"))
    compact = StreamingKCoreEngine(g, StreamingConfig(frontier="compact"))
    rng = np.random.default_rng(4)
    for _ in range(4):
        batch = random_churn_batch(dense.graph, 10, 10, rng)
        r1, r2 = dense.apply_batch(batch), compact.apply_batch(batch)
        assert (r1.core == r2.core).all()
        assert (r1.stats.messages_per_round
                == r2.stats.messages_per_round).all()
        assert (r1.core == bz_core_numbers(dense.graph)).all()


# ---------------------------------------------------------------------- #
# Query server
# ---------------------------------------------------------------------- #

def test_server_queries_and_updates():
    g = gen.barabasi_albert(200, 3, seed=2)
    srv = KCoreServer(g)
    ref = bz_core_numbers(g)
    ids = np.array([0, 5, 17, 199])
    assert (srv.core_number(ids) == ref[ids]).all()
    assert srv.max_k() == int(ref.max())
    assert (srv.kcore_members(2) == np.flatnonzero(ref >= 2)).all()

    rng = np.random.default_rng(5)
    batch = random_churn_batch(g, 15, 15, rng)
    out = srv.serve([Request(op="update", batch=batch),
                     Request(op="core", vertices=ids),
                     Request(op="in_kcore", vertices=ids, k=2),
                     Request(op="max_k")])
    ref = bz_core_numbers(srv.engine.graph)
    assert (out[1].payload == ref[ids]).all()
    assert (out[2].payload == (ref[ids] >= 2)).all()
    assert out[3].payload == int(ref.max())
    st = srv.stats()
    assert st["updates_applied"] == 1 and st["queries_served"] == 3


def test_server_rejects_bad_ids():
    srv = KCoreServer(gen.cycle(10))
    # direct methods raise (library API) ...
    with pytest.raises(IndexError):
        srv.core_number([10])
    # ... but the request loop answers a structured error Response: a bad
    # request must never raise through a serving front end, and it must be
    # rejected before touching any state
    out = srv.serve([Request(op="nope"),
                     Request(op="core", vertices=[10]),
                     Request(op="in_kcore", vertices=[0]),       # missing k
                     Request(op="core", vertices=[0])])
    assert not out[0].ok and "unknown op" in out[0].error
    assert not out[1].ok and out[1].payload is None
    assert not out[2].ok and "requires k" in out[2].error
    assert out[3].ok and out[3].payload.tolist() == [2]
    assert srv.errors_returned == 3
    assert srv.stats()["queries_served"] == 1     # errors aren't queries
