"""CI gate over the mixed-traffic serving benchmark.

Runs benchmarks.serving_mixed (concurrent readers hammering snapshots
while the writer replays a temporal trace), writes the full structured
output to BENCH_serving.json, and fails if the write path's mean
incremental/from-scratch message ratio regresses past the threshold
against the committed baseline (benchmarks/serving_baseline.json).

This is an exactness lock more than a perf gate: readers never touch the
engine, so the bills under concurrent load must be bit-identical to the
same replay without readers — a drifting ratio here means the front end
started perturbing convergence. Latency/staleness are reported as
informational columns; the benchmark itself asserts the serving
acceptance bar (reads proceed during re-convergence, every response
bit-equal to a BZ-anchored fixpoint).

    # CI (smoke settings; the workflow sets the env knobs):
    python -m benchmarks.serving_gate --require-match

    # refresh the committed baseline after an intended perf change:
    REPRO_SERVING_BENCH_N=800 REPRO_SERVING_BENCH_TICKS=4 \
        python -m benchmarks.serving_gate --write-baseline
"""

import pathlib
import sys

from benchmarks.gate_common import gate_main
from benchmarks.serving_mixed import run_records, settings, summarize

BASELINE = pathlib.Path(__file__).parent / "serving_baseline.json"


def main() -> int:
    return gate_main(
        run_records=run_records,
        settings=settings,
        summarize=summarize,
        baseline=BASELINE,
        default_out="BENCH_serving.json",
        label="serving",
    )


if __name__ == "__main__":
    sys.exit(main())
