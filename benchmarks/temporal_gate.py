"""CI perf gate over the temporal replay benchmark.

Runs benchmarks.temporal_replay (every step BZ-oracle-verified inside the
benchmark), writes the full structured output to a JSON artifact
(BENCH_temporal.json), and fails if any per-trace mean incremental/
from-scratch message ratio regresses past a threshold against the
committed baseline (benchmarks/temporal_baseline.json). Gate semantics
(thresholds, baseline settings match, --write-baseline) live in
benchmarks.gate_common, shared with the streaming gate.

    # CI (smoke settings; the workflow sets the env knobs):
    python -m benchmarks.temporal_gate

    # refresh the committed baseline after an intended perf change:
    REPRO_TEMPORAL_BENCH_N=600 REPRO_TEMPORAL_BENCH_STEPS=4 \
        python -m benchmarks.temporal_gate --write-baseline
"""

import pathlib
import sys

from benchmarks.gate_common import gate_main
from benchmarks.temporal_replay import run_records, settings, summarize

BASELINE = pathlib.Path(__file__).parent / "temporal_baseline.json"


def main() -> int:
    return gate_main(
        run_records=run_records,
        settings=settings,
        summarize=summarize,
        baseline=BASELINE,
        default_out="BENCH_temporal.json",
        label="temporal",
    )


if __name__ == "__main__":
    sys.exit(main())
