"""Roofline report: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (one row per arch x shape x mesh) with the
three terms, dominant bottleneck, MODEL_FLOPS and the useful-compute ratio.

The dry-run must have been executed first:
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod1
"""

from __future__ import annotations

import json
import pathlib

from repro.configs import get_config
from repro.configs.registry import shape_by_name

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / \
    "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS: 6·N·D (dense train) / 6·N_active·D (MoE train);
    2·N_active per decoded token; prefill = 2·N_active·D."""
    cfg = get_config(arch)
    shape = shape_by_name(arch, shape_name)
    if cfg.family == "lm":
        n_act = cfg.n_active_params
        if shape.kind == "train":
            D = shape.params["seq_len"] * shape.params["global_batch"]
            return 6.0 * n_act * D
        if shape.kind == "prefill":
            D = shape.params["seq_len"] * shape.params["global_batch"]
            return 2.0 * n_act * D
        return 2.0 * n_act * shape.params["global_batch"]   # decode: 1 tok
    if cfg.family == "gnn":
        # per-edge message MLP + per-node update, x3 for fwd+bwd
        p = shape.params
        E = 2 * p.get("n_edges", p.get("batch", 1) * p.get("n_edges", 64))
        d = cfg.d_hidden
        return 3.0 * cfg.n_layers * (E * (6 * d * d) * 2)
    # recsys: embedding + MLPs per example
    cfgr = cfg
    B = shape.params.get("batch", 1) * max(
        shape.params.get("n_candidates", 1), 1)
    mlp_flops = 0
    dims = [8 * cfgr.embed_dim] + list(cfgr.mlp) + [1]
    for a, b in zip(dims[:-1], dims[1:]):
        mlp_flops += 2 * a * b
    return float(B) * mlp_flops * (3.0 if shape.kind == "train" else 1.0)


def run() -> list[str]:
    rows = ["arch,shape,mesh,chips,compute_s,memory_s,collective_s,"
            "dominant,bound_s,model_flops,hlo_flops,useful_ratio,"
            "mem_per_dev_GB,fits_16GB"]
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("status") != "OK":
            if d.get("status") == "SKIP":
                rows.append(f"{d['arch']},{d['shape']},{d['mesh']},,,,,SKIP,"
                            f",,,,,{d.get('reason', '')}")
            continue
        r = d["roofline"]
        mem = d.get("memory", {})
        live = mem.get("per_device_live_bytes", 0) / 1e9
        try:
            mf = model_flops(d["arch"], d["shape"]) if d["arch"] != "kcore" \
                else 0.0
        except Exception:
            mf = 0.0
        ratio = round(mf / r["flops"], 3) if r["flops"] and mf else ""
        rows.append(",".join(str(x) for x in (
            d["arch"], d["shape"], d["mesh"], d.get("chips", ""),
            f"{r['compute_s']:.5f}", f"{r['memory_s']:.5f}",
            f"{r['collective_s']:.5f}", r["dominant"],
            f"{max(r['compute_s'], r['memory_s'], r['collective_s']):.5f}",
            f"{mf:.3e}" if mf else "", f"{r['flops']:.3e}", ratio,
            f"{live:.2f}", mem.get("fits_16GB", ""))))
    return rows
