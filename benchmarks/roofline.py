"""Roofline report: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (one row per arch x shape x mesh) with the
three terms, dominant bottleneck, MODEL_FLOPS and the useful-compute ratio.

The dry-run must have been executed first:
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod1

Superstep mode (``--superstep``) measures the k-core masked superstep
ITSELF instead of aggregating dry-runs: for each (graph, dispatch) pair it
compiles the dispatched round program (repro.core.dispatch), reads the
compiled cost analysis (flops / bytes accessed), times the superstep wall,
and reports achieved vs peak flops/s and bytes/s against the platform
layer's per-backend peaks (repro.platform.peaks) — the measurable
trajectory toward the EEN-118/FC-283 ms/round floor:

    PYTHONPATH=src python -m benchmarks.roofline --superstep --json out.json
"""

from __future__ import annotations

import json
import pathlib
import time

from repro.configs import get_config
from repro.configs.registry import shape_by_name

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / \
    "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS: 6·N·D (dense train) / 6·N_active·D (MoE train);
    2·N_active per decoded token; prefill = 2·N_active·D."""
    cfg = get_config(arch)
    shape = shape_by_name(arch, shape_name)
    if cfg.family == "lm":
        n_act = cfg.n_active_params
        if shape.kind == "train":
            D = shape.params["seq_len"] * shape.params["global_batch"]
            return 6.0 * n_act * D
        if shape.kind == "prefill":
            D = shape.params["seq_len"] * shape.params["global_batch"]
            return 2.0 * n_act * D
        return 2.0 * n_act * shape.params["global_batch"]   # decode: 1 tok
    if cfg.family == "gnn":
        # per-edge message MLP + per-node update, x3 for fwd+bwd
        p = shape.params
        E = 2 * p.get("n_edges", p.get("batch", 1) * p.get("n_edges", 64))
        d = cfg.d_hidden
        return 3.0 * cfg.n_layers * (E * (6 * d * d) * 2)
    # recsys: embedding + MLPs per example
    cfgr = cfg
    B = shape.params.get("batch", 1) * max(
        shape.params.get("n_candidates", 1), 1)
    mlp_flops = 0
    dims = [8 * cfgr.embed_dim] + list(cfgr.mlp) + [1]
    for a, b in zip(dims[:-1], dims[1:]):
        mlp_flops += 2 * a * b
    return float(B) * mlp_flops * (3.0 if shape.kind == "train" else 1.0)


def run() -> list[str]:
    rows = ["arch,shape,mesh,chips,compute_s,memory_s,collective_s,"
            "dominant,bound_s,model_flops,hlo_flops,useful_ratio,"
            "mem_per_dev_GB,fits_16GB"]
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("status") != "OK":
            if d.get("status") == "SKIP":
                rows.append(f"{d['arch']},{d['shape']},{d['mesh']},,,,,SKIP,"
                            f",,,,,{d.get('reason', '')}")
            continue
        r = d["roofline"]
        mem = d.get("memory", {})
        live = mem.get("per_device_live_bytes", 0) / 1e9
        try:
            mf = model_flops(d["arch"], d["shape"]) if d["arch"] != "kcore" \
                else 0.0
        except Exception:
            mf = 0.0
        ratio = round(mf / r["flops"], 3) if r["flops"] and mf else ""
        rows.append(",".join(str(x) for x in (
            d["arch"], d["shape"], d["mesh"], d.get("chips", ""),
            f"{r['compute_s']:.5f}", f"{r['memory_s']:.5f}",
            f"{r['collective_s']:.5f}", r["dominant"],
            f"{max(r['compute_s'], r['memory_s'], r['collective_s']):.5f}",
            f"{mf:.3e}" if mf else "", f"{r['flops']:.3e}", ratio,
            f"{live:.2f}", mem.get("fits_16GB", ""))))
    return rows


# ---------------------------------------------------------------------- #
# Superstep roofline: achieved vs peak for the dispatched masked round
# ---------------------------------------------------------------------- #

def superstep_records(ns=(2000,), m_attach: int = 4,
                      dispatches=("xla", "pallas"), reps: int = 5) -> list:
    """Compile + time the dispatched masked superstep per (graph, dispatch).

    One record per pair: HLO flops / bytes from the compiled program's cost
    analysis, best-of-``reps`` wall, achieved rates, and the fraction of the
    platform peaks those rates reach. Pallas rows are skipped on jax builds
    without Pallas; on CPU/GPU they run in interpret mode — expect achieved
    fractions far below the XLA rows there (the columns exist exactly so
    that gap is measurable, per-backend, over time).
    """
    import jax
    import jax.numpy as jnp

    from repro import platform
    from repro.core import dispatch as dmod
    from repro.core.kcore import _bs_iters
    from repro.graph.generators import barabasi_albert
    from repro.graph.structs import build_ell

    peak_flops, peak_bw = platform.peaks()
    backend = jax.default_backend()
    records = []
    for n in ns:
        g = barabasi_albert(int(n), m_attach, seed=0)
        n_iters = _bs_iters(g.max_deg)
        est = jnp.asarray(g.deg, jnp.int32)
        amask = jnp.ones(g.num_arcs, bool)
        act = jnp.ones(g.n, bool)
        for mode in dispatches:
            if mode == "pallas" and not dmod.pallas_supported():
                continue
            plan = dmod.DispatchPlan(kind=mode,
                                     interpret=platform.interpret_kernels())
            ell = build_ell(g) if mode == "pallas" else None
            prog = dmod.masked_round_program(g.n, n_iters, plan,
                                             g.src, g.dst, ell=ell)
            compiled = prog.lower(est, amask, act).compile()
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            flops = float(ca.get("flops", 0.0) or 0.0)
            nbytes = float(ca.get("bytes accessed", 0.0) or 0.0)
            jax.block_until_ready(prog(est, amask, act))   # warmup
            wall = min(_timed_round(prog, est, amask, act)
                       for _ in range(max(reps, 1)))
            ach_flops = flops / wall if wall > 0 else 0.0
            ach_bw = nbytes / wall if wall > 0 else 0.0
            records.append({
                "graph": f"ba_{g.n}_{m_attach}", "n": g.n, "m": g.m,
                "backend": backend, "dispatch": mode,
                "interpret": bool(plan.interpret and mode == "pallas"),
                "n_iters": n_iters, "ms_per_round": wall * 1e3,
                "hlo_flops": flops, "hlo_bytes": nbytes,
                "achieved_gflops": ach_flops / 1e9,
                "achieved_gbs": ach_bw / 1e9,
                "peak_gflops": peak_flops / 1e9,
                "peak_gbs": peak_bw / 1e9,
                "frac_peak_flops": ach_flops / peak_flops,
                "frac_peak_bytes": ach_bw / peak_bw,
            })
    return records


def _timed_round(prog, est, amask, act) -> float:
    import jax

    t0 = time.perf_counter()
    jax.block_until_ready(prog(est, amask, act))
    return time.perf_counter() - t0


def superstep_rows(records: list) -> list[str]:
    cols = ("graph", "n", "m", "backend", "dispatch", "interpret",
            "ms_per_round", "hlo_flops", "hlo_bytes", "achieved_gflops",
            "achieved_gbs", "peak_gflops", "peak_gbs", "frac_peak_flops",
            "frac_peak_bytes")
    rows = [",".join(cols)]
    for r in records:
        vals = []
        for c in cols:
            v = r[c]
            if isinstance(v, float):
                v = f"{v:.4g}"
            vals.append(str(v))
        rows.append(",".join(vals))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--superstep", action="store_true",
                    help="measure the dispatched masked superstep instead "
                         "of aggregating dry-run artifacts")
    ap.add_argument("--n", type=int, nargs="+", default=[2000],
                    help="graph sizes (barabasi-albert) for --superstep")
    ap.add_argument("--m-attach", type=int, default=4)
    ap.add_argument("--dispatch", nargs="+", default=["xla", "pallas"],
                    choices=["xla", "pallas"])
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--json", metavar="PATH",
                    help="also write the records as JSON")
    args = ap.parse_args()
    if args.superstep:
        records = superstep_records(ns=args.n, m_attach=args.m_attach,
                                    dispatches=tuple(args.dispatch),
                                    reps=args.reps)
        rows = superstep_rows(records)
        if args.json:
            pathlib.Path(args.json).write_text(json.dumps(records, indent=2))
    else:
        rows = run()
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
