"""CI perf gate over the streaming maintenance benchmark.

Runs benchmarks.streaming_maintenance, writes the full structured output to
a JSON artifact (BENCH_streaming.json), and fails if the per-(graph, churn)
mean incremental/from-scratch message ratio regresses past a threshold
against the committed baseline (benchmarks/streaming_baseline.json).

The ratio is integer-deterministic for fixed settings (message counts are
exact, the churn RNG is seeded), so the threshold only needs to absorb
genuine algorithmic regressions, not noise. The baseline records the
settings it was generated under; a run with different settings (e.g. a
local full-scale run) skips the comparison instead of spuriously failing.

    # CI (smoke settings; the workflow sets the env knobs):
    python -m benchmarks.streaming_gate

    # refresh the committed baseline after an intended perf change:
    REPRO_STREAM_BENCH_N=800 REPRO_STREAM_BENCH_BATCHES=2 \
        python -m benchmarks.streaming_gate --write-baseline
"""

import argparse
import json
import pathlib
import sys

from benchmarks.streaming_maintenance import run_records, settings, summarize

BASELINE = pathlib.Path(__file__).parent / "streaming_baseline.json"
GATE_HELP = "fail when mean_ratio > baseline * this factor + slack"
MATCH_HELP = "fail on baseline-settings mismatch instead of skipping"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_streaming.json")
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--write-baseline", action="store_true")
    ap.add_argument("--max-regression", type=float, default=1.5, help=GATE_HELP)
    ap.add_argument("--abs-slack", type=float, default=0.01)
    # CI passes this so editing the bench settings without --write-baseline
    # cannot silently disarm the gate
    ap.add_argument("--require-match", action="store_true", help=MATCH_HELP)
    args = ap.parse_args()

    records = run_records()
    summary = summarize(records)
    payload = {"settings": settings(), "summary": summary, "records": records}
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2))
    print(f"wrote {args.out} ({len(records)} records)")

    if args.write_baseline:
        ratios = {k: v["mean_ratio"] for k, v in summary.items()}
        base = {"settings": settings(), "mean_ratio": ratios}
        pathlib.Path(args.baseline).write_text(json.dumps(base, indent=2))
        print(f"wrote baseline {args.baseline}")
        return 0

    base_path = pathlib.Path(args.baseline)
    if not base_path.exists():
        print(f"no baseline at {args.baseline}; nothing to gate against")
        return 1
    base = json.loads(base_path.read_text())
    if base.get("settings") != settings():
        print(
            "baseline settings differ from this run "
            f"({base.get('settings')} vs {settings()})",
        )
        if args.require_match:
            print("refusing to gate against a stale baseline; regenerate it")
            return 1
        print("skipping comparison (pass --require-match to fail instead)")
        return 0

    failures = []
    for key, base_ratio in base["mean_ratio"].items():
        cur = summary.get(key)
        if cur is None:
            failures.append(f"{key}: missing from current run")
            continue
        limit = base_ratio * args.max_regression + args.abs_slack
        status = "OK" if cur["mean_ratio"] <= limit else "REGRESSED"
        print(
            f"{key}: ratio {cur['mean_ratio']} vs baseline {base_ratio} "
            f"(limit {limit:.4f}) {status}",
        )
        if cur["mean_ratio"] > limit:
            detail = f"(baseline {base_ratio})"
            failures.append(f"{key}: {cur['mean_ratio']} > {limit:.4f} {detail}")
    if failures:
        print("streaming message-ratio regression:", *failures, sep="\n  ")
        return 1
    print("streaming ratio gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
