"""CI perf gate over the streaming maintenance benchmark.

Runs benchmarks.streaming_maintenance, writes the full structured output to
a JSON artifact (BENCH_streaming.json), and fails if the per-(graph, churn)
mean incremental/from-scratch message ratio regresses past a threshold
against the committed baseline (benchmarks/streaming_baseline.json).
Gate semantics (thresholds, baseline settings match, --write-baseline)
live in benchmarks.gate_common, shared with the temporal gate.

    # CI (smoke settings; the workflow sets the env knobs):
    python -m benchmarks.streaming_gate

    # refresh the committed baseline after an intended perf change:
    REPRO_STREAM_BENCH_N=800 REPRO_STREAM_BENCH_BATCHES=2 \
        python -m benchmarks.streaming_gate --write-baseline
"""

import pathlib
import sys

from benchmarks.gate_common import gate_main
from benchmarks.streaming_maintenance import run_records, settings, summarize

BASELINE = pathlib.Path(__file__).parent / "streaming_baseline.json"


def main() -> int:
    return gate_main(
        run_records=run_records,
        settings=settings,
        summarize=summarize,
        baseline=BASELINE,
        default_out="BENCH_streaming.json",
        label="streaming",
    )


if __name__ == "__main__":
    sys.exit(main())
