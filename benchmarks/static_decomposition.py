"""Static decomposition benchmark: host-loop vs fused runtime (ISSUE 5).

The paper's headline experiment — from-scratch distributed k-core
decomposition — run over Table-I analogues in BOTH execution modes:

* ``host`` — the per-round Python loop (one jitted superstep per round);
* ``fused`` — the whole round loop as ONE device-resident ``lax.while_loop``
  through the shared fused runtime (``kcore_decompose(..., fused=True)``).

Every graph asserts the fused mode bit-equal to the host loop (cores AND
per-round messages/active/changed, round count, convergence flag) and the
host cores exact vs the BZ oracle — so the wall/ratio columns only compare
things that provably compute the same answer. The fused column reports a
cold wall (first call, pays the XLA compile, ``recompiles`` counts it) and
a warm wall (second call, all programs cache hits) separately.

``benchmarks.static_gate`` turns the per-graph messages-over-work-bound
ratio into a CI regression gate against ``benchmarks/static_baseline.json``
(message bills are integer-deterministic for seeded generators, so the
tight gate is an exactness lock on the paper's measurement set, not a noise
threshold) and writes the full structured output as ``BENCH_static.json``.

Environment knobs (for CI smoke):
  REPRO_BENCH_SCALE          analogue scale        (default 0.05, common.py)
  REPRO_STATIC_BENCH_GRAPHS  comma-separated Table-I abbrevs
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import SCALE, csv_row, graph_for
from repro.core import bz_core_numbers, kcore_decompose, work_bound
from repro.core.messages import heartbeat_overhead
from repro.obs import flight, health

GRAPHS = tuple(os.environ.get("REPRO_STATIC_BENCH_GRAPHS", "EEN,G31,FC,PTBR,MGF").split(","))

COLUMNS = (
    "graph",
    "n",
    "m",
    "max_core",
    "rounds",
    "total_messages",
    "work_bound",
    "ratio",
    "host_ms",
    "host_ms_per_round",
    "fused_cold_ms",
    "fused_ms",
    "fused_ms_per_round",
    "device_ms",
    "reconstruct_ms",
    "compile_s",
    "heartbeats",
    "recompiles",
    "speedup",
    "flight_ms",
    "flight_records",
    "health_ok",
    "bit_equal",
    "oracle_ok",
)


def settings() -> dict:
    return {"scale": SCALE, "graphs": list(GRAPHS)}


def _bit_equal(a, b) -> bool:
    return bool(
        (a.core == b.core).all()
        and (a.stats.messages_per_round == b.stats.messages_per_round).all()
        and (a.stats.active_per_round == b.stats.active_per_round).all()
        and (a.stats.changed_per_round == b.stats.changed_per_round).all()
        and a.rounds == b.rounds
        and a.converged == b.converged
    )


def run_records() -> list[dict]:
    """Structured per-graph records (CSV in run(), JSON in static_gate)."""
    records = []
    for abbrev in GRAPHS:
        g = graph_for(abbrev)

        t0 = time.perf_counter()
        host = kcore_decompose(g)
        host_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        fused = kcore_decompose(g, fused=True)
        fused_cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        fused_warm = kcore_decompose(g, fused=True)
        fused_s = time.perf_counter() - t0

        # fourth run: fused again UNDER the flight recorder + invariant
        # monitor — measures the observability wall and re-asserts the
        # accounting is untouched by recording
        flight.enable()
        health.install()
        flight.reset()
        health.reset()
        try:
            t0 = time.perf_counter()
            fused_flight = kcore_decompose(g, fused=True)
            flight_s = time.perf_counter() - t0
            flight_records = flight.get_recorder().rounds_recorded
            health_ok = health.ok()
        finally:
            flight.disable()
            flight.reset()
            health.reset()

        bit_equal = (
            _bit_equal(host, fused)
            and _bit_equal(host, fused_warm)
            and _bit_equal(host, fused_flight)
        )
        assert bit_equal, (
            f"{abbrev}: fused decomposition diverged from the host loop "
            "(cores or per-round accounting)"
        )
        ok = bool((host.core == bz_core_numbers(g)).all())
        assert ok, f"{abbrev}: host-loop cores diverged from the BZ oracle"

        wb = work_bound(g, host.core)
        rounds = max(host.rounds, 1)
        records.append(
            {
                "graph": abbrev,
                "n": g.n,
                "m": g.m,
                "max_core": int(host.core.max()) if g.n else 0,
                "rounds": host.rounds,
                "total_messages": int(host.stats.total_messages),
                "work_bound": wb,
                "ratio": round(host.stats.total_messages / max(wb, 1), 4),
                "host_ms": round(host_s * 1e3, 3),
                "host_ms_per_round": round(host_s * 1e3 / rounds, 3),
                "fused_cold_ms": round(fused_cold_s * 1e3, 3),
                "fused_ms": round(fused_s * 1e3, 3),
                "fused_ms_per_round": round(fused_s * 1e3 / rounds, 3),
                # warm fused phase breakdown (KCoreResult.phase_s) and the
                # wall XLA spent compiling for the COLD call
                "device_ms": round(fused_warm.phase_s.get("device-converge", 0.0) * 1e3, 3),
                "reconstruct_ms": round(fused_warm.phase_s.get("host-reconstruct", 0.0) * 1e3, 3),
                "compile_s": round(fused.compile_s, 3),
                # modeled termination-detection bill (§III.C heartbeats)
                "heartbeats": int(heartbeat_overhead(host.stats)["heartbeat_messages"]),
                "recompiles": fused.recompiles,
                "speedup": round(host_s / max(fused_s, 1e-9), 2),
                # warm fused wall with the flight recorder on, and what it
                # captured (overhead target: see temporal_replay)
                "flight_ms": round(flight_s * 1e3, 3),
                "flight_records": flight_records,
                "health_ok": health_ok,
                "bit_equal": bit_equal,
                "oracle_ok": ok,
            }
        )
    return records


def summarize(records: list[dict]) -> dict:
    """Per-graph gated ratio (messages over the paper's work bound W) plus
    the wall/compile telemetry the baseline records as info keys."""
    return {
        r["graph"]: {
            "mean_ratio": r["ratio"],
            "mean_ms_per_round": r["fused_ms_per_round"],
            "host_ms_per_round": r["host_ms_per_round"],
            "recompiles": r["recompiles"],
            "speedup": r["speedup"],
        }
        for r in records
    }


def run() -> list[str]:
    records = run_records()
    rows = [csv_row(*COLUMNS)]
    rows.extend(csv_row(*(r[c] for c in COLUMNS)) for r in records)
    speedups = [r["speedup"] for r in records]
    rows.append(csv_row("# mean_speedup", round(float(np.mean(speedups)), 2), ""))
    return rows
