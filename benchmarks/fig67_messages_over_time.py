"""Paper Figs 6-7: messages passed per time interval (BSP round here).

Claims checked: most messages move in the first couple of intervals; the
count decays as vertices go inactive."""

from benchmarks.common import csv_row, decompose

GRAPHS = ("FC", "EEN", "G31", "CA", "WG", "S0811", "PTBR", "MGF")


def run() -> list[str]:
    rows = [csv_row("graph", "round", "messages")]
    fracs = []
    decays = []
    for g in GRAPHS:
        res, _ = decompose(g)
        mpr = res.stats.messages_per_round
        for r, m in enumerate(mpr):
            rows.append(csv_row(g, r, int(m)))
        frac3 = mpr[:3].sum() / max(mpr.sum(), 1)
        fracs.append(frac3)
        rows.append(csv_row(f"# {g}_frac_first_3_rounds", round(frac3, 3),
                            ""))
        decays.append(len(mpr) < 3 or mpr[-1] <= mpr[1])
    # Paper claim ('most messages in the first couple of intervals'):
    # holds for the majority of graphs; per-graph fractions above.
    majority = sum(f >= 0.5 for f in fracs) >= len(fracs) / 2
    rows.append(csv_row("# front_loaded_majority", majority,
                        round(sum(fracs) / len(fracs), 3)))
    rows.append(csv_row("# tail_decays_all", all(decays), ""))
    return rows
