"""Shared scaffolding for the benchmark regression gates.

A gate (streaming_gate, temporal_gate) runs its benchmark's
``run_records``, writes the full structured output to a JSON artifact,
optionally refreshes the committed baseline, and otherwise fails when any
gated ``mean_ratio`` regresses past ``baseline * max_regression +
abs_slack``. Message counts are exact and every generator is seeded, so
for fixed settings the ratios are integer-deterministic — the threshold
only has to absorb genuine algorithmic regressions, not noise.

The baseline records the settings it was generated under; a run with
different settings (e.g. a local full-scale run) skips the comparison
instead of spuriously failing, unless ``--require-match`` is passed (CI
passes it so editing bench settings without ``--write-baseline`` cannot
silently disarm the gate).
"""

import argparse
import json
import os
import pathlib

GATE_HELP = "fail when mean_ratio > baseline * this factor + slack"
MATCH_HELP = "fail on baseline-settings mismatch instead of skipping"


def write_job_summary(lines) -> None:
    """Append a markdown block to the GitHub Actions job summary.

    No-op outside Actions ($GITHUB_STEP_SUMMARY unset), so gates and
    benchmarks call it unconditionally; in CI the verdict tables land on
    the run's summary page instead of only in scrollback."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n\n")


def gate_main(*, run_records, settings, summarize, baseline, default_out,
              label) -> int:
    """One gate run; returns the process exit code.

    ``run_records``/``settings``/``summarize`` are the benchmark module's
    hooks; ``baseline`` is the committed baseline path; ``label`` names
    the gate in its verdict lines.
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=default_out)
    ap.add_argument("--baseline", default=str(baseline))
    ap.add_argument("--write-baseline", action="store_true")
    ap.add_argument("--max-regression", type=float, default=1.5,
                    help=GATE_HELP)
    ap.add_argument("--abs-slack", type=float, default=0.01)
    ap.add_argument("--require-match", action="store_true", help=MATCH_HELP)
    args = ap.parse_args()

    records = run_records()
    summary = summarize(records)
    payload = {"settings": settings(), "summary": summary,
               "records": records}
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2))
    print(f"wrote {args.out} ({len(records)} records)")

    if args.write_baseline:
        ratios = {k: v["mean_ratio"] for k, v in summary.items()}
        base = {"settings": settings(), "mean_ratio": ratios}
        # informational only (not gated): the wall/compile telemetry the
        # ratios were recorded alongside, so a baseline refresh documents
        # the perf state it locked in
        for key in ("mean_ms_per_round", "recompiles"):
            vals = {k: v[key] for k, v in summary.items() if key in v}
            if vals:
                base[f"info_{key}"] = vals
        pathlib.Path(args.baseline).write_text(json.dumps(base, indent=2))
        print(f"wrote baseline {args.baseline}")
        write_job_summary([f"### `{label}` gate",
                           f"baseline refreshed → `{args.baseline}`"])
        return 0

    base_path = pathlib.Path(args.baseline)
    if not base_path.exists():
        print(f"no baseline at {args.baseline}; nothing to gate against")
        write_job_summary([f"### `{label}` gate",
                           f"**FAIL** — no baseline at `{args.baseline}`"])
        return 1
    base = json.loads(base_path.read_text())
    if base.get("settings") != settings():
        print(
            "baseline settings differ from this run "
            f"({base.get('settings')} vs {settings()})",
        )
        verdict = ("**FAIL** — baseline settings mismatch"
                   if args.require_match else
                   "skipped — baseline settings mismatch")
        write_job_summary([f"### `{label}` gate", verdict])
        if args.require_match:
            print("refusing to gate against a stale baseline; regenerate it")
            return 1
        print("skipping comparison (pass --require-match to fail instead)")
        return 0

    failures = []
    table = [f"### `{label}` gate", "",
             "| key | mean_ratio | baseline | limit | status |",
             "|---|---|---|---|---|"]
    for key, base_ratio in base["mean_ratio"].items():
        cur = summary.get(key)
        if cur is None:
            failures.append(f"{key}: missing from current run")
            table.append(f"| {key} | — | {base_ratio} | — | MISSING |")
            continue
        limit = base_ratio * args.max_regression + args.abs_slack
        status = "OK" if cur["mean_ratio"] <= limit else "REGRESSED"
        print(
            f"{key}: ratio {cur['mean_ratio']} vs baseline {base_ratio} "
            f"(limit {limit:.4f}) {status}",
        )
        table.append(f"| {key} | {cur['mean_ratio']} | {base_ratio} | "
                     f"{limit:.4f} | {status} |")
        if cur["mean_ratio"] > limit:
            detail = f"(baseline {base_ratio})"
            failures.append(
                f"{key}: {cur['mean_ratio']} > {limit:.4f} {detail}")
    table.append("")
    table.append("**FAIL**" if failures else "**PASS**")
    write_job_summary(table)
    if failures:
        print(f"{label} message-ratio regression:", *failures, sep="\n  ")
        return 1
    print(f"{label} ratio gate passed")
    return 0
