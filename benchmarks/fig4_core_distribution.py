"""Paper Fig 4: core-number distributions — 'a great portion of vertices
have small core numbers, and few have large core numbers'."""

import numpy as np

from benchmarks.common import csv_row, decompose

GRAPHS = ("FC", "EEN", "G31", "CA", "PTBR", "MGF")


def run() -> list[str]:
    rows = [csv_row("graph", "core_k", "n_vertices")]
    checks = []
    for g in GRAPHS:
        res, _ = decompose(g)
        hist = np.bincount(res.core)
        for k, c in enumerate(hist):
            if c:
                rows.append(csv_row(g, k, int(c)))
        # paper claim: distribution is skewed toward small cores
        low = hist[: max(len(hist) // 2, 1)].sum()
        checks.append(low >= hist.sum() * 0.5)
    rows.append(csv_row("# skew_claim_holds", all(checks), "", ""))
    return rows
