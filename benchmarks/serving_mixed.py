"""Mixed-traffic serving benchmark: concurrent reads under temporal churn.

Drives the snapshot-isolated front end (repro.streaming.concurrent) the way
a deployment would: ONE writer replays a temporal trace through the
windowed engine (every tick is a window advance + incremental
re-convergence) while READER threads hammer the published snapshot with
sampled ``core`` / ``in_kcore`` / ``members`` / ``core_asof`` reads. It
reports

  * p50/p99 read latency and updates/sec under the mixed load;
  * the observed stale-read window (max sampled snapshot age — readers
    serve the PREVIOUS fixpoint while the writer re-converges, so this
    tracks the longest re-convergence);
  * reads completed DURING re-convergence (the point of the front end:
    this is > 0 and read latency stays orders below the batch wall);
  * the read-consistency assertion: every response is verified bit-equal
    to the registered fixpoint of the snapshot version it was answered
    from, and registered fixpoints are BZ-verified every VERIFY_EVERY
    flips. A torn, partially-flipped, or mid-convergence read would fail
    here.

The GATED signal (serving_gate.py) is the write path's incremental /
from-scratch message ratio under concurrent read load — an exactness
lock, not a latency gate: snapshots are published copies and readers
never touch the engine, so the bills must be bit-identical to the same
replay without readers (integer-deterministic for fixed settings; the
latency/staleness columns are informational).

Env knobs (recorded in settings(); CI smoke sets small values):
REPRO_SERVING_BENCH_N, REPRO_SERVING_BENCH_TICKS,
REPRO_SERVING_BENCH_READERS, REPRO_SERVING_BENCH_FRONTIER,
REPRO_SERVING_BENCH_VERIFY_EVERY.
"""

import os
import threading
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core import bz_core_numbers, kcore_decompose
from repro.graph import generators as gen
from repro.streaming import (ConcurrentKCoreServer, KCoreServer, Request,
                             StreamingConfig)
from repro.temporal import WindowedKCoreEngine, temporal_snap_analogue

TARGET_N = int(os.environ.get("REPRO_SERVING_BENCH_N", "5000"))
TICKS = int(os.environ.get("REPRO_SERVING_BENCH_TICKS", "8"))
READERS = int(os.environ.get("REPRO_SERVING_BENCH_READERS", "4"))
FRONTIER = os.environ.get("REPRO_SERVING_BENCH_FRONTIER", "fused")
VERIFY_EVERY = int(os.environ.get("REPRO_SERVING_BENCH_VERIFY_EVERY", "4"))

TRACE = "EEN"                 # temporal SNAP analogue driving the writes
WINDOW_STRIDES = 3            # window size in strides (count-based)
SNAP_REMOVE_FRAC = 0.15
IDS_PER_READ = 32             # vertex ids sampled per point read
# p99 read latency must stay under the batch re-convergence wall — that is
# what "reads proceed during re-convergence" means. The floor absorbs CI
# jitter on runs whose update walls are only a few ms.
P99_WALL_FLOOR_S = 0.05

COLUMNS = ("tick", "t_hi", "m", "inserted", "deleted", "messages",
           "scratch_messages", "ratio", "rounds", "mode", "update_ms",
           "version", "reads_done", "bz_checked")

# run-level reader aggregates (latency percentiles need the raw samples,
# which don't belong in per-tick records); filled by run_records() and
# joined into summarize() output in the same process
_READ_STATS: dict = {}


def settings() -> dict:
    return {"target_n": TARGET_N, "ticks": TICKS, "readers": READERS,
            "frontier": FRONTIER, "verify_every": VERIFY_EVERY,
            "trace": TRACE, "window_strides": WINDOW_STRIDES,
            "snap_remove_frac": SNAP_REMOVE_FRAC,
            "ids_per_read": IDS_PER_READ}


def _build() -> tuple[WindowedKCoreEngine, ConcurrentKCoreServer]:
    entry = gen.SNAP_BY_ABBREV[TRACE]
    log = temporal_snap_analogue(TRACE, scale=TARGET_N / entry.n, seed=0,
                                 remove_frac=SNAP_REMOVE_FRAC)
    stride = max(len(log) // (TICKS + 2), 1)
    weng = WindowedKCoreEngine(log, WINDOW_STRIDES * stride, stride,
                               by="count",
                               config=StreamingConfig(frontier=FRONTIER))
    server = KCoreServer(windowed=weng, asof_capacity=TICKS + 2)
    front = ConcurrentKCoreServer(server, read_workers=READERS)
    return weng, front


def _reader(front: ConcurrentKCoreServer, seed: int, stop: threading.Event,
            busy: threading.Event, out: dict) -> None:
    """One reader: sampled reads against the published snapshot until
    stopped, recording (latency, version, during-write) plus everything
    needed to verify each response against the fixpoint registry."""
    rng = np.random.default_rng(seed)
    n = front.server.engine.n
    walls, ages, responses = [], [], []
    during_write = 0
    while not stop.is_set():
        p = rng.random()
        v = rng.integers(0, n, size=IDS_PER_READ)
        snap = front.snapshot
        if p < 0.55:
            req = Request(op="core", vertices=v)
        elif p < 0.75:
            req = Request(op="in_kcore", vertices=v,
                          k=max(snap.max_k - 1, 1))
        elif p < 0.9 and len(snap.asof):
            t = float(rng.choice(snap.asof.times))
            req = Request(op="core_asof", t=t, vertices=v)
        else:
            req = Request(op="members", k=max(snap.max_k, 1))
        resp = front.read(req)
        if busy.is_set():
            during_write += 1
        walls.append(resp.wall_s)
        ages.append(front.snapshot_age_s())
        responses.append((req, resp))
    out["walls"] = walls
    out["ages"] = ages
    out["during_write"] = during_write
    out["responses"] = responses


def _verify_responses(responses, registry) -> int:
    """Read-consistency assertion: every successful response must be
    bit-equal to a recomputation from the REGISTERED fixpoint of the
    version it reports (registry cores are BZ-verified at checkpoints).
    Returns the number of responses checked."""
    checked = 0
    for req, resp in responses:
        if not resp.ok:
            # only core_asof may fail here (a boundary aged out of the
            # ring between sampling and reading); anything else is a bug
            assert req.op == "core_asof", (req.op, resp.error)
            continue
        assert resp.version in registry, \
            f"read answered from unregistered snapshot v{resp.version}"
        snap = registry[resp.version]
        if req.op == "core":
            expect = snap.core[np.asarray(req.vertices)]
            assert (resp.payload == expect).all(), "torn core read"
        elif req.op == "in_kcore":
            expect = snap.core[np.asarray(req.vertices)] >= req.k
            assert (resp.payload == expect).all(), "torn in_kcore read"
        elif req.op == "members":
            expect = np.flatnonzero(snap.core >= req.k)
            assert (resp.payload == expect).all(), "torn members read"
        else:                                     # core_asof
            bt, core = snap.asof.asof(req.t)
            expect = core[np.asarray(req.vertices)]
            assert resp.payload[0] == bt, "as-of boundary mismatch"
            assert (resp.payload[1] == expect).all(), "torn as-of read"
        checked += 1
    return checked


def run_records() -> list[dict]:
    """The mixed run: writer replays the trace, readers hammer snapshots.

    Per-tick records carry the deterministic write-path signal (message
    bills, ratios — identical with or without readers); run-level reader
    aggregates land in _READ_STATS for summarize()."""
    weng, front = _build()
    registry = {front.snapshot.version: front.snapshot}

    stop, busy = threading.Event(), threading.Event()
    outs = [{} for _ in range(READERS)]
    threads = [threading.Thread(target=_reader,
                                args=(front, 1000 + i, stop, busy, outs[i]),
                                name=f"bench-reader-{i}", daemon=True)
               for i in range(READERS)]
    for th in threads:
        th.start()

    records = []
    reads_before = 0
    write_wall = 0.0
    tick = 0
    try:
        while not weng.done and tick < TICKS:
            t0 = time.perf_counter()
            busy.set()
            ws = front.advance_window()
            busy.clear()
            wall = time.perf_counter() - t0
            write_wall += wall
            snap = front.snapshot
            registry[snap.version] = snap

            res = ws.result
            scratch = kcore_decompose(weng.window_graph())
            scratch_msgs = int(scratch.stats.total_messages)
            bz_checked = False
            if tick % VERIFY_EVERY == 0:
                ref = bz_core_numbers(weng.window_graph())
                assert (snap.core == ref).all(), \
                    f"published snapshot v{snap.version} is not the BZ " \
                    f"fixpoint of the window graph at tick {tick}"
                bz_checked = True

            reads_now = int(front.stats()["reads_total"])
            records.append({
                "tick": tick, "t_hi": round(ws.t_hi, 3), "m": ws.m,
                "inserted": int(res.delta.inserted.shape[0]),
                "deleted": int(res.delta.deleted.shape[0]),
                "messages": int(res.total_messages),
                "scratch_messages": scratch_msgs,
                "ratio": round(res.total_messages / max(scratch_msgs, 1),
                               4),
                "rounds": int(res.rounds), "mode": res.mode,
                "update_ms": round(1e3 * wall, 2),
                "version": snap.version,
                "reads_done": reads_now - reads_before,
                "bz_checked": bz_checked,
            })
            reads_before = reads_now
            tick += 1
    finally:
        stop.set()
        for th in threads:
            th.join(timeout=30)

    walls = np.concatenate([np.asarray(o["walls"], float)
                            for o in outs if o.get("walls")] or
                           [np.zeros(0)])
    ages = np.concatenate([np.asarray(o["ages"], float)
                           for o in outs if o.get("ages")] or
                          [np.zeros(0)])
    during = int(sum(o.get("during_write", 0) for o in outs))
    checked = sum(_verify_responses(o.get("responses", ()), registry)
                  for o in outs)
    assert checked > 0, "no reads were consistency-checked"

    mean_update_s = write_wall / max(tick, 1)
    p99_s = float(np.percentile(walls, 99)) if walls.size else 0.0
    # the acceptance bar: reads keep flowing while the writer re-converges,
    # at latencies far below the batch wall they would otherwise sit behind
    assert p99_s < max(mean_update_s, P99_WALL_FLOOR_S), (
        f"p99 read latency {p99_s:.4f}s is not below the re-convergence "
        f"wall {mean_update_s:.4f}s — reads are not proceeding "
        "during re-convergence")
    _READ_STATS.clear()
    _READ_STATS.update({
        "reads_total": int(walls.size),
        "reads_checked": int(checked),
        "reads_during_reconvergence": during,
        "read_p50_ms": round(1e3 * float(np.percentile(walls, 50)), 4)
        if walls.size else 0.0,
        "read_p99_ms": round(1e3 * p99_s, 4),
        "stale_ms_max": round(1e3 * float(ages.max()), 2)
        if ages.size else 0.0,
        "updates_per_s": round(tick / max(write_wall, 1e-9), 2),
        "mean_update_ms": round(1e3 * mean_update_s, 2),
        "snapshot_flips": int(front.box.flips),
    })
    return records


def summarize(records: list[dict]) -> dict:
    """One gated key ('mixed'): the write path's mean message ratio under
    read load, plus the run's serving telemetry (informational)."""
    out = {"mixed": {
        "mean_ratio": round(float(np.mean([r["ratio"] for r in records])),
                            4),
        "mean_messages": round(float(np.mean([r["messages"]
                                              for r in records])), 1),
        "mean_update_ms": round(float(np.mean([r["update_ms"]
                                               for r in records])), 2),
        "bz_checks": int(np.sum([r["bz_checked"] for r in records])),
    }}
    out["mixed"].update(_READ_STATS)
    return out


def run() -> list[str]:
    records = run_records()
    rows = [csv_row(*COLUMNS)]
    rows.extend(csv_row(*(r[c] for c in COLUMNS)) for r in records)
    for key, s in summarize(records).items():
        rows.append(f"# {key}: " + " ".join(f"{k}={v}"
                                            for k, v in s.items()))
    return rows


def main() -> None:
    for row in run():
        print(row, flush=True)


if __name__ == "__main__":
    main()
