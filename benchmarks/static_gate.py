"""CI perf gate over the static decomposition benchmark (ISSUE 5).

Runs benchmarks.static_decomposition — the paper's from-scratch experiment
in host-loop AND fused modes, asserting bit-equal per-round message bills
between the modes and BZ-exact cores — writes the full structured output to
a JSON artifact (BENCH_static.json), and fails if any per-graph
messages-over-work-bound ratio regresses past the threshold against the
committed baseline (benchmarks/static_baseline.json). Message counts are
integer-deterministic for the seeded analogues, so CI runs this gate tight
(an exactness lock on the paper's measurement set); the fused wall and
recompile telemetry ride along as info keys. Gate semantics (thresholds,
baseline settings match, --write-baseline) live in benchmarks.gate_common,
shared with the streaming and temporal gates.

    # CI (smoke settings; the workflow uses the default scale):
    python -m benchmarks.static_gate --require-match --max-regression 1.02

    # refresh the committed baseline after an intended change:
    python -m benchmarks.static_gate --write-baseline
"""

import pathlib
import sys

from benchmarks.gate_common import gate_main
from benchmarks.static_decomposition import run_records, settings, summarize

BASELINE = pathlib.Path(__file__).parent / "static_baseline.json"


def main() -> int:
    return gate_main(
        run_records=run_records,
        settings=settings,
        summarize=summarize,
        baseline=BASELINE,
        default_out="BENCH_static.json",
        label="static",
    )


if __name__ == "__main__":
    sys.exit(main())
