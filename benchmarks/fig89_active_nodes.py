"""Paper Figs 8-9: number of Active nodes per interval — monotone-ish decay
whose rate tracks the core-number distribution."""

from benchmarks.common import csv_row, decompose

GRAPHS = ("FC", "EEN", "G31", "CA", "WG", "S0811")


def run() -> list[str]:
    rows = [csv_row("graph", "round", "active_nodes")]
    for g in GRAPHS:
        res, _ = decompose(g)
        for r, a in enumerate(res.stats.active_per_round):
            rows.append(csv_row(g, r, int(a)))
        # claim: all nodes eventually inactive (termination)
        rows.append(csv_row(f"# {g}_terminated", res.converged, ""))
    return rows
