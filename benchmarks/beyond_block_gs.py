"""Beyond-paper: block-Gauss-Seidel vs the paper-faithful Jacobi schedule —
message and round reduction per graph (the §Perf-kcore hillclimb axis)."""

from repro.core import KCoreConfig

from benchmarks.common import csv_row, decompose

GRAPHS = ("FC", "EEN", "G31", "CA", "WG", "S0811", "PTBR", "MGF")


def run() -> list[str]:
    rows = [csv_row("graph", "jacobi_msgs", "gs_msgs", "msg_reduction",
                    "jacobi_rounds", "gs_rounds")]
    for g in GRAPHS:
        jac, _ = decompose(g)
        gs, _ = decompose(g, KCoreConfig(mode="block_gs", n_blocks=16))
        rows.append(csv_row(
            g, jac.stats.total_messages, gs.stats.total_messages,
            round(1 - gs.stats.total_messages /
                  max(jac.stats.total_messages, 1), 3),
            jac.rounds, gs.rounds))
    return rows
