"""Shared benchmark machinery.

The paper's 14 SNAP graphs are reproduced as synthetic analogues at
``SCALE`` of their original size (no network access in this container —
graph/generators.py matches n, m and the degree law per graph; Table-I
stats of the originals are reported side-by-side). The default scale keeps
the full suite a few CPU-minutes; crank it with REPRO_BENCH_SCALE=1.0 on a
bigger machine.
"""

from __future__ import annotations

import os
import time

from repro.core import KCoreConfig, kcore_decompose
from repro.graph import generators as gen

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.05"))
# Graphs small enough to run at every scale; the multi-million-vertex ones
# are clamped so CPU bench time stays bounded.
_CLAMP = {
    "SPR": 0.02,
    "LJ1": 0.01,
    "CLJ": 0.01,
    "WS": 0.05,
    "WG": 0.05,
    "A0505": 0.05,
    "CA": 0.05,
    "EEU": 0.05,
}

_cache: dict = {}


def graph_for(abbrev: str):
    if abbrev not in _cache:
        scale = min(SCALE, _CLAMP.get(abbrev, SCALE))
        _cache[abbrev] = gen.snap_analogue(abbrev, scale=scale, seed=0)
    return _cache[abbrev]


def decompose(abbrev: str, config: KCoreConfig | None = None, fused: bool = False):
    """Cached (result, wall_s) of one decomposition — ``fused=True`` routes
    the round loop through the shared fused runtime (same accounting)."""
    key = (abbrev, config, fused)
    if key not in _cache:
        g = graph_for(abbrev)
        t0 = time.perf_counter()
        res = kcore_decompose(g, config or KCoreConfig(), fused=fused)
        wall = time.perf_counter() - t0
        _cache[key] = (res, wall)
    return _cache[key]


def csv_row(*cols) -> str:
    return ",".join(str(c) for c in cols)
