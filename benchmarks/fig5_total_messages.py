"""Paper Fig 5: total passed messages per graph — larger graphs need more
messages; totals bounded by the §II.B work bound W."""

from repro.core import work_bound
from repro.graph.generators import SNAP_TABLE

from benchmarks.common import csv_row, decompose, graph_for


def run() -> list[str]:
    rows = [csv_row("graph", "n", "arcs", "total_messages", "work_bound",
                    "messages_over_bound", "rounds", "fused_equal")]
    for e in SNAP_TABLE:
        g = graph_for(e.abbrev)
        res, _ = decompose(e.abbrev)
        # the fused runtime must bill the identical per-round messages —
        # the paper's headline number may not depend on execution mode.
        # Reported as a column (not asserted) so a divergence shows up as
        # False in the CSV; the static gate is the hard CI lock.
        fres, _ = decompose(e.abbrev, fused=True)
        mpr = res.stats.messages_per_round
        fmpr = fres.stats.messages_per_round
        fused_equal = bool(mpr.shape == fmpr.shape and (mpr == fmpr).all()
                           and (res.core == fres.core).all())
        wb = work_bound(g, res.core)
        rows.append(csv_row(
            e.abbrev, g.n, g.num_arcs, res.stats.total_messages, wb,
            round(res.stats.total_messages / max(wb, 1), 3), res.rounds,
            fused_equal))
    return rows
