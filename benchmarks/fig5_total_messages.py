"""Paper Fig 5: total passed messages per graph — larger graphs need more
messages; totals bounded by the §II.B work bound W."""

from repro.core import work_bound
from repro.graph.generators import SNAP_TABLE

from benchmarks.common import csv_row, decompose, graph_for


def run() -> list[str]:
    rows = [csv_row("graph", "n", "arcs", "total_messages", "work_bound",
                    "messages_over_bound", "rounds")]
    for e in SNAP_TABLE:
        g = graph_for(e.abbrev)
        res, _ = decompose(e.abbrev)
        wb = work_bound(g, res.core)
        rows.append(csv_row(
            e.abbrev, g.n, g.num_arcs, res.stats.total_messages, wb,
            round(res.stats.total_messages / max(wb, 1), 3), res.rounds))
    return rows
