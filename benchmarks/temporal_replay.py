"""Temporal replay benchmark: windowed maintenance over timestamped streams.

Replays sliding windows over temporal traces (repro.temporal) through the
incremental engine and reports, per window advance: the message bill vs a
from-scratch decomposition of the same window graph, re-convergence
rounds, CSR patch health (compactions / fragmentation / slack occupancy),
and host-side wall cost: ``patch_ms`` (CSR patching), ``step_ms`` (the
whole advance), ``ms_per_round`` = step_ms / rounds — an UPPER BOUND on
per-round overhead (it also amortizes the window edge-set diff and the
patch over the rounds) — and ``recompiles``, the fresh XLA compilations
each step caused (repro.core.jit_telemetry), which makes the fused path's
shape-stability claim measurable: over a whole replay the recompile total
must stay O(log), not O(steps). The replay runs the ``fused`` frontier
(one device-resident while_loop per advance — override with
REPRO_TEMPORAL_BENCH_FRONTIER to compare modes); message bills are
mode-invariant, so the gated ratios are comparable across frontiers.
Every step is BZ-oracle verified, so the ratio column is only meaningful
because the windowed cores are exact.

Traces (>= 3 regimes):

  * ``EEN``/``FC`` — temporal SNAP analogues: growth-ordered arrivals with
    heavy-tailed inter-arrival times and 15% link-decay removals;
  * ``ba`` — timestamped preferential attachment with removals;
  * ``contact`` — contact-network bursts (add/remove churn dominated,
    recurring re-insertion).

``benchmarks.temporal_gate`` turns the per-trace mean ratios into a CI
regression gate against ``benchmarks/temporal_baseline.json`` and writes
the full structured output as ``BENCH_temporal.json``.

Environment knobs (for CI smoke):
  REPRO_TEMPORAL_BENCH_N        target vertex count       (default 10000)
  REPRO_TEMPORAL_BENCH_STEPS    window advances per trace (default 8)
  REPRO_TEMPORAL_BENCH_FRONTIER engine frontier mode      (default fused)
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import csv_row
from repro.core import kcore_decompose
from repro.graph import generators as gen
from repro.streaming import StreamingConfig
from repro.temporal import (contact_bursts, replay,
                            temporal_barabasi_albert,
                            temporal_snap_analogue)

TARGET_N = int(os.environ.get("REPRO_TEMPORAL_BENCH_N", "10000"))
STEPS = int(os.environ.get("REPRO_TEMPORAL_BENCH_STEPS", "8"))
FRONTIER = os.environ.get("REPRO_TEMPORAL_BENCH_FRONTIER", "fused")

# Trace geometry — recorded in settings() so the gate's --require-match
# catches workload edits, not just env-knob changes (a changed workload
# must ship a regenerated baseline).
TRACE_NAMES = ("EEN", "FC", "ba", "contact")
WINDOW_STRIDES = 3            # window size in strides
SNAP_REMOVE_FRAC = 0.15       # link-decay removals in the SNAP analogues
BA_REMOVE_FRAC = 0.1

COLUMNS = ("trace", "n", "events", "window", "stride", "step", "m",
           "inserted", "deleted", "messages", "scratch_messages", "ratio",
           "rounds", "frontier_peak", "mode", "patch_ms", "seed_ms",
           "converge_ms", "reconstruct_ms", "step_ms", "ms_per_round",
           "heartbeats", "recompiles", "compactions", "dead_frac",
           "occupancy", "core_max", "oracle_ok", "flight_rounds",
           "health_ok")


def traces() -> list[tuple[str, object, float, float, str]]:
    """(name, log, window, stride, by) per trace — sized off TARGET_N/STEPS
    so every trace yields ~STEPS window advances with sliding (not only
    growing) windows. ``by`` travels with the trace because window/stride
    are in by-dependent units (events vs time spans)."""
    out = []
    for abbrev in ("EEN", "FC"):
        entry = gen.SNAP_BY_ABBREV[abbrev]
        log = temporal_snap_analogue(abbrev, scale=TARGET_N / entry.n,
                                     seed=0,
                                     remove_frac=SNAP_REMOVE_FRAC)
        stride = max(len(log) // (STEPS + 2), 1)
        out.append((abbrev, log, WINDOW_STRIDES * stride, stride, "count"))
    blog = temporal_barabasi_albert(TARGET_N, 3, seed=0,
                                    remove_frac=BA_REMOVE_FRAC)
    stride = max(len(blog) // (STEPS + 2), 1)
    out.append(("ba", blog, WINDOW_STRIDES * stride, stride, "count"))
    clog = contact_bursts(max(TARGET_N // 10, 20),
                          n_bursts=4 * STEPS, seed=0)
    span = clog.t_max - clog.t_min
    stride = max(span / (STEPS + 2), 1e-9)
    out.append(("contact", clog, WINDOW_STRIDES * stride, stride, "time"))
    return out


def settings() -> dict:
    return {"target_n": TARGET_N, "steps": STEPS, "frontier": FRONTIER,
            "traces": list(TRACE_NAMES),
            "window_strides": WINDOW_STRIDES,
            "snap_remove_frac": SNAP_REMOVE_FRAC,
            "ba_remove_frac": BA_REMOVE_FRAC}


def run_records() -> list[dict]:
    """Structured per-step records (CSV in run(), JSON in temporal_gate)."""
    records = []
    for name, log, window, stride, by in traces():
        traj = replay(log, window, stride, by=by, oracle_every=1,
                      config=StreamingConfig(frontier=FRONTIER),
                      max_steps=STEPS)
        # from-scratch message bill of each window graph, for the ratio
        for rec in traj.records:
            wg = log.graph_between(rec.lo, rec.hi)
            scratch = kcore_decompose(wg)
            scratch_msgs = int(scratch.stats.total_messages)
            records.append({
                "trace": name, "n": log.n, "events": len(log),
                "window": round(float(window), 3),
                "stride": round(float(stride), 3),
                "step": rec.step, "m": rec.m,
                "inserted": rec.inserted, "deleted": rec.deleted,
                "messages": rec.messages,
                "scratch_messages": scratch_msgs,
                "ratio": round(rec.messages / max(scratch_msgs, 1), 4),
                "rounds": rec.rounds, "frontier_peak": rec.frontier_peak,
                "mode": rec.mode, "patch_ms": rec.patch_ms,
                # per-phase breakdown of each advance (engine-measured,
                # same boundaries as the trace spans)
                "seed_ms": rec.seed_ms,
                "converge_ms": rec.converge_ms,
                "reconstruct_ms": rec.reconstruct_ms,
                "step_ms": rec.step_ms,
                "ms_per_round": round(rec.step_ms / max(rec.rounds, 1), 3),
                # modeled termination-detection bill (§III.C) per advance
                "heartbeats": rec.heartbeats,
                "recompiles": rec.recompiles,
                "compactions": rec.csr_compactions,
                "dead_frac": rec.csr_dead_frac,
                "occupancy": rec.csr_occupancy,
                "core_max": rec.core_max,
                "oracle_ok": bool(rec.oracle_ok),
                # flight-recorder join (zeros/"" unless recording is on)
                "flight_rounds": rec.flight_rounds,
                "health_ok": "" if rec.health_ok is None else rec.health_ok,
            })
    return records


def summarize(records: list[dict]) -> dict:
    """Per-trace means — the gated signal plus host-overhead telemetry."""
    out: dict = {}
    for r in records:
        out.setdefault(r["trace"], []).append(r)
    return {trace: {
        "mean_ratio": round(float(np.mean([r["ratio"] for r in rs])), 4),
        "mean_messages": round(float(np.mean([r["messages"]
                                              for r in rs])), 1),
        "mean_patch_ms": round(float(np.mean([r["patch_ms"]
                                              for r in rs])), 3),
        "mean_seed_ms": round(float(np.mean([r["seed_ms"] for r in rs])), 3),
        "mean_converge_ms": round(float(np.mean([r["converge_ms"]
                                                 for r in rs])), 3),
        "mean_ms_per_round": round(float(np.mean([r["ms_per_round"]
                                                  for r in rs])), 3),
        "total_heartbeats": int(np.sum([r["heartbeats"] for r in rs])),
        "recompiles": int(np.sum([r["recompiles"] for r in rs])),
        "compactions": int(rs[-1]["compactions"]),
    } for trace, rs in out.items()}


def flight_overhead() -> dict:
    """Measured flight-recorder cost on the fused EEN replay.

    Three replays of the same trace: a warmup (pays the XLA compiles,
    discarded), recorder OFF, recorder ON (+ invariant monitor). The
    overhead is the ON/OFF delta of the summed step walls — the ISSUE 8
    acceptance budget is <= 3% on the 10k-vertex fused EEN replay."""
    from repro.obs import flight, health

    name, log, window, stride, by = traces()[0]   # EEN

    def one_replay() -> float:
        traj = replay(log, window, stride, by=by,
                      config=StreamingConfig(frontier=FRONTIER),
                      max_steps=STEPS)
        return float(traj.series("step_ms").sum())

    one_replay()                      # warmup
    off_ms = one_replay()
    flight.enable()
    health.install()
    try:
        on_ms = one_replay()
        rounds = flight.get_recorder().rounds_recorded
        status = health.verdict()["status"]
    finally:
        flight.disable()
        flight.reset()
        health.reset()
    overhead = 100.0 * (on_ms - off_ms) / max(off_ms, 1e-9)
    return {"trace": name, "off_ms": round(off_ms, 1),
            "on_ms": round(on_ms, 1), "overhead_pct": round(overhead, 2),
            "flight_rounds": rounds, "health": status}


def run() -> list[str]:
    records = run_records()
    rows = [csv_row(*COLUMNS)]
    rows.extend(csv_row(*(r[c] for c in COLUMNS)) for r in records)
    for trace, s in summarize(records).items():
        mean = {c: "" for c in COLUMNS}
        mean.update(trace=trace, step="mean", ratio=s["mean_ratio"],
                    messages=s["mean_messages"],
                    patch_ms=s["mean_patch_ms"],
                    ms_per_round=s["mean_ms_per_round"],
                    recompiles=s["recompiles"],
                    compactions=s["compactions"])
        rows.append(csv_row(*(mean[c] for c in COLUMNS)))
    fo = flight_overhead()
    rows.append("# flight_overhead "
                + " ".join(f"{k}={v}" for k, v in fo.items()))
    return rows
