"""Scale benchmark: the million-vertex Table I on bounded device memory.

The paper's Table-I graphs top out in the millions of vertices; the
in-memory modes materialize the full arc arrays on device, so the largest
decomposable graph is capped by device memory. This benchmark runs the
out-of-core block-cycling driver (``repro.core.outofcore``) over a SNAP
analogue at 10^6-vertex scale under a FORCED byte budget and reports the
memory story next to the convergence story:

  * ``device_block_bytes`` — the arc bytes of ONE padded block, i.e. the
    device-resident peak of the block-cycling driver;
  * ``total_arc_bytes``    — the full arc arrays an in-memory mode would
    have to materialize (``device_frac`` is the ratio: the headline claim
    is device_frac << 1 at million-vertex scale);
  * ``peak_rss_mb``        — host-side peak RSS (the O(n) vertex state plus
    the LRU block cache, itself capped by ``mem_budget``);
  * ``blocks_loaded`` / ``blocks_skipped`` / ``evictions`` — the I/O bill:
    frontier-masked block skipping plus LRU cycling under the budget;
  * ``imbalance``          — max/mean live arcs per block (straggler
    factor of the uniform-V partition, satellite of balance_report).

At verification scale (``n <= REPRO_SCALE_VERIFY_MAX``, or always when
``REPRO_SCALE_VERIFY=1``) the run additionally asserts the out-of-core
cores BZ-exact and the per-round message/active/changed bills bit-equal to
the in-memory fused runtime — the same exactness lock the static gate
holds, extended to the spill-to-disk tier.

``python -m benchmarks.scale_decomposition`` writes ``BENCH_scale.json``
(the committed artifact carries the 10^6-vertex headline run) and enforces
``device_block_bytes < total_arc_bytes`` plus an optional eviction floor
(CI's smoke lane forces a tiny budget and requires the cache actually
cycled). Environment knobs:

  REPRO_SCALE_GRAPH       Table-I abbrev for the analogue  (default LJ1)
  REPRO_SCALE_VERTICES    comma-separated vertex targets   (default 1000000)
  REPRO_SCALE_MEM_BUDGET  LRU cache budget in bytes        (default 64 MiB)
  REPRO_SCALE_VERIFY      1 = always, 0 = never, auto = n <= VERIFY_MAX
  REPRO_SCALE_VERIFY_MAX  auto-verify size cutoff          (default 200000)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks.common import csv_row
from benchmarks.gate_common import write_job_summary
from repro.core.bz import bz_core_numbers
from repro.core.kcore import kcore_decompose
from repro.core.outofcore import outofcore_decompose
from repro.graph import generators as gen

GRAPH = os.environ.get("REPRO_SCALE_GRAPH", "LJ1")
VERTICES = tuple(
    int(v) for v in os.environ.get("REPRO_SCALE_VERTICES", "1000000").split(",")
)
MEM_BUDGET = int(os.environ.get("REPRO_SCALE_MEM_BUDGET", str(64 << 20)))
VERIFY = os.environ.get("REPRO_SCALE_VERIFY", "auto")
VERIFY_MAX = int(os.environ.get("REPRO_SCALE_VERIFY_MAX", "200000"))

COLUMNS = (
    "graph",
    "vertices",
    "edges",
    "n_blocks",
    "mem_budget",
    "device_block_bytes",
    "total_arc_bytes",
    "device_frac",
    "blocks_loaded",
    "blocks_skipped",
    "skip_rate",
    "cache_hits",
    "evictions",
    "cache_peak_bytes",
    "peak_rss_mb",
    "imbalance",
    "rounds",
    "max_core",
    "total_messages",
    "ms_per_round",
    "wall_s",
    "verified",
)


def settings() -> dict:
    return {
        "graph": GRAPH,
        "vertices": list(VERTICES),
        "mem_budget": MEM_BUDGET,
        "verify": VERIFY,
    }


def _should_verify(n: int) -> bool:
    if VERIFY == "1":
        return True
    if VERIFY == "0":
        return False
    return n <= VERIFY_MAX


def _verify(g, res) -> bool:
    """BZ-exact cores AND bit-equal bills vs the in-memory fused runtime."""
    fused = kcore_decompose(g, fused=True)
    ok = bool(
        (res.core == fused.core).all()
        and (res.stats.messages_per_round == fused.stats.messages_per_round).all()
        and (res.stats.active_per_round == fused.stats.active_per_round).all()
        and (res.stats.changed_per_round == fused.stats.changed_per_round).all()
        and res.rounds == fused.rounds
        and (res.core == bz_core_numbers(g)).all()
    )
    assert ok, "out-of-core run diverged from the in-memory fused runtime"
    return ok


def run_records() -> list[dict]:
    records = []
    entry = gen.SNAP_BY_ABBREV[GRAPH]
    for target in VERTICES:
        g = gen.snap_analogue(GRAPH, scale=target / entry.n, seed=0)
        t0 = time.perf_counter()
        res = outofcore_decompose(g, mem_budget=MEM_BUDGET)
        wall = time.perf_counter() - t0
        bs = res.block_stats
        assert bs is not None and res.converged
        verified = _verify(g, res) if _should_verify(g.n) else False
        records.append(
            {
                "graph": GRAPH,
                "vertices": g.n,
                "edges": g.m,
                "n_blocks": bs.n_blocks,
                "mem_budget": bs.mem_budget,
                "device_block_bytes": bs.device_block_bytes,
                "total_arc_bytes": bs.total_arc_bytes,
                "device_frac": round(bs.device_block_bytes / max(bs.total_arc_bytes, 1), 4),
                "blocks_loaded": bs.blocks_loaded,
                "blocks_skipped": bs.blocks_skipped,
                "skip_rate": round(bs.skip_rate, 4),
                "cache_hits": bs.cache_hits,
                "evictions": bs.evictions,
                "cache_peak_bytes": bs.cache_peak_bytes,
                "peak_rss_mb": round(bs.peak_rss_bytes / (1 << 20), 1),
                "imbalance": round(bs.imbalance, 3),
                "rounds": res.rounds,
                "max_core": int(res.core.max()) if g.n else 0,
                "total_messages": int(res.stats.total_messages),
                "ms_per_round": round(bs.ms_per_round, 2),
                "wall_s": round(wall, 2),
                "verified": verified,
            }
        )
    return records


def run() -> list[str]:
    records = run_records()
    rows = [csv_row(*COLUMNS)]
    rows.extend(csv_row(*(r[c] for c in COLUMNS)) for r in records)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_scale.json")
    ap.add_argument(
        "--min-evictions",
        type=int,
        default=0,
        metavar="N",
        help="fail unless every run evicted at least N blocks (CI smoke "
        "passes 1 with a tiny budget to prove the cache actually cycled)",
    )
    args = ap.parse_args()
    records = run_records()
    payload = {"settings": settings(), "records": records}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} ({len(records)} records)")
    table = [
        "### `scale` (out-of-core) smoke",
        "",
        "| graph | n | device/total bytes | rounds | evictions | verified |",
        "|---|---|---|---|---|---|",
    ]
    for r in records:
        table.append(
            f"| {r['graph']} | {r['vertices']} | "
            f"{r['device_block_bytes']:,} / {r['total_arc_bytes']:,} "
            f"({r['device_frac']:.1%}) | {r['rounds']} | "
            f"{r['evictions']} | {r['verified']} |"
        )
    write_job_summary(table)
    for r in records:
        print(
            f"{r['graph']} n={r['vertices']} m={r['edges']}: "
            f"device {r['device_block_bytes']:,}B of {r['total_arc_bytes']:,}B "
            f"({r['device_frac']:.1%}), {r['rounds']} rounds @ "
            f"{r['ms_per_round']}ms, evictions={r['evictions']} "
            f"skip_rate={r['skip_rate']:.1%} verified={r['verified']}"
        )
        if r["device_block_bytes"] >= r["total_arc_bytes"]:
            print("FAIL: device block bytes not below total arc bytes")
            return 1
        if r["evictions"] < args.min_evictions:
            print(f"FAIL: {r['evictions']} evictions < floor {args.min_evictions}")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
