"""Benchmark harness — one module per paper table/figure (+ the beyond-paper
and roofline reports). Prints CSV blocks per benchmark.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table1 fig5
"""

from __future__ import annotations

import sys
import time

BENCHES = {
    "table1": "benchmarks.table1_graphs",
    "fig4": "benchmarks.fig4_core_distribution",
    "fig5": "benchmarks.fig5_total_messages",
    "fig67": "benchmarks.fig67_messages_over_time",
    "fig89": "benchmarks.fig89_active_nodes",
    "fig10": "benchmarks.fig10_runtime",
    "beyond_gs": "benchmarks.beyond_block_gs",
    "roofline": "benchmarks.roofline",
    "streaming": "benchmarks.streaming_maintenance",
    "temporal": "benchmarks.temporal_replay",
}


def main() -> None:
    import importlib
    names = sys.argv[1:] or list(BENCHES)
    for name in names:
        mod = importlib.import_module(BENCHES[name])
        t0 = time.perf_counter()
        rows = mod.run()
        dt = time.perf_counter() - t0
        print(f"\n===== {name} ({BENCHES[name]}) [{dt:.1f}s] =====")
        for r in rows:
            print(r)


if __name__ == "__main__":
    main()
