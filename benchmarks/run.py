"""Benchmark harness — one module per paper table/figure (+ the beyond-paper
and roofline reports). Prints CSV blocks per benchmark.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table1 fig5

Wall numbers in a single run mix first-compile cost into the timings
(``ms_per_round`` in the streaming/temporal benchmarks most of all).
``--repeat N`` runs each benchmark N times in-process: run 1 is the
warmup that pays the jit compiles, the reported rows come from the LAST
run (steady state, caches hot), and a ``# wall`` footer separates the
warmup wall time from the mean steady-state wall time so compile cost is
visible instead of smeared into the means.

    PYTHONPATH=src python -m benchmarks.run --repeat 3 temporal
"""

from __future__ import annotations

import argparse
import time

BENCHES = {
    "table1": "benchmarks.table1_graphs",
    "fig4": "benchmarks.fig4_core_distribution",
    "fig5": "benchmarks.fig5_total_messages",
    "fig67": "benchmarks.fig67_messages_over_time",
    "fig89": "benchmarks.fig89_active_nodes",
    "fig10": "benchmarks.fig10_runtime",
    "beyond_gs": "benchmarks.beyond_block_gs",
    "roofline": "benchmarks.roofline",
    "streaming": "benchmarks.streaming_maintenance",
    "temporal": "benchmarks.temporal_replay",
    "serving": "benchmarks.serving_mixed",
    "static": "benchmarks.static_decomposition",
    "scale": "benchmarks.scale_decomposition",
}


def main() -> None:
    import importlib

    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*", metavar="BENCH",
                    help=f"benchmarks to run (default: all): "
                         f"{' '.join(BENCHES)}")
    ap.add_argument("--repeat", type=int, default=1, metavar="N",
                    help="run each benchmark N times; report the last "
                         "(steady-state) run, print warmup wall separately")
    args = ap.parse_args()
    unknown = [n for n in args.names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; pick from {list(BENCHES)}")
    names = args.names or list(BENCHES)
    repeat = max(args.repeat, 1)

    for name in names:
        mod = importlib.import_module(BENCHES[name])
        walls = []
        for _ in range(repeat):
            t0 = time.perf_counter()
            rows = mod.run()
            walls.append(time.perf_counter() - t0)
        print(f"\n===== {name} ({BENCHES[name]}) [{walls[-1]:.1f}s] =====")
        for r in rows:
            print(r)
        if repeat > 1:
            steady = sum(walls[1:]) / len(walls[1:])
            print(f"# wall: warmup={walls[0]:.1f}s "
                  f"steady_mean={steady:.1f}s over {repeat - 1} repeats")


if __name__ == "__main__":
    main()
