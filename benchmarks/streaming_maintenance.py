"""Streaming maintenance benchmark: incremental vs from-scratch message bill.

For each graph (10k-vertex SNAP analogues by default) and churn rate, applies
a sequence of random edge-churn batches through the incremental engine and
compares its per-batch message bill against a full from-scratch
re-decomposition of the same post-batch graph. Every batch is verified
against the BZ oracle — the ratio column is only meaningful because the
incremental answer is exact.

Beyond the message ratio the table tracks the PR-2 maintenance stack:

  * ``patch_ms`` vs ``rebuild_ms`` — in-place CSR patching against the old
    O(m log m) sorted-COO rebuild of the same batch;
  * ``sharded_ok`` — a second engine running the identical batch stream in
    the ``sharded`` (shard_map mesh) frontier mode must match the dense
    engine's cores AND per-round message bill exactly;
  * ``mode`` — the execution mode the dense-side engine chose.

Acceptance target (ISSUE 1): at 1% churn on a 10k-vertex analogue the
incremental engine spends < 25% of the from-scratch messages per batch.
``benchmarks.streaming_gate`` turns the per-(graph, churn) mean ratios into
a CI regression gate against a committed baseline.

Environment knobs (for CI smoke):
  REPRO_STREAM_BENCH_N        target vertex count        (default 10000)
  REPRO_STREAM_BENCH_BATCHES  batches per (graph, churn) (default 5)
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core import bz_core_numbers, kcore_decompose
from repro.core.messages import heartbeat_overhead
from repro.graph import generators as gen
from repro.obs.health import InvariantMonitor
from repro.obs.metrics import MetricsRegistry
from repro.streaming import (StreamingConfig, StreamingKCoreEngine,
                             apply_batch, random_churn_batch)

GRAPHS = ("EEN", "G31", "FC")
CHURN_RATES = (0.002, 0.01, 0.02)

TARGET_N = int(os.environ.get("REPRO_STREAM_BENCH_N", "10000"))
BATCHES = int(os.environ.get("REPRO_STREAM_BENCH_BATCHES", "5"))

COLUMNS = ("graph", "n", "m", "churn", "batch", "inserted", "deleted",
           "inc_messages", "scratch_messages", "ratio", "inc_rounds",
           "scratch_rounds", "region", "mode", "patch_ms", "seed_ms",
           "converge_ms", "reconstruct_ms", "rebuild_ms", "heartbeats",
           "recompiles", "compactions", "dead_frac", "occupancy",
           "sharded_ok", "bill_invariant", "oracle_ok")


def settings() -> dict:
    return {"target_n": TARGET_N, "batches": BATCHES,
            "graphs": list(GRAPHS), "churn_rates": list(CHURN_RATES)}


def run_records() -> list[dict]:
    """Structured per-batch records (the CSV in run() and the JSON artifact
    in streaming_gate both render these)."""
    records = []
    # message-bill mode-invariance, checked through the invariant monitor
    # (repro.obs.health): the dense and sharded engines bill each batch
    # under the same key — differing totals raise an anomaly. A local
    # registry keeps the bench from polluting the process-wide metrics.
    monitor = InvariantMonitor(registry=MetricsRegistry())
    for abbrev in GRAPHS:
        entry = gen.SNAP_BY_ABBREV[abbrev]
        scale = TARGET_N / entry.n
        for churn in CHURN_RATES:
            g = gen.snap_analogue(abbrev, scale=scale, seed=0)
            eng = StreamingKCoreEngine(g)
            sharded = StreamingKCoreEngine(
                g, StreamingConfig(frontier="sharded"))
            rng = np.random.default_rng(1)
            for t in range(BATCHES):
                g_before = eng.graph       # materialized pre-batch snapshot
                b = max(2, int(churn * g_before.m))
                batch = random_churn_batch(g_before, b // 2, b - b // 2,
                                           rng)
                res = eng.apply_batch(batch)
                # the old path: full sorted-COO rebuild of the same batch
                t0 = time.perf_counter()
                apply_batch(g_before, batch)
                rebuild_s = time.perf_counter() - t0

                res_sh = sharded.apply_batch(batch)
                sharded_ok = bool(
                    (res.core == res_sh.core).all()
                    and (res.stats.messages_per_round
                         == res_sh.stats.messages_per_round).all())
                assert sharded_ok, (
                    f"{abbrev} churn={churn} batch={t}: sharded engine "
                    "diverged from the single-device engine")
                before = monitor.anomalies
                key = (abbrev, churn, t)
                monitor.observe_bill(key, "dense",
                                     int(res.total_messages))
                monitor.observe_bill(key, "sharded",
                                     int(res_sh.total_messages))
                bill_invariant = monitor.anomalies == before
                assert bill_invariant, (
                    f"{abbrev} churn={churn} batch={t}: "
                    f"mode bill mismatch: {monitor.last}")

                scratch = kcore_decompose(eng.graph)
                ok = bool((res.core == bz_core_numbers(eng.graph)).all())
                assert ok, (f"{abbrev} churn={churn} batch={t}: incremental "
                            "cores diverged from the BZ oracle")
                ratio = res.total_messages / max(
                    scratch.stats.total_messages, 1)
                records.append({
                    "graph": abbrev, "n": eng.graph.n, "m": eng.graph.m,
                    "churn": churn, "batch": t,
                    "inserted": int(res.delta.inserted.shape[0]),
                    "deleted": int(res.delta.deleted.shape[0]),
                    "inc_messages": int(res.total_messages),
                    "scratch_messages": int(scratch.stats.total_messages),
                    "ratio": round(ratio, 4),
                    "inc_rounds": res.rounds,
                    "scratch_rounds": scratch.rounds,
                    "region": res.region_size,
                    "mode": res.mode,
                    # per-phase breakdown of the incremental batch (engine-
                    # measured walls; same boundaries as the trace spans)
                    "patch_ms": round(res.patch_s * 1e3, 3),
                    "seed_ms": round(res.seed_s * 1e3, 3),
                    "converge_ms": round(res.converge_s * 1e3, 3),
                    "reconstruct_ms": round(res.reconstruct_s * 1e3, 3),
                    "rebuild_ms": round(rebuild_s * 1e3, 3),
                    # modeled termination-detection bill (§III.C heartbeat
                    # model at round granularity) for this batch
                    "heartbeats": int(heartbeat_overhead(
                        res.stats)["heartbeat_messages"]),
                    # jit-recompile telemetry (dense-side engine; 0 = all
                    # programs were cache hits this batch)
                    "recompiles": res.recompiles,
                    # PatchableCSR health — compaction behavior over the
                    # stream (cumulative count, fragmentation, slack usage)
                    "compactions": res.csr_compactions,
                    "dead_frac": round(res.csr_dead_frac, 4),
                    "occupancy": round(res.csr_occupancy, 4),
                    "sharded_ok": sharded_ok,
                    "bill_invariant": bill_invariant, "oracle_ok": ok,
                })
    return records


def summarize(records: list[dict]) -> dict:
    """Mean ratio / patch / rebuild per (graph, churn) — the gated signal."""
    out: dict = {}
    for r in records:
        out.setdefault(f"{r['graph']}/{r['churn']}", []).append(r)
    return {key: {
        "mean_ratio": round(float(np.mean([r["ratio"] for r in rs])), 4),
        "mean_patch_ms": round(float(np.mean([r["patch_ms"] for r in rs])),
                               3),
        "mean_seed_ms": round(float(np.mean([r["seed_ms"] for r in rs])), 3),
        "mean_converge_ms": round(float(np.mean([r["converge_ms"]
                                                 for r in rs])), 3),
        "mean_rebuild_ms": round(float(np.mean([r["rebuild_ms"]
                                                for r in rs])), 3),
        "total_heartbeats": int(np.sum([r["heartbeats"] for r in rs])),
        "compactions": int(rs[-1]["compactions"]),
        "mean_occupancy": round(float(np.mean([r["occupancy"]
                                               for r in rs])), 4),
    } for key, rs in out.items()}


def run() -> list[str]:
    records = run_records()
    rows = [csv_row(*COLUMNS)]
    rows.extend(csv_row(*(r[c] for c in COLUMNS)) for r in records)
    for key, s in summarize(records).items():
        graph, churn = key.split("/")
        mean = {c: "" for c in COLUMNS}
        mean.update(graph=graph, churn=churn, batch="mean",
                    ratio=s["mean_ratio"], patch_ms=s["mean_patch_ms"],
                    rebuild_ms=s["mean_rebuild_ms"],
                    compactions=s["compactions"],
                    occupancy=s["mean_occupancy"])
        rows.append(csv_row(*(mean[c] for c in COLUMNS)))
    return rows
