"""Streaming maintenance benchmark: incremental vs from-scratch message bill.

For each graph (10k-vertex SNAP analogues by default) and churn rate, applies
a sequence of random edge-churn batches through the incremental engine and
compares its per-batch message bill against a full from-scratch
re-decomposition of the same post-batch graph. Every batch is verified
against the BZ oracle — the ratio column is only meaningful because the
incremental answer is exact.

Acceptance target (ISSUE 1): at 1% churn on a 10k-vertex analogue the
incremental engine spends < 25% of the from-scratch messages per batch.

Environment knobs (for CI smoke):
  REPRO_STREAM_BENCH_N        target vertex count        (default 10000)
  REPRO_STREAM_BENCH_BATCHES  batches per (graph, churn) (default 5)
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import csv_row
from repro.core import bz_core_numbers, kcore_decompose
from repro.graph import generators as gen
from repro.streaming import StreamingKCoreEngine, random_churn_batch

GRAPHS = ("EEN", "G31", "FC")
CHURN_RATES = (0.002, 0.01, 0.02)

TARGET_N = int(os.environ.get("REPRO_STREAM_BENCH_N", "10000"))
BATCHES = int(os.environ.get("REPRO_STREAM_BENCH_BATCHES", "5"))


def run() -> list[str]:
    rows = [csv_row("graph", "n", "m", "churn", "batch", "inserted",
                    "deleted", "inc_messages", "scratch_messages", "ratio",
                    "inc_rounds", "scratch_rounds", "region", "oracle_ok")]
    for abbrev in GRAPHS:
        entry = gen.SNAP_BY_ABBREV[abbrev]
        scale = TARGET_N / entry.n
        for churn in CHURN_RATES:
            g = gen.snap_analogue(abbrev, scale=scale, seed=0)
            eng = StreamingKCoreEngine(g)
            rng = np.random.default_rng(1)
            ratios = []
            for t in range(BATCHES):
                b = max(2, int(churn * eng.graph.m))
                batch = random_churn_batch(eng.graph, b // 2, b - b // 2,
                                           rng)
                res = eng.apply_batch(batch)
                scratch = kcore_decompose(eng.graph)
                ok = bool((res.core == bz_core_numbers(eng.graph)).all())
                assert ok, (f"{abbrev} churn={churn} batch={t}: incremental "
                            "cores diverged from the BZ oracle")
                ratio = res.total_messages / max(
                    scratch.stats.total_messages, 1)
                ratios.append(ratio)
                rows.append(csv_row(
                    abbrev, eng.graph.n, eng.graph.m, churn, t,
                    res.delta.inserted.shape[0], res.delta.deleted.shape[0],
                    res.total_messages, scratch.stats.total_messages,
                    round(ratio, 4), res.rounds, scratch.rounds,
                    res.region_size, ok))
            rows.append(csv_row(
                abbrev, eng.graph.n, eng.graph.m, churn, "mean", "", "",
                "", "", round(float(np.mean(ratios)), 4), "", "", "", ""))
    return rows
