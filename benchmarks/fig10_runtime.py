"""Paper Fig 10: total running time. The paper itself warns wall-clock of
the simulation is not a deployment proxy — we report the simulation wall
time AND the message-complexity-derived simulated runtimes (cost_model)
under three network regimes, which is the §V future-work item."""

from repro.core.cost_model import DATACENTER, INTERNET, TPU_POD, \
    simulate_runtime
from repro.graph.generators import SNAP_TABLE

from benchmarks.common import csv_row, decompose


def run() -> list[str]:
    rows = [csv_row("graph", "sim_wall_s", "fused_wall_s", "fused_speedup",
                    "internet_s", "datacenter_s", "tpu_pod_s",
                    "latency_bound_frac_internet")]
    for e in SNAP_TABLE:
        res, wall = decompose(e.abbrev)
        # fused mode: same decomposition as one device-resident while_loop
        # (identical message bill — checked in fig5); first call pays the
        # XLA compile, so this wall is an upper bound on the fused cost
        _fres, fwall = decompose(e.abbrev, fused=True)
        t_net = simulate_runtime(res.stats, INTERNET)
        t_dc = simulate_runtime(res.stats, DATACENTER)
        t_tpu = simulate_runtime(res.stats, TPU_POD)
        rows.append(csv_row(
            e.abbrev, round(wall, 3), round(fwall, 3),
            round(wall / max(fwall, 1e-9), 2), round(t_net["total_s"], 4),
            round(t_dc["total_s"], 6), round(t_tpu["total_s"], 6),
            round(t_net["latency_bound_fraction"], 3)))
    return rows
