"""Paper Table I: tested data graphs — original stats vs our analogues,
with MaxCore computed by the engine (validated vs BZ)."""

from repro.core import bz_core_numbers
from repro.graph.generators import SNAP_TABLE

from benchmarks.common import csv_row, decompose, graph_for


def run() -> list[str]:
    rows = [csv_row("abbrev", "orig_n", "orig_m", "orig_maxcore",
                    "analogue_n", "analogue_m", "avg_deg", "max_deg",
                    "max_core", "matches_bz")]
    for e in SNAP_TABLE:
        g = graph_for(e.abbrev)
        res, _ = decompose(e.abbrev)
        ok = bool((res.core == bz_core_numbers(g)).all())
        rows.append(csv_row(
            e.abbrev, e.n, e.m, e.max_core, g.n, g.m,
            round(g.avg_deg, 1), g.max_deg, int(res.core.max()), ok))
    return rows
